"""Time-varying FIFO client datasets (paper Section II-A).

Each client stores at most D_u samples. Between global rounds up to E_u new
samples arrive; each of the E_u arrival slots is an independent
Bernoulli(p_ac) trial, so the number of arrivals is Binomial(E_u, p_ac).
Arrivals are staged in a temporary buffer and the dataset is updated once,
FIFO, right before the next round (paper footnote: "the arrived sample can be
held in a temporary buffer").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass
class OnlineBuffer:
    capacity: int                     # D_u
    x: np.ndarray                     # (capacity, ...) feature storage
    y: np.ndarray                     # (capacity,) labels
    size: int = 0
    head: int = 0                     # FIFO eviction pointer (oldest sample)
    _staged_x: list = field(default_factory=list)
    _staged_y: list = field(default_factory=list)
    last_hist: Optional[np.ndarray] = None

    @classmethod
    def create(cls, capacity: int, feature_shape: tuple, num_classes: int,
               dtype=np.float32, label_dtype=np.int64) -> "OnlineBuffer":
        buf = cls(capacity=capacity,
                  x=np.zeros((capacity,) + feature_shape, dtype),
                  y=np.zeros((capacity,), label_dtype))
        buf.num_classes = num_classes
        return buf

    # -- staging (within-round arrivals go to the temp buffer) --------------
    def stage(self, x_new: np.ndarray, y_new: np.ndarray) -> None:
        for xi, yi in zip(x_new, y_new):
            self._staged_x.append(xi)
            self._staged_y.append(yi)

    def commit(self) -> int:
        """Apply staged arrivals FIFO at the round boundary. Returns #ingested."""
        n = len(self._staged_x)
        for xi, yi in zip(self._staged_x, self._staged_y):
            self._insert(xi, yi)
        self._staged_x, self._staged_y = [], []
        return n

    def _insert(self, xi, yi) -> None:
        if self.size < self.capacity:
            idx = (self.head + self.size) % self.capacity
            self.size += 1
        else:
            idx = self.head                       # overwrite oldest
            self.head = (self.head + 1) % self.capacity
        self.x[idx] = xi
        self.y[idx] = yi

    # -- views ---------------------------------------------------------------
    def dataset(self) -> Tuple[np.ndarray, np.ndarray]:
        idx = (self.head + np.arange(self.size)) % self.capacity
        return self.x[idx], self.y[idx]

    def label_histogram(self) -> np.ndarray:
        _, y = self.dataset()
        h = np.bincount(y, minlength=self.num_classes).astype(np.float64)
        return h / max(h.sum(), 1)

    def distribution_shift(self) -> float:
        """Empirical proxy for Phi_u^t (Definition 1): squared L2 distance
        between the label distributions of consecutive rounds."""
        h = self.label_histogram()
        if self.last_hist is None:
            shift = 0.0
        else:
            shift = float(np.sum((h - self.last_hist) ** 2))
        self.last_hist = h
        return shift

    def sample_batch(self, rng: np.random.Generator, batch: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
        x, y = self.dataset()
        idx = rng.integers(0, len(y), size=batch)
        return x[idx], y[idx]

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Full snapshot: storage, FIFO pointers, staged-but-uncommitted
        arrivals and the shift-proxy memory (see repro/checkpoint)."""
        feat = self.x.shape[1:]
        return {
            "capacity": int(self.capacity),
            "x": self.x, "y": self.y,
            "size": int(self.size), "head": int(self.head),
            "staged_x": (np.stack(self._staged_x).astype(self.x.dtype)
                         if self._staged_x
                         else np.zeros((0,) + feat, self.x.dtype)),
            "staged_y": np.asarray(self._staged_y, self.y.dtype),
            "num_classes": int(getattr(self, "num_classes", 0)),
            "last_hist": self.last_hist,
        }

    def load_state_dict(self, sd: dict) -> None:
        """Restore a ``state_dict`` snapshot (full overwrite)."""
        self.capacity = int(sd["capacity"])
        self.x = np.array(sd["x"])
        self.y = np.array(sd["y"])
        self.size = int(sd["size"])
        self.head = int(sd["head"])
        self._staged_x = [np.array(r) for r in sd["staged_x"]]
        self._staged_y = list(np.asarray(sd["staged_y"]))
        self.num_classes = int(sd["num_classes"])
        lh = sd["last_hist"]
        self.last_hist = None if lh is None else np.asarray(lh)


def binomial_arrivals(rng: np.random.Generator, e_u: int, p_ac: float) -> int:
    """Number of new samples between two rounds: Binomial(E_u, p_ac)."""
    return int(rng.binomial(e_u, p_ac))
