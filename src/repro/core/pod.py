"""Pod-scale OSAFL engines (the paper's aggregation mapped onto TPU meshes).

Clients ⇄ data-parallel rows of the mesh. Three engines (DESIGN.md §3):

exact_tp        shard_map manual over the client axes ('pod','data'), auto-TP
                over 'model'. Per-client gradients are the natural pre-all-
                reduce local gradients; OSAFL's server-side scoring becomes a
                two-phase scored all-reduce:
                  (1) psum(g)   -> mean update d^t          [grad-sized]
                  (2) local dot/norm scalars -> lambda_u -> Delta_u
                  (3) psum(Delta_u * g) -> scored update    [grad-sized]
                Exact paper semantics (kappa=1 normalized update), 1 backward.

exact_recompute auto-SPMD (any sharding incl. FSDP, for the >100B MoE archs
                whose replicas cannot fit TP-only). Clients are microbatch
                groups scanned twice: pass 1 accumulates sum d_u, pass 2
                recomputes each d_u, scores it against d^t on the fly and
                accumulates Delta_u d_u. Exact semantics, 2 backwards.

sketch          beyond-paper §Perf variant of exact_tp: replace the mean-
                update psum with a k-dim count-sketch psum; lambda_u is
                estimated from sketches (unbiased JL inner products). One
                grad-sized all-reduce instead of two.

Online mode (DESIGN.md §3 "Online arrivals"): every factory also accepts
``batch_fn``/``grad_fn``. With ``batch_fn`` set, the returned step no longer
takes a stationary batch — it takes the client-sharded storage of a
``StackedOnlineBuffer`` plus sampled slots, gathers each mesh row's local-SGD
minibatches from its own buffer shard *inside* the shard_map body
(``make_pod_batch_fn``), and runs the paper's masked kappa_u-step local SGD
(``client.make_local_train_body``) per client. The step returns the stacked
``(d, w)`` client contributions; aggregation stays with the stacked servers
(``repro.harness.run`` on the pod engine), whose dense
``(U, N)`` round ops shard over the same client axes under auto-SPMD.

The online steps are indifferent to what the leading client dimension
indexes: under the sparse-cohort engine (``core/cohort.py``) the storage,
slots and kappas arriving here are *slot*-indexed arrays of width C (the
active-slot pool capacity, C % mesh client rows == 0) rather than
user-indexed arrays of width U — the per-row local-SGD math is identical,
only the harness's gather/scatter against the per-user tables changes.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import FLConfig, ModelConfig
from repro.core.shmap import client_axes, client_rows, shard_map
from repro.core.scores import (sketch_tree, tree_add, tree_dot, tree_norm,
                               tree_scale, tree_sub, tree_zeros_like)
from repro.models.transformer import decode_step, forward, loss_fn

# clients ⇄ mesh rows: one client per device along the client axes
num_pod_clients = client_rows


def _lambda(chi, cos):
    return (chi + cos) / (chi + 1.0)


def _scored_metrics(lam, loss, axes, U):
    return {
        "loss": jax.lax.psum(loss, axes) / U,
        "lambda_mean": jax.lax.psum(lam, axes) / U,
        "lambda_min": -jax.lax.pmax(-lam, axes),
        "lambda_max": jax.lax.pmax(lam, axes),
    }


# ---------------------------------------------------------------------------
# online mode: mesh rows sample their minibatches from their own shard of a
# StackedOnlineBuffer (the paper's FIFO arrivals at pod scale)
# ---------------------------------------------------------------------------

def make_pod_batch_fn() -> Callable:
    """The sampling layer between a mesh-sharded ``StackedOnlineBuffer`` and
    the pod train steps: ``batch_fn(bx, by, slots)`` gathers each client
    row's local-SGD minibatches from that client's own storage rows.

    ``bx``/``by`` are buffer storage ``(U_loc, D, *feat)`` / ``(U_loc, D)``
    (one whole shard inside a shard_map body; the full arrays under
    auto-SPMD or on a 1-row mesh) and ``slots`` is ``(U_loc, kappa_max, B)``
    live-window storage slots from ``StackedOnlineBuffer.sample_slots``.
    Returns the ``{"x", "y"}`` batch pytree with leaves
    ``(U_loc, kappa_max, B, ...)`` that ``client.make_local_train_body``
    consumes. Row-local by construction — client u's minibatches only ever
    read storage row u — so under shard_map there is no cross-shard (and no
    host) gather.
    """
    def batch_fn(bx, by, slots):
        uu = jnp.arange(bx.shape[0], dtype=jnp.int32)[:, None, None]
        return {"x": bx[uu, slots], "y": by[uu, slots]}
    return batch_fn


def _online_grad_fn(grad_fn, cfg):
    if grad_fn is not None:
        return grad_fn
    return jax.grad(lambda p, b: loss_fn(p, b, cfg)[0])


def _make_online_step(fl: FLConfig, mesh, batch_fn: Callable,
                      grad_fn: Callable, *, scan: bool = False,
                      prox_mu: float = 0.0) -> Callable:
    """Online train step shared by the four engine factories:
    ``step(params, bx, by, slots, kappas) -> (d, w)`` with ``d``/``w``
    stacked over the client axes. ``scan=False`` (exact_tp / stale / fedavg
    flavors) runs every shard's clients under one vmap inside a shard_map
    body; ``scan=True`` (the recompute flavor) scans clients sequentially
    under auto-SPMD, trading wall-clock for the recompute engine's O(1)
    per-client activation memory. Both execute the identical per-client
    masked local-SGD math (``client.make_local_train_body``), so the engines
    agree to float tolerance and kappa_u = 0 stragglers yield d_u = 0.
    """
    from repro.core.client import make_local_train_body
    one_client = make_local_train_body(grad_fn, fl.local_lr, fl.kappa_max,
                                       prox_mu=prox_mu)

    if scan:
        def step(params, bx, by, slots, kappas):
            batch = batch_fn(bx, by, slots)

            def body(_, inp):
                batch_u, kappa_u = inp
                return None, one_client(params, batch_u, kappa_u)

            _, (d, w) = jax.lax.scan(body, None, (batch, kappas))
            return d, w
        return step

    axes = client_axes(mesh)

    def body(params, bx, by, slots, kappas):
        batch = batch_fn(bx, by, slots)
        return jax.vmap(one_client, in_axes=(None, 0, 0))(params, batch,
                                                          kappas)

    def step(params, bx, by, slots, kappas):
        def row(x):
            return P(axes, *([None] * (x.ndim - 1)))
        in_specs = (jax.tree.map(lambda _: P(), params),
                    row(bx), row(by), row(slots), P(axes))
        out_shape = jax.eval_shape(body, params, bx, by, slots, kappas)
        out_specs = jax.tree.map(row, out_shape)
        return shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs,
                         axis_names=set(axes))(params, bx, by, slots, kappas)
    return step


# ---------------------------------------------------------------------------
# exact_tp / sketch engines (shard_map manual over clients, auto over model)
# ---------------------------------------------------------------------------

def make_tp_train_step(cfg: ModelConfig, fl: FLConfig, mesh,
                       *, sketch_dim: int = 0, batch_fn: Callable = None,
                       grad_fn: Callable = None,
                       prox_mu: float = 0.0) -> Callable:
    if batch_fn is not None:
        # online mode: rows sample from their own buffer shard (module doc)
        return _make_online_step(fl, mesh, batch_fn,
                                 _online_grad_fn(grad_fn, cfg),
                                 prox_mu=prox_mu)
    axes = client_axes(mesh)
    U = num_pod_clients(mesh)
    lr_eff = fl.global_lr * fl.local_lr
    chi = fl.chi
    sketch_key = jax.random.PRNGKey(17)

    def local_update(params, batch):
        """Client-local normalized update d_u (kappa-step grad accumulation:
        d_u = (1/kappa) sum_tau g(w, b_tau) — first-order-exact local SGD)."""
        def one(batch_tau):
            (l, m), g = jax.value_and_grad(
                lambda p: loss_fn(p, batch_tau, cfg), has_aux=True)(params)
            return l, g
        if fl.kappa_max <= 1:
            return one(batch)
        # microbatch split along batch dim
        split = jax.tree.map(
            lambda x: x.reshape((fl.kappa_max, -1) + x.shape[1:]), batch)
        def body(acc, b_tau):
            l, g = one(b_tau)
            return (acc[0] + l / fl.kappa_max,
                    tree_add(acc[1], tree_scale(g, 1.0 / fl.kappa_max))), None
        (l, g), _ = jax.lax.scan(body, (jnp.float32(0.0),
                                        tree_zeros_like(params)), split)
        return l, g

    def step_body(params, batch):
        loss, g = local_update(params, batch)
        if sketch_dim:
            sk = sketch_tree(g, sketch_key, sketch_dim)
            sk_mean = jax.lax.psum(sk, axes) / U
            cos = jnp.vdot(sk, sk_mean) / jnp.maximum(
                jnp.linalg.norm(sk) * jnp.linalg.norm(sk_mean), 1e-12)
        else:
            d_mean = jax.tree.map(lambda x: jax.lax.psum(x, axes) / U, g)
            cos = tree_dot(g, d_mean) / jnp.maximum(
                tree_norm(g) * tree_norm(d_mean), 1e-12)
        lam = _lambda(chi, cos)
        update = jax.tree.map(lambda x: jax.lax.psum(lam * x, axes) / U, g)
        new_params = jax.tree.map(lambda w, u: w - lr_eff * u.astype(w.dtype),
                                  params, update)
        return new_params, _scored_metrics(lam, loss, axes, U)

    batch_spec = P(axes)  # shard batch dim over client axes

    def step(params, batch):
        in_specs = (jax.tree.map(lambda _: P(), params),
                    jax.tree.map(lambda _: batch_spec, batch))
        out_specs = (jax.tree.map(lambda _: P(), params),
                     {k: P() for k in ("loss", "lambda_mean", "lambda_min",
                                       "lambda_max")})
        return shard_map(step_body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs,
                         axis_names=set(axes))(params, batch)
    return step


# ---------------------------------------------------------------------------
# exact_recompute engine (auto-SPMD; FSDP-compatible; 2 backwards)
# ---------------------------------------------------------------------------

def make_recompute_train_step(cfg: ModelConfig, fl: FLConfig, mesh,
                              num_clients: int, grad_specs=None,
                              *, batch_fn: Callable = None,
                              grad_fn: Callable = None,
                              prox_mu: float = 0.0) -> Callable:
    if batch_fn is not None:
        # online mode: sequential client scan under auto-SPMD (grad_specs
        # pinning is a stationary-batch concern; the online scan carries no
        # grad-sized accumulator — aggregation lives in the stacked server)
        return _make_online_step(fl, mesh, batch_fn,
                                 _online_grad_fn(grad_fn, cfg),
                                 scan=True, prox_mu=prox_mu)
    lr_eff = fl.global_lr * fl.local_lr
    chi = fl.chi
    U = num_clients

    def pin(tree):
        """Pin the grad accumulator to the parameter sharding: without this
        the SPMD partitioner replicates the scan carry and all-gathers full
        stacked expert-gradient tensors every client iteration (§Perf A2:
        13.9TB/step of all-gather on deepseek-v3)."""
        if grad_specs is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree,
            grad_specs)

    import os
    acc_dtype = (jnp.bfloat16 if os.environ.get("REPRO_ACCUM_BF16") == "1"
                 else jnp.float32)

    def grad_u(params, batch_u):
        (l, m), g = jax.value_and_grad(
            lambda p: loss_fn(p, batch_u, cfg), has_aux=True)(params)
        # accumulate in f32 by default: bf16 params yield mixed cotangents.
        # REPRO_ACCUM_BF16=1 accumulates in bf16 (§Perf A3 experiment).
        return l, pin(jax.tree.map(lambda x: x.astype(acc_dtype), g))

    def f32_zeros(params):
        return pin(jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params))

    def step(params, batch):
        # batch leaves: (U, b, ...) — clients scanned sequentially
        def pass1(acc, batch_u):
            l, g = grad_u(params, batch_u)
            return pin(tree_add(acc, g)), l
        sum_d, losses = jax.lax.scan(pass1, f32_zeros(params), batch)
        d_mean = tree_scale(sum_d, 1.0 / U)
        nm = tree_norm(d_mean)

        def pass2(acc, batch_u):
            _, g = grad_u(params, batch_u)
            cos = tree_dot(g, d_mean) / jnp.maximum(tree_norm(g) * nm, 1e-12)
            lam = _lambda(chi, cos)
            scaled = jax.tree.map(lambda x: (lam * x).astype(acc_dtype), g)
            return pin(tree_add(acc, scaled)), lam
        wsum, lams = jax.lax.scan(pass2, f32_zeros(params), batch)
        update = tree_scale(wsum, 1.0 / U)
        new_params = jax.tree.map(lambda w, u: w - lr_eff * u.astype(w.dtype),
                                  params, update)
        metrics = {"loss": jnp.mean(losses), "lambda_mean": jnp.mean(lams),
                   "lambda_min": jnp.min(lams), "lambda_max": jnp.max(lams)}
        return new_params, metrics
    return step


# ---------------------------------------------------------------------------
# stale-score engine (beyond-paper §Perf A5): ONE backward pass.
# Delta_u^t is computed from round t-1's gradient sketches; this round's
# sketches are accumulated during the same pass for round t+1. Exact OSAFL
# needs d^t before it can weight d_u^t (hence recompute's 2 passes); scores
# drift slowly round-to-round, so a one-round-stale lambda trades a small
# weighting lag for halving compute/memory/collectives. Task-accuracy impact
# is validated on the paper's CPU experiments (benchmarks/ablation).
# ---------------------------------------------------------------------------

def make_stale_score_train_step(cfg: ModelConfig, fl: FLConfig, mesh,
                                num_clients: int, grad_specs=None,
                                sketch_dim: int = 1024,
                                *, batch_fn: Callable = None,
                                grad_fn: Callable = None,
                                prox_mu: float = 0.0) -> Callable:
    if batch_fn is not None:
        # online mode: local SGD is identical to exact_tp's; the one-round
        # score lag lives server-side (FLConfig.stale_scores — the stacked
        # servers weight this round's buffer with round t-1's lambdas)
        return _make_online_step(fl, mesh, batch_fn,
                                 _online_grad_fn(grad_fn, cfg),
                                 prox_mu=prox_mu)
    lr_eff = fl.global_lr * fl.local_lr
    chi = fl.chi
    U = num_clients
    sketch_key = jax.random.PRNGKey(17)

    def pin(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree,
            grad_specs)

    def step(params, lam_prev, batch):
        """lam_prev: (U,) scores from the previous round (init: ones)."""
        def body(acc, inp):
            batch_u, lam_u = inp
            (l, m), g = jax.value_and_grad(
                lambda p: loss_fn(p, batch_u, cfg), has_aux=True)(params)
            g = pin(jax.tree.map(lambda x: x.astype(jnp.float32), g))
            sk = sketch_tree(g, sketch_key, sketch_dim)
            acc = pin(jax.tree.map(lambda a, x: a + lam_u * x, acc, g))
            return acc, (l, sk)

        zeros = pin(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        wsum, (losses, sketches) = jax.lax.scan(body, zeros,
                                                (batch, lam_prev))
        update = tree_scale(wsum, 1.0 / U)
        new_params = jax.tree.map(lambda w, u: w - lr_eff * u.astype(w.dtype),
                                  params, update)
        # next round's scores from this round's sketches (eq. 20 on sketches)
        mean_sk = jnp.mean(sketches, axis=0)
        cos = (sketches @ mean_sk) / jnp.maximum(
            jnp.linalg.norm(sketches, axis=1) * jnp.linalg.norm(mean_sk),
            1e-12)
        lam_next = _lambda(chi, cos)
        metrics = {"loss": jnp.mean(losses),
                   "lambda_mean": jnp.mean(lam_next),
                   "lambda_min": jnp.min(lam_next),
                   "lambda_max": jnp.max(lam_next)}
        return new_params, lam_next, metrics
    return step


# ---------------------------------------------------------------------------
# plain data-parallel train step (the M-FedAvg pod baseline: 1 all-reduce)
# ---------------------------------------------------------------------------

def make_fedavg_train_step(cfg: ModelConfig, fl: FLConfig, mesh,
                           *, batch_fn: Callable = None,
                           grad_fn: Callable = None,
                           prox_mu: float = 0.0) -> Callable:
    """Ordinary DP+TP step — the unscored baseline the roofline compares to."""
    if batch_fn is not None:
        # online mode: same sharded local SGD; unscored averaging lives in
        # the stacked FedAvg server
        return _make_online_step(fl, mesh, batch_fn,
                                 _online_grad_fn(grad_fn, cfg),
                                 prox_mu=prox_mu)
    lr_eff = fl.global_lr * fl.local_lr

    def step(params, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        new_params = jax.tree.map(lambda w, u: w - lr_eff * u.astype(w.dtype),
                                  params, g)
        return new_params, {"loss": loss}
    return step


# ---------------------------------------------------------------------------
# serving steps (decode shapes)
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, tokens, pos, memory=None):
        logits, new_cache = decode_step(params, cache, tokens, pos, cfg,
                                        memory=memory)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache
    return serve_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill(params, batch):
        logits, _ = forward(params, batch, cfg)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    return prefill
