"""Batched joint resource optimization (paper Section II-C, Appendix B).

Vectorized port of ``core/resource.py``: the same Lemma 1 / Lemma 2 closed
forms and the interval-endpoint SCA power step, evaluated for all U clients
at once as elementwise jnp over (U,) arrays. The math is purely elementwise,
so the port is a broadcast rewrite of the scalar module; the per-client
NumPy module remains the oracle and this module must agree with it exactly
on kappa/feasibility and to <= 1e-6 relative on (f, p)
(tests/test_online_stacked.py).

The solve runs in float64 under a scoped ``jax.experimental.enable_x64``
context (the repo keeps the global x64 flag off): the SCA's minimum-SNR term
2^(Nb / (omega * t_left)) overflows float32 under tight deadlines, and the
parity bar sits far below f32 resolution. Per-client early exits in the
scalar algorithm (straggler breaks, frequency fallback, SCA convergence)
become lane masks; iteration counts are the static
``NetworkConfig.outer_iters`` / ``sca_iters``, so the whole alternating
solve — all five initial power points of Algorithm 1's sweep — jits to one
XLA program per network configuration.

Channel sampling is vectorized too, and ``np.random.Generator`` draws are
stream-equivalent between one size-U array draw and U sequential scalar
draws, so ``sample_channels`` reproduces the loop path's channels exactly
for the same generator state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import lru_cache
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.resource import (_J_SLACK, _P_SLACK, FPP, ClientSystem,
                                 NetworkConfig, pathloss_linear)

_LN2 = float(np.log(2.0))


@dataclass
class ClientSystemBatch:
    """Column-stacked ``ClientSystem``: every field an (U,) float64 array."""
    c: np.ndarray
    s: np.ndarray
    f_max: np.ndarray
    p_max: np.ndarray
    e_bd: np.ndarray
    distance: np.ndarray

    def __len__(self) -> int:
        return self.c.shape[0]


def stack_clients(clients: Sequence[ClientSystem]) -> ClientSystemBatch:
    """Stack a ``make_clients`` population into (U,) field arrays."""
    cols = {f.name: np.array([getattr(cl, f.name) for cl in clients],
                             np.float64)
            for f in dataclasses.fields(ClientSystem)}
    return ClientSystemBatch(**cols)


@dataclass
class ChannelBatch:
    """Per-round wireless channels for the whole cohort: (U,) arrays."""
    xi: np.ndarray
    gamma: np.ndarray


def sample_channels(rng: np.random.Generator, sysb: ClientSystemBatch,
                    shadow_sigma_db: float = 8.0) -> ChannelBatch:
    """Whole-cohort ``resource.sample_channel``: one array draw, same stream
    as U sequential scalar draws from the same generator state."""
    gamma = 10 ** (rng.normal(0.0, shadow_sigma_db, size=len(sysb)) / 10)
    return ChannelBatch(xi=pathloss_linear(sysb.distance), gamma=gamma)


@dataclass
class ResourceDecisionBatch:
    """Column-stacked ``ResourceDecision``; ``kappa`` is 0 for stragglers."""
    kappa: np.ndarray       # (U,) int64
    f: np.ndarray           # (U,) float64
    p: np.ndarray           # (U,) float64
    feasible: np.ndarray    # (U,) bool
    t_total: np.ndarray     # (U,) float64
    e_total: np.ndarray     # (U,) float64


@lru_cache(maxsize=8)
def _make_solver(net_fields: tuple):
    """Build (and cache) the jitted all-clients solve for one NetworkConfig.

    The returned fn maps (c, s, f_max, p_max, e_bd, xi, gamma, n_params) —
    all (U,) f64 except the scalar payload — to the six decision columns.
    Every formula below mirrors the scalar module line-for-line; only the
    control flow changes (breaks -> lane masks, init-point loop -> vmap).
    """
    net = NetworkConfig(*net_fields)
    noise = net.noise_power
    fracs = np.array([1.0, 0.1, 0.01, 1e-3, 1e-4])
    ks = np.arange(1.0, net.kappa_max + 1)          # (K,) candidate kappas

    def solve(c, s, f_max, p_max, e_bd, xi, gamma, n_params):
        xg = xi * gamma
        cc = net.n * net.nbar * c * s               # cycles per local round
        nb = n_params * (FPP + 1)                   # upload payload (bits)
        g = xg / noise                              # SNR slope: snr = g*p

        def rate(p):
            return net.omega * jnp.log2(1.0 + xg * p / noise)

        def t_up(p):
            return nb / jnp.maximum(rate(p), 1e-12)

        def e_up(p):
            return t_up(p) * p

        def opt_kappa(f, p):
            """Lemma 1 (eq. 42)."""
            j1 = (e_bd - e_up(p)) / (0.5 * net.v * cc * f ** 2)
            j2 = f * (net.t_th - t_up(p)) / cc
            k = jnp.minimum(float(net.kappa_max),
                            jnp.floor(jnp.minimum(j1, j2) + _J_SLACK))
            return jnp.maximum(k, 0.0)

        def opt_freq(kappa, p):
            """Lemma 2 (eq. 48); inf where upload alone exceeds deadline."""
            r = rate(p)
            denom = net.t_th * r - nb
            val = cc * kappa * r / jnp.where(denom > 0, denom, 1.0)
            return jnp.where(denom > 0, val, jnp.inf)

        def sca_power(kappa, f, p0):
            """SCA (eqs. 50-52) with convergence/abort masks per lane."""
            e_cp = 0.5 * net.v * cc * kappa * f ** 2
            t_cp = cc * kappa / f
            t_left = net.t_th - t_cp
            valid = t_left > 0
            snr_min = 2.0 ** (nb / (net.omega *
                                    jnp.where(valid, t_left, 1.0))) - 1.0
            p_lo = snr_min / g
            valid &= p_lo <= p_max * (1 + _P_SLACK)
            p_lo = jnp.where(valid, jnp.minimum(p_lo, p_max), 1e-6)
            p = jnp.maximum(jnp.maximum(jnp.minimum(p0, p_max), p_lo), 1e-6)
            done = jnp.zeros(valid.shape, bool)
            for _ in range(net.sca_iters):
                act = valid & ~done
                ln = jnp.log1p(g * p)
                obj_slope = (net.omega / _LN2) * (g / (p * (1 + g * p))
                                                  - ln / p ** 2)
                e_at = nb * _LN2 / net.omega * (p / ln)
                e_slope = nb * _LN2 / net.omega * (1 / ln - g * p /
                                                   (ln ** 2 * (1 + g * p)))
                pos = e_slope > 0
                p_hi = jnp.where(
                    pos,
                    jnp.minimum(p_max, p + (e_bd - e_cp - e_at)
                                / jnp.where(pos, e_slope, 1.0)),
                    p_max)
                bad = p_hi < p_lo - 1e-12
                valid &= ~(act & bad)
                act &= ~bad
                p_new = jnp.clip(jnp.where(obj_slope >= 0, p_hi, p_lo),
                                 p_lo, p_max)
                conv = jnp.abs(p_new - p) < net.tol
                p = jnp.where(act, jnp.where(conv, p_new,
                                             0.5 * (p + p_new)), p)
                done |= act & conv
            ok = valid & (e_up(p) + e_cp <= e_bd * (1 + 1e-6)) \
                & (t_cp + t_up(p) <= net.t_th * (1 + 1e-6))
            return p, ok

        def from_point(p0):
            """Masked ``resource._optimize_from`` over all lanes at once."""
            f, p = f_max, p0
            alive = jnp.ones(p0.shape, bool)
            rk = jnp.zeros_like(p0)
            rf, rp = f, p
            rfeas = jnp.zeros(p0.shape, bool)
            rt = jnp.zeros_like(p0)
            re_ = jnp.zeros_like(p0)
            for _ in range(net.outer_iters):
                kappa = opt_kappa(f, p)
                alive &= kappa >= 1
                f_new = opt_freq(kappa, p)
                good = jnp.isfinite(f_new) & (f_new <= f_max)
                # deadline infeasible at kappa: largest k2 < kappa that fits
                f_all = opt_freq(ks[:, None], p[None, :])        # (K, U)
                ok_all = jnp.isfinite(f_all) & (f_all <= f_max[None, :])
                cand = ok_all & (ks[:, None] <= (kappa - 1)[None, :])
                k2 = jnp.max(jnp.where(cand, ks[:, None], 0.0), axis=0)
                f_k2 = jnp.sum(jnp.where(ks[:, None] == k2[None, :],
                                         f_all, 0.0), axis=0)
                kappa = jnp.where(good, kappa, k2)
                f_new = jnp.where(good, f_new, f_k2)
                alive &= good | (k2 >= 1)
                f = jnp.where(alive, jnp.clip(f_new, 1e6, f_max), f)
                p_sca, sca_ok = sca_power(kappa, f, p)
                alive &= sca_ok
                p = jnp.where(alive, p_sca, p)
                t_tot = cc * kappa / f + t_up(p)
                e_tot = 0.5 * net.v * cc * kappa * f ** 2 + e_up(p)
                okc = alive & (t_tot <= net.t_th * (1 + 1e-6)) \
                    & (e_tot <= e_bd * (1 + 1e-6))
                rk = jnp.where(okc, kappa, rk)
                rf = jnp.where(okc, f, rf)
                rp = jnp.where(okc, p, rp)
                rt = jnp.where(okc, t_tot, rt)
                re_ = jnp.where(okc, e_tot, re_)
                rfeas |= okc
            return rk, rf, rp, rfeas, rt, re_

        # Algorithm 1's sweep over initial power points: all five at once
        sk, sf, sp, sfeas, st_, se = jax.vmap(from_point)(
            p_max[None, :] * fracs[:, None])
        bk = jnp.zeros_like(c)
        bf, bp = f_max, p_max
        bfeas = jnp.zeros(c.shape, bool)
        bt = jnp.zeros_like(c)
        be = jnp.zeros_like(c)
        for i in range(len(fracs)):                 # keep the scalar order
            better = sfeas[i] & (~bfeas | (sk[i] > bk))
            bk = jnp.where(better, sk[i], bk)
            bf = jnp.where(better, sf[i], bf)
            bp = jnp.where(better, sp[i], bp)
            bt = jnp.where(better, st_[i], bt)
            be = jnp.where(better, se[i], be)
            bfeas |= sfeas[i]
        return bk, bf, bp, bfeas, bt, be

    return jax.jit(solve)


def optimize_clients_batched(net: NetworkConfig, sysb: ClientSystemBatch,
                             ch: ChannelBatch, n_params: int
                             ) -> ResourceDecisionBatch:
    """All-clients ``resource.optimize_client``: one jitted f64 solve."""
    solver = _make_solver(dataclasses.astuple(net))
    with enable_x64():
        cols = (sysb.c, sysb.s, sysb.f_max, sysb.p_max, sysb.e_bd,
                ch.xi, ch.gamma)
        out = solver(*[jnp.asarray(a, jnp.float64) for a in cols],
                     jnp.float64(n_params))
        kappa, f, p, feas, t, e = [np.asarray(o) for o in out]
    return ResourceDecisionBatch(kappa=kappa.astype(np.int64), f=f, p=p,
                                 feasible=feas.astype(bool), t_total=t,
                                 e_total=e)


def optimize_round_batched(rng: np.random.Generator, net: NetworkConfig,
                           sysb: ClientSystemBatch, n_params: int
                           ) -> ResourceDecisionBatch:
    """One FL round: vectorized channel sampling + the batched solve (5)."""
    return optimize_clients_batched(net, sysb, sample_channels(rng, sysb),
                                    n_params)
