"""Batched joint resource optimization (paper Section II-C, Appendix B).

Vectorized port of ``core/resource.py``: the same Lemma 1 / Lemma 2 closed
forms and the interval-endpoint SCA power step, evaluated for all U clients
at once as elementwise jnp over (U,) arrays. The math is purely elementwise,
so the port is a broadcast rewrite of the scalar module; the per-client
NumPy module remains the oracle and this module must agree with it exactly
on kappa/feasibility and to <= 1e-6 relative on (f, p)
(tests/test_online_stacked.py).

Two numeric backends (``resource_backend`` in the harness configs):

  * ``"x64"`` (default, the parity oracle): the solve runs in float64 under
    a scoped ``jax.experimental.enable_x64`` context (the repo keeps the
    global x64 flag off). The SCA's minimum-SNR term
    2^(Nb / (omega * t_left)) overflows float32 under tight deadlines, and
    the scalar-oracle parity bar sits far below f32 resolution.
  * ``"f32"``: the accelerator-native path. The minimum-SNR/minimum-power
    step is reformulated in the log domain — ``log p_lo =
    log(expm1(Nb ln2 / (omega t_left))) - log g`` compared against
    ``log p_max`` — so the solve never materializes 2^x and compiles and
    runs without x64 on TPU/GPU. Everything else is the identical formula
    set in f32. Tolerance vs the x64 oracle is documented in DESIGN.md
    ("Fused round"): kappa/feasibility match exactly away from the
    ``_J_SLACK``/``_P_SLACK`` knife edges, (f, p) to ~1e-3 relative.

``make_solver_core`` exposes the un-jitted solve body so the fused round
(``core/round_fused.py``) can inline it into a larger single-dispatch
program; ``optimize_clients_batched`` remains the host entry point and owns
the x64 scope boundary: results are materialized to host NumPy *inside* the
scope (device f64 arrays must never escape ``enable_x64()`` — later jnp ops
outside the scope would silently downcast them) and checked finite, raising
``ResourceSolveError`` naming the offending clients otherwise.

Per-client early exits in the scalar algorithm (straggler breaks, frequency
fallback, SCA convergence) become lane masks; iteration counts are the
static ``NetworkConfig.outer_iters`` / ``sca_iters``, so the whole
alternating solve — all five initial power points of Algorithm 1's sweep —
jits to one XLA program per (network configuration, backend).

Channel sampling is vectorized too, and ``np.random.Generator`` draws are
stream-equivalent between one size-U array draw and U sequential scalar
draws, so ``sample_channels`` reproduces the loop path's channels exactly
for the same generator state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import lru_cache
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.resource import (_J_SLACK, _P_SLACK, FPP, ClientSystem,
                                 NetworkConfig, pathloss_linear)

_LN2 = float(np.log(2.0))

RESOURCE_BACKENDS = ("x64", "f32")


class ResourceSolveError(RuntimeError):
    """The batched solve produced non-finite kappa/f/p on feasible lanes
    (f32 knife-edge regime — see the f32 notes in the module docstring)."""


@dataclass
class ClientSystemBatch:
    """Column-stacked ``ClientSystem``: every field an (U,) float64 array."""
    c: np.ndarray
    s: np.ndarray
    f_max: np.ndarray
    p_max: np.ndarray
    e_bd: np.ndarray
    distance: np.ndarray

    def __len__(self) -> int:
        return self.c.shape[0]


def stack_clients(clients: Sequence[ClientSystem]) -> ClientSystemBatch:
    """Stack a ``make_clients`` population into (U,) field arrays."""
    cols = {f.name: np.array([getattr(cl, f.name) for cl in clients],
                             np.float64)
            for f in dataclasses.fields(ClientSystem)}
    return ClientSystemBatch(**cols)


@dataclass
class ChannelBatch:
    """Per-round wireless channels for the whole cohort: (U,) arrays."""
    xi: np.ndarray
    gamma: np.ndarray


def sample_channels(rng: np.random.Generator, sysb: ClientSystemBatch,
                    shadow_sigma_db: float = 8.0) -> ChannelBatch:
    """Whole-cohort ``resource.sample_channel``: one array draw, same stream
    as U sequential scalar draws from the same generator state."""
    gamma = 10 ** (rng.normal(0.0, shadow_sigma_db, size=len(sysb)) / 10)
    return ChannelBatch(xi=pathloss_linear(sysb.distance), gamma=gamma)


@dataclass
class ResourceDecisionBatch:
    """Column-stacked ``ResourceDecision``; ``kappa`` is 0 for stragglers."""
    kappa: np.ndarray       # (U,) int64
    f: np.ndarray           # (U,) float64
    p: np.ndarray           # (U,) float64
    feasible: np.ndarray    # (U,) bool
    t_total: np.ndarray     # (U,) float64
    e_total: np.ndarray     # (U,) float64


def make_solver_core(net: NetworkConfig, backend: str = "x64"):
    """The all-clients solve as a pure (un-jitted) function.

    Maps (c, s, f_max, p_max, e_bd, xi, gamma, n_params) — all (U,) arrays
    of the backend's dtype except the scalar payload — to the six decision
    columns. Every formula mirrors the scalar module line-for-line; only the
    control flow changes (breaks -> lane masks, init-point loop -> vmap),
    and on the f32 backend the minimum-power step runs in the log domain
    (the lone f32-overflowing term — see the module docstring). The x64
    variant must be traced under ``enable_x64``; ``core/round_fused.py``
    inlines either variant into the one-dispatch round program.
    """
    if backend not in RESOURCE_BACKENDS:
        raise ValueError(f"unknown resource backend {backend!r} "
                         f"(expected one of {RESOURCE_BACKENDS})")
    log_domain = backend == "f32"
    noise = net.noise_power
    fracs = np.array([1.0, 0.1, 0.01, 1e-3, 1e-4])
    ks = np.arange(1.0, net.kappa_max + 1)          # (K,) candidate kappas

    def solve(c, s, f_max, p_max, e_bd, xi, gamma, n_params):
        xg = xi * gamma
        cc = net.n * net.nbar * c * s               # cycles per local round
        nb = n_params * (FPP + 1)                   # upload payload (bits)
        g = xg / noise                              # SNR slope: snr = g*p

        def rate(p):
            return net.omega * jnp.log2(1.0 + xg * p / noise)

        def t_up(p):
            return nb / jnp.maximum(rate(p), 1e-12)

        def e_up(p):
            return t_up(p) * p

        def opt_kappa(f, p):
            """Lemma 1 (eq. 42)."""
            j1 = (e_bd - e_up(p)) / (0.5 * net.v * cc * f ** 2)
            j2 = f * (net.t_th - t_up(p)) / cc
            k = jnp.minimum(float(net.kappa_max),
                            jnp.floor(jnp.minimum(j1, j2) + _J_SLACK))
            return jnp.maximum(k, 0.0)

        def opt_freq(kappa, p):
            """Lemma 2 (eq. 48); inf where upload alone exceeds deadline."""
            r = rate(p)
            denom = net.t_th * r - nb
            val = cc * kappa * r / jnp.where(denom > 0, denom, 1.0)
            return jnp.where(denom > 0, val, jnp.inf)

        def min_power(t_left, valid):
            """(52c)/(11c): smallest p meeting the deadline at (kappa, f).

            The direct form 2^(Nb/(omega*t_left)) - 1 overflows f32 for
            tight deadlines; the log-domain form compares log p_lo against
            log p_max and only exponentiates the clipped value, so the f32
            backend never materializes the overflow."""
            t_safe = jnp.where(valid, t_left, 1.0)
            if not log_domain:
                snr_min = 2.0 ** (nb / (net.omega * t_safe)) - 1.0
                p_lo = snr_min / g
                valid &= p_lo <= p_max * (1 + _P_SLACK)
                return jnp.where(valid, jnp.minimum(p_lo, p_max), 1e-6), valid
            a = nb * _LN2 / (net.omega * t_safe)    # log(1 + snr_min)
            # log(expm1(a)): exact small-a form, overflow-free large-a form
            log_snr = jnp.where(a > 10.0,
                                a + jnp.log1p(-jnp.exp(-jnp.maximum(a, 10.0))),
                                jnp.log(jnp.expm1(jnp.minimum(a, 10.0))))
            log_p_lo = log_snr - jnp.log(g)
            log_cap = jnp.log(p_max)
            valid &= log_p_lo <= log_cap + jnp.log1p(_P_SLACK)
            p_lo = jnp.exp(jnp.minimum(log_p_lo, log_cap))
            return jnp.where(valid, p_lo, 1e-6), valid

        def sca_power(kappa, f, p0):
            """SCA (eqs. 50-52) with convergence/abort masks per lane."""
            e_cp = 0.5 * net.v * cc * kappa * f ** 2
            t_cp = cc * kappa / f
            t_left = net.t_th - t_cp
            valid = t_left > 0
            p_lo, valid = min_power(t_left, valid)
            p = jnp.maximum(jnp.maximum(jnp.minimum(p0, p_max), p_lo), 1e-6)
            done = jnp.zeros(valid.shape, bool)
            for _ in range(net.sca_iters):
                act = valid & ~done
                ln = jnp.log1p(g * p)
                obj_slope = (net.omega / _LN2) * (g / (p * (1 + g * p))
                                                  - ln / p ** 2)
                e_at = nb * _LN2 / net.omega * (p / ln)
                e_slope = nb * _LN2 / net.omega * (1 / ln - g * p /
                                                   (ln ** 2 * (1 + g * p)))
                pos = e_slope > 0
                p_hi = jnp.where(
                    pos,
                    jnp.minimum(p_max, p + (e_bd - e_cp - e_at)
                                / jnp.where(pos, e_slope, 1.0)),
                    p_max)
                bad = p_hi < p_lo - 1e-12
                valid &= ~(act & bad)
                act &= ~bad
                p_new = jnp.clip(jnp.where(obj_slope >= 0, p_hi, p_lo),
                                 p_lo, p_max)
                conv = jnp.abs(p_new - p) < net.tol
                p = jnp.where(act, jnp.where(conv, p_new,
                                             0.5 * (p + p_new)), p)
                done |= act & conv
            ok = valid & (e_up(p) + e_cp <= e_bd * (1 + 1e-6)) \
                & (t_cp + t_up(p) <= net.t_th * (1 + 1e-6))
            return p, ok

        def from_point(p0):
            """Masked ``resource._optimize_from`` over all lanes at once."""
            f, p = f_max, p0
            alive = jnp.ones(p0.shape, bool)
            rk = jnp.zeros_like(p0)
            rf, rp = f, p
            rfeas = jnp.zeros(p0.shape, bool)
            rt = jnp.zeros_like(p0)
            re_ = jnp.zeros_like(p0)
            for _ in range(net.outer_iters):
                kappa = opt_kappa(f, p)
                alive &= kappa >= 1
                f_new = opt_freq(kappa, p)
                good = jnp.isfinite(f_new) & (f_new <= f_max)
                # deadline infeasible at kappa: largest k2 < kappa that fits
                f_all = opt_freq(ks[:, None], p[None, :])        # (K, U)
                ok_all = jnp.isfinite(f_all) & (f_all <= f_max[None, :])
                cand = ok_all & (ks[:, None] <= (kappa - 1)[None, :])
                k2 = jnp.max(jnp.where(cand, ks[:, None], 0.0), axis=0)
                f_k2 = jnp.sum(jnp.where(ks[:, None] == k2[None, :],
                                         f_all, 0.0), axis=0)
                kappa = jnp.where(good, kappa, k2)
                f_new = jnp.where(good, f_new, f_k2)
                alive &= good | (k2 >= 1)
                f = jnp.where(alive, jnp.clip(f_new, 1e6, f_max), f)
                p_sca, sca_ok = sca_power(kappa, f, p)
                alive &= sca_ok
                p = jnp.where(alive, p_sca, p)
                t_tot = cc * kappa / f + t_up(p)
                e_tot = 0.5 * net.v * cc * kappa * f ** 2 + e_up(p)
                okc = alive & (t_tot <= net.t_th * (1 + 1e-6)) \
                    & (e_tot <= e_bd * (1 + 1e-6))
                rk = jnp.where(okc, kappa, rk)
                rf = jnp.where(okc, f, rf)
                rp = jnp.where(okc, p, rp)
                rt = jnp.where(okc, t_tot, rt)
                re_ = jnp.where(okc, e_tot, re_)
                rfeas |= okc
            return rk, rf, rp, rfeas, rt, re_

        # Algorithm 1's sweep over initial power points: all five at once
        sk, sf, sp, sfeas, st_, se = jax.vmap(from_point)(
            p_max[None, :] * fracs[:, None])
        bk = jnp.zeros_like(c)
        bf, bp = f_max, p_max
        bfeas = jnp.zeros(c.shape, bool)
        bt = jnp.zeros_like(c)
        be = jnp.zeros_like(c)
        for i in range(len(fracs)):                 # keep the scalar order
            better = sfeas[i] & (~bfeas | (sk[i] > bk))
            bk = jnp.where(better, sk[i], bk)
            bf = jnp.where(better, sf[i], bf)
            bp = jnp.where(better, sp[i], bp)
            bt = jnp.where(better, st_[i], bt)
            be = jnp.where(better, se[i], be)
            bfeas |= sfeas[i]
        return bk, bf, bp, bfeas, bt, be

    return solve


@lru_cache(maxsize=8)
def _make_solver(net_fields: tuple, backend: str):
    """Jitted-and-cached ``make_solver_core`` per (NetworkConfig, backend)."""
    return jax.jit(make_solver_core(NetworkConfig(*net_fields), backend))


def _check_finite(kappa, f, p, feas, backend: str) -> None:
    """Feasible lanes must carry finite decisions; the f32 backend can lose
    them at the ``_J_SLACK``/``_P_SLACK`` knife edges (documented contract:
    raise, never hand non-finite kappa/f/p to the round loop)."""
    bad = feas & ~(np.isfinite(kappa) & np.isfinite(f) & np.isfinite(p))
    if bad.any():
        lanes = np.flatnonzero(bad)[:8]
        raise ResourceSolveError(
            f"resource solve ({backend} backend) produced non-finite "
            f"kappa/f/p on {int(bad.sum())} feasible client(s) "
            f"(first lanes {lanes.tolist()}: "
            f"kappa={kappa[lanes].tolist()}, f={f[lanes].tolist()}, "
            f"p={p[lanes].tolist()}); for tight-deadline/knife-edge "
            "configurations run resource_backend='x64'")


def optimize_clients_batched(net: NetworkConfig, sysb: ClientSystemBatch,
                             ch: ChannelBatch, n_params: int,
                             backend: str = "x64") -> ResourceDecisionBatch:
    """All-clients ``resource.optimize_client``: one jitted solve.

    ``backend="x64"`` (default) is the scalar-parity oracle under scoped
    ``enable_x64``; ``backend="f32"`` is the accelerator-native log-domain
    solve. Either way the returned columns are **host NumPy float64/int64**:
    the x64 scope boundary materializes every output inside the scope so no
    f64 device array escapes it (escaped arrays silently downcast on the
    next op once the scope closes)."""
    if backend not in RESOURCE_BACKENDS:
        raise ValueError(f"unknown resource backend {backend!r} "
                         f"(expected one of {RESOURCE_BACKENDS})")
    solver = _make_solver(dataclasses.astuple(net), backend)
    cols = (sysb.c, sysb.s, sysb.f_max, sysb.p_max, sysb.e_bd,
            ch.xi, ch.gamma)
    if backend == "x64":
        with enable_x64():
            out = solver(*[jnp.asarray(a, jnp.float64) for a in cols],
                         jnp.float64(n_params))
            # scope boundary: host-materialize before the scope closes
            out = [np.asarray(o) for o in out]
            assert all(isinstance(o, np.ndarray) for o in out)
            assert all(o.dtype == np.float64 for o in out[:3]), \
                "x64 solve returned non-f64 decision columns"
    else:
        out = solver(*[jnp.asarray(a, jnp.float32) for a in cols],
                     jnp.float32(n_params))
        out = [np.asarray(o) for o in out]
    kappa, f, p, feas, t, e = out
    feas = feas.astype(bool)
    _check_finite(kappa, f, p, feas, backend)
    return ResourceDecisionBatch(kappa=kappa.astype(np.int64),
                                 f=f.astype(np.float64),
                                 p=p.astype(np.float64),
                                 feasible=feas,
                                 t_total=t.astype(np.float64),
                                 e_total=e.astype(np.float64))


def optimize_round_batched(rng: np.random.Generator, net: NetworkConfig,
                           sysb: ClientSystemBatch, n_params: int,
                           backend: str = "x64") -> ResourceDecisionBatch:
    """One FL round: vectorized channel sampling + the batched solve (5)."""
    return optimize_clients_batched(net, sysb, sample_channels(rng, sysb),
                                    n_params, backend=backend)
