"""Sparse-cohort server state: a fixed-capacity active-slot pool in front of
the stacked servers (DESIGN.md "Sparse cohorts").

The paper's server only ever *computes* on the round-active cohort — scores,
staleness and contributions of inactive clients are carried, not touched
(Algorithm 2 writes back active rows and refreshes never-participated ones;
the partial-participation analysis in Dinh et al., 1910.13067, renormalizes
the aggregation weights over the sampled cohort). The dense engines still
materialize a ``(U, N)`` contribution buffer and ``(U, D, ...)`` datasets for
every *registered* user, which caps U at a few hundred on one host. This
module decouples the two scales:

  * ``SlotPool`` — a host-side bijection between resident user ids and the
    ``C`` pool slots (``user_slot``/``slot_user`` int32 maps, FIFO eviction
    clocks). All round-dense state (contribution rows, FIFO datasets, the
    local-SGD vmap) is slot-indexed and sized ``C``.
  * ``CohortTables`` — persistent per-user ``(U,)`` tables (scores, the
    stale-score carry, staleness/participation flags) with **explicit**
    ``NamedSharding`` over the mesh's ``('pod','data')`` client axes
    (``shmap.client_sharding``), not auto-SPMD propagation: the tables are
    the only O(U) device state left, and their layout must be pinned so
    gather/scatter against them stays a local row op per shard.
  * ``SparseCohortServer`` — the engine: a width-``C`` *inner* stacked server
    (the unchanged ``StackedOSAFLServer``/``Stacked*`` classes) behind the
    pool. Per round the inner server runs the identical jitted round body on
    ``(C, N)`` slot buffers and the results are scattered back into the
    per-user tables; at admission the carried per-user state is gathered
    into the slot and the slot's contribution row is reset to the
    algorithm's refresh value (``init_row``) — slot-resident contributions
    and datasets are *lost* on eviction, by design.

Dense parity is the correctness anchor: with ``cohort_size = U`` the pool is
the identity map, the inner server *is* the dense stacked server (same
width, same uniform ``alphas``), and the harness consumes the host RNG in
exactly the dense order — so trajectories are bit-exact against the dense
engines for every algorithm (tests/test_cohort.py). With C < U the inner
width-C aggregation renormalizes weights over the sampled cohort
automatically (uniform ``1/C`` slots; FedNova/FedDisco size/histogram
weights over cohort rows), which is precisely the Dinh et al. partial-
participation rule.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.baselines import STACKED_SERVERS
from repro.core.osafl import StackedOSAFLServer
from repro.core.shmap import client_rows, client_sharding


def sample_participants(rng: np.random.Generator, num_users: int, m: int,
                        weights: Optional[np.ndarray] = None,
                        available: Optional[np.ndarray] = None) -> np.ndarray:
    """Sample the round-active participant set (sorted user ids).

    With neither ``weights`` nor ``available`` this is exactly
    ``np.sort(rng.choice(U, size=m, replace=False))`` — the historical
    host-RNG consumption the dense-parity and null-scenario anchors rest on.
    The scenario layer biases it: ``weights`` (U,) are relative sampling
    weights (Pareto-biased selection), ``available`` (U,) masks departed
    users out entirely (churn); when fewer than ``m`` users remain the
    sample shrinks to the available count (possibly empty — a round where
    everyone is away trains nobody)."""
    if weights is None and available is None:
        return np.sort(rng.choice(num_users, size=m, replace=False))
    w = (np.ones(num_users, np.float64) if weights is None
         else np.asarray(weights, np.float64).copy())
    if w.shape != (num_users,):
        raise ValueError(
            f"selection weights must have shape ({num_users},), "
            f"got {w.shape}")
    if (w < 0).any():
        # a negative weight would silently renormalize into a *valid*
        # probability against a negative sum — reject it loudly
        raise ValueError("selection weights must be non-negative")
    if available is not None:
        w[~np.asarray(available, bool)] = 0.0
    eligible = int(np.count_nonzero(w))
    m = min(int(m), eligible)
    if m == 0:
        return np.empty(0, np.int64)
    return np.sort(rng.choice(num_users, size=m, replace=False,
                              p=w / w.sum()))


class AdmitResult(NamedTuple):
    """Outcome of ``SlotPool.admit``: per requested user its slot, whether
    the user was newly seated this call (slot state must be initialized),
    and which previously-resident users were evicted to make room."""
    slots: np.ndarray       # (k,) int32, aligned with the admitted users
    newly: np.ndarray       # (k,) bool — True where the user was not resident
    evicted: np.ndarray     # (m,) int32 user ids displaced by this call


class SlotPool:
    """Host-side user↔slot bijection with FIFO eviction.

    ``user_slot`` (U,) maps registered user -> slot (-1 = not resident);
    ``slot_user`` (C,) maps slot -> user (-1 = free). Two monotonic int64
    clock tables drive the FIFO policy and make the whole pool a plain dict
    of arrays for RunState snapshots: ``admit_seq[s]`` is the tick slot s's
    resident was seated (-1 = free) and ``free_seq[s]`` the tick it was
    freed (-1 = occupied; fresh slots are pre-freed in index order so
    initial admissions fill 0..C-1 left to right — at C = U that makes the
    pool the identity map, the dense-parity anchor). Eviction takes the
    oldest-seated resident not being admitted in the same call; freed slots
    are reused oldest-freed first. The clocks never wrap (int64), but slot
    *reuse* cycles through the pool indefinitely — the wrap-around the
    property tests exercise."""

    def __init__(self, num_users: int, capacity: int):
        if not 1 <= capacity <= num_users:
            raise ValueError(
                f"slot-pool capacity must satisfy 1 <= C <= U "
                f"(got C={capacity}, U={num_users})")
        self.U = int(num_users)
        self.C = int(capacity)
        self.user_slot = np.full(self.U, -1, np.int32)
        self.slot_user = np.full(self.C, -1, np.int32)
        self.admit_seq = np.full(self.C, -1, np.int64)
        self.free_seq = np.arange(self.C, dtype=np.int64)
        self._clock = self.C

    @property
    def cohort(self) -> np.ndarray:
        """(C,) slot -> user id view (-1 = free slot)."""
        return self.slot_user.copy()

    @property
    def occupancy(self) -> int:
        return int((self.slot_user >= 0).sum())

    def resident(self, users) -> np.ndarray:
        return self.user_slot[np.asarray(users, np.int64)] >= 0

    def admit(self, users) -> AdmitResult:
        users = np.asarray(users, np.int64).ravel()
        if users.size:
            if users.min() < 0 or users.max() >= self.U:
                raise ValueError(
                    f"user ids must be in [0, {self.U}); got range "
                    f"[{users.min()}, {users.max()}]")
            if np.unique(users).size != users.size:
                raise ValueError("duplicate user ids in one admit() call")
        if users.size > self.C:
            raise ValueError(
                f"cannot admit {users.size} users into {self.C} slots")
        protected = set(users.tolist())
        slots = np.empty(users.size, np.int32)
        newly = np.zeros(users.size, bool)
        evicted = []
        for i, u in enumerate(users.tolist()):
            s = int(self.user_slot[u])
            if s < 0:
                free = np.flatnonzero(self.free_seq >= 0)
                if free.size:
                    s = int(free[np.argmin(self.free_seq[free])])
                else:
                    occ = [int(c) for c in np.flatnonzero(self.admit_seq >= 0)
                           if int(self.slot_user[c]) not in protected]
                    s = min(occ, key=lambda c: self.admit_seq[c])
                    ev = int(self.slot_user[s])
                    self.user_slot[ev] = -1
                    evicted.append(ev)
                self.slot_user[s] = u
                self.user_slot[u] = s
                self.admit_seq[s] = self._clock
                self.free_seq[s] = -1
                self._clock += 1
                newly[i] = True
            slots[i] = s
        return AdmitResult(slots=slots, newly=newly,
                           evicted=np.asarray(evicted, np.int32))

    def evict(self, users) -> np.ndarray:
        """Explicitly free the given users' slots (non-residents are
        ignored). Returns the freed slot indices."""
        freed = []
        for u in np.asarray(users, np.int64).ravel().tolist():
            s = int(self.user_slot[u])
            if s < 0:
                continue
            self.user_slot[u] = -1
            self.slot_user[s] = -1
            self.admit_seq[s] = -1
            self.free_seq[s] = self._clock
            self._clock += 1
            freed.append(s)
        return np.asarray(freed, np.int32)

    def check(self) -> None:
        """Raise ``ValueError`` unless the pool invariants hold: the two
        maps are a bijection on residents (no aliasing, no leaked slots) and
        the clock tables mark exactly the occupied/free slots."""
        occ = np.flatnonzero(self.slot_user >= 0)
        res = np.flatnonzero(self.user_slot >= 0)
        if occ.size != res.size:
            raise ValueError(
                f"slot pool leak: {occ.size} occupied slots vs "
                f"{res.size} resident users")
        for s in occ.tolist():
            u = int(self.slot_user[s])
            if int(self.user_slot[u]) != s:
                raise ValueError(
                    f"slot aliasing: slot {s} holds user {u} but "
                    f"user_slot[{u}] = {int(self.user_slot[u])}")
        if ((self.admit_seq >= 0) != (self.slot_user >= 0)).any():
            raise ValueError("admit_seq marks do not match occupied slots")
        if ((self.free_seq >= 0) != (self.slot_user < 0)).any():
            raise ValueError("free_seq marks do not match free slots")
        live = np.concatenate([self.admit_seq[self.admit_seq >= 0],
                               self.free_seq[self.free_seq >= 0]])
        if live.size and live.max(initial=-1) >= self._clock:
            raise ValueError("clock table entry ahead of the pool clock")

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        return {"user_slot": self.user_slot.copy(),
                "slot_user": self.slot_user.copy(),
                "admit_seq": self.admit_seq.copy(),
                "free_seq": self.free_seq.copy(),
                "clock": np.int64(self._clock)}

    def load_state_dict(self, sd: dict) -> None:
        from repro.checkpoint.run_state import validate_cohort_shapes
        validate_cohort_shapes(sd, self.U, self.C)
        self.user_slot = np.asarray(sd["user_slot"], np.int32).copy()
        self.slot_user = np.asarray(sd["slot_user"], np.int32).copy()
        self.admit_seq = np.asarray(sd["admit_seq"], np.int64).copy()
        self.free_seq = np.asarray(sd["free_seq"], np.int64).copy()
        self._clock = int(sd["clock"])
        self.check()


class CohortTables:
    """Persistent per-user ``(U,)``-leading tables under explicit
    ``NamedSharding`` over the mesh's client axes (``client_sharding``).
    Without a mesh the tables are plain device arrays. Gather pulls cohort
    rows into ``(C,)`` slot vectors; scatter writes slot results back."""

    def __init__(self, num_users: int, tables: dict, mesh=None):
        self.U = int(num_users)
        self.mesh = mesh
        if mesh is not None and self.U % client_rows(mesh):
            raise ValueError(
                f"user-table length {self.U} is not divisible by the mesh's "
                f"{client_rows(mesh)} client rows")
        self._tables = {k: self._put(jnp.asarray(v))
                        for k, v in tables.items()}

    def _put(self, arr):
        if self.mesh is None:
            return arr
        return jax.device_put(arr, client_sharding(self.mesh, arr.ndim))

    def keys(self):
        return self._tables.keys()

    def __getitem__(self, k):
        return self._tables[k]

    def gather(self, users) -> dict:
        idx = jnp.asarray(np.asarray(users, np.int64))
        return {k: jnp.take(v, idx, axis=0) for k, v in self._tables.items()}

    def scatter(self, users, values: dict) -> None:
        idx = jnp.asarray(np.asarray(users, np.int64))
        for k, val in values.items():
            self._tables[k] = self._put(
                self._tables[k].at[idx].set(jnp.asarray(val)))

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        # live device arrays, NamedSharding intact: the v2 checkpoint writer
        # pulls them per addressable shard (no host gather on the round
        # loop); load_state_dict re-applies client_sharding on restore
        return dict(self._tables)

    def load_state_dict(self, sd: dict) -> None:
        from repro.checkpoint.run_state import CheckpointError
        missing = sorted(set(self._tables) - set(sd))
        if missing:
            raise CheckpointError(
                "cohort-table snapshot is missing keys: "
                + ", ".join(missing))
        for k, cur in self._tables.items():
            got = np.asarray(sd[k])
            if tuple(got.shape) != tuple(cur.shape):
                raise CheckpointError(
                    f"cohort table {k!r} has snapshot shape "
                    f"{tuple(got.shape)}; the live run expects "
                    f"{tuple(cur.shape)}")
            self._tables[k] = self._put(jnp.asarray(got))


class SparseCohortServer:
    """The sparse-cohort engine: ``SlotPool`` + ``CohortTables`` wrapped
    around an unchanged width-C stacked server (see module docstring).

    Drop-in for the stacked servers in the harness: ``round_stacked``
    forwards to the inner server (whose round consumes ``(C, N)`` updates
    and a ``(C,)`` active mask, both *slot*-indexed) and then scatters the
    per-slot results back into the per-user tables, so eviction needs no
    extra write — an evicted slot's carry is already in the tables."""

    def __init__(self, params, fl: FLConfig, num_users: int, seed: int = 0,
                 mesh=None, capacity: Optional[int] = None):
        capacity = int(fl.cohort_size if capacity is None else capacity)
        if not 1 <= capacity <= num_users:
            raise ValueError(
                f"cohort_size must satisfy 1 <= C <= num_clients "
                f"(got C={capacity}, num_clients={num_users})")
        self.fl = fl
        self.U = int(num_users)
        self.C = capacity
        self.K = int(fl.num_clusters)
        self.is_osafl = fl.algorithm == "osafl"
        inner_fl = dataclasses.replace(fl, num_clients=capacity,
                                       cohort_size=0, participation=1.0)
        if self.K >= 1:
            # hierarchical: K per-cluster slot blocks in front of the
            # two-tier inner servers (core/hierarchy.py). inner_fl keeps
            # fl.num_clusters, so the width-C inner round body splits its
            # buffer into the same K blocks the pool keeps contiguous.
            from repro.core.hierarchy import (ClusterSlotPool,
                                              contiguous_clusters,
                                              make_hier_server)
            self.assign = contiguous_clusters(self.U, self.K)
            if capacity % self.K:
                raise ValueError(
                    f"num_clusters must divide cohort_size "
                    f"(got K={self.K}, C={capacity})")
            self.inner = make_hier_server(params, inner_fl, capacity,
                                          seed=seed)
            self.pool = ClusterSlotPool(self.U, capacity, self.assign,
                                        self.K)
        elif self.is_osafl:
            self.assign = None
            self.inner = StackedOSAFLServer(params, inner_fl, capacity,
                                            seed=seed)
            self.pool = SlotPool(num_users, capacity)
        elif fl.algorithm in STACKED_SERVERS:
            self.assign = None
            self.inner = STACKED_SERVERS[fl.algorithm](params, inner_fl,
                                                       capacity, seed=seed)
            self.pool = SlotPool(num_users, capacity)
        else:
            raise ValueError(f"unknown algorithm {fl.algorithm!r}")
        tables = {"participated": np.zeros(self.U, bool)}
        if self.is_osafl:
            tables["scores"] = np.ones(self.U, np.float32)
            tables["lam_prev"] = np.ones(self.U, np.float32)
        self.tables = CohortTables(self.U, tables, mesh=mesh)
        if not self.is_osafl:
            # sticky per-user metadata (loop "last seen update" semantics),
            # host-side like the inner servers' own copies
            self.sizes = np.ones(self.U)
            self.kappas = np.ones(self.U)
            self.hists: Optional[np.ndarray] = None
            self.has_hist = np.zeros(self.U, bool)

    # -- delegated views -----------------------------------------------------
    @property
    def params(self):
        return self.inner.params

    @property
    def w(self):
        return self.inner.w

    @property
    def codec(self):
        return self.inner.codec

    @property
    def alphas(self):
        return self.inner.alphas

    @property
    def cohort(self) -> np.ndarray:
        """(C,) slot -> user map of the current residents."""
        return self.pool.cohort

    @property
    def last_scores(self) -> np.ndarray:
        """Per-*user* (U,) score view (OSAFL): the carried score table."""
        if not self.is_osafl:
            raise AttributeError("last_scores is OSAFL-only")
        return np.asarray(self.tables["scores"])

    # -- admission -----------------------------------------------------------
    def initial_residents(self) -> np.ndarray:
        """The users seated before round 0: the first ``C`` ids on the flat
        pool; under hierarchy the first ``C/K`` members of *each* cluster, so
        every cluster block starts full. With the contiguous static map at
        K=1 both are exactly ``arange(C)`` — the parity anchor."""
        if self.K < 1:
            return np.arange(self.C, dtype=np.int64)
        B = self.C // self.K
        return np.concatenate([
            np.flatnonzero(self.assign == k)[:B] for k in range(self.K)])

    def apply_cluster_moves(self, users, dest):
        """Scenario-driven membership churn: move ``users`` to clusters
        ``dest``. Residents among the movers are evicted from their old
        block and immediately re-seated in the destination block (FIFO-
        evicting there as needed) — their carried tables follow them via the
        normal ``admit`` gather, but slot-resident contribution rows and FIFO
        datasets reset (edge migration does not move data between edge
        servers). Returns ``(moved_resident_users, AdmitResult)``; the
        caller must reset the same slots in its slot-indexed dataset buffer,
        exactly as after any admission."""
        if self.K < 1:
            raise ValueError(
                "cluster moves require a hierarchical run (num_clusters>=1)")
        users = np.asarray(users, np.int64).ravel()
        dest = np.asarray(dest, np.int64).ravel()
        if users.size:
            # a user named twice takes the LAST destination (scenario
            # composition order = sequential application)
            _, first_rev = np.unique(users[::-1], return_index=True)
            keep = np.sort(users.size - 1 - first_rev)
            users, dest = users[keep], dest[keep]
        moved = self.pool.reassign(users, dest)
        if moved.size == 0:
            return moved, None
        return moved, self.admit(moved)

    def admit(self, users) -> AdmitResult:
        """Seat ``users`` in the pool (FIFO-evicting as needed) and load each
        newly seated slot: carried per-user state is gathered from the
        tables, the contribution row is reset to the algorithm's refresh
        value (``init_row``) — the evicted resident's row is lost, which is
        the documented eviction semantics. The caller owns the slot-indexed
        *dataset* buffer and must reset the same slots
        (``StackedOnlineBuffer.reset_rows``)."""
        res = self.pool.admit(users)
        ns = res.slots[res.newly]
        if ns.size == 0:
            return res
        nu = np.asarray(users, np.int64).ravel()[res.newly]
        g = self.tables.gather(nu)
        idx = jnp.asarray(ns)
        row = self.inner.init_row()
        if self.is_osafl:
            self.inner.d_buffer = self.inner.d_buffer.at[idx].set(row)
            self.inner.participated = self.inner.participated.at[idx].set(
                g["participated"])
            self.inner._lam_prev = self.inner._lam_prev.at[idx].set(
                g["lam_prev"])
            ls = np.array(self.inner.last_scores)
            ls[ns] = np.asarray(g["scores"])
            self.inner.last_scores = ls
        else:
            self.inner.buffer = self.inner.buffer.at[idx].set(row)
            self.inner.participated[ns] = np.asarray(g["participated"])
            self.inner.sizes[ns] = self.sizes[nu]
            self.inner.kappas[ns] = self.kappas[nu]
            if self.hists is not None:
                if self.inner.hists is None:
                    self.inner.hists = np.zeros((self.C,
                                                 self.hists.shape[1]))
                self.inner.hists[ns] = self.hists[nu]
            self.inner.has_hist[ns] = self.has_hist[nu]
        return res

    # -- the round -----------------------------------------------------------
    def round_stacked(self, d_new, active, **meta):
        """Slot-indexed round: ``d_new`` (C, N), ``active`` (C,) plus the
        algorithm's metadata kwargs, all in slot order. Runs the inner
        stacked round unchanged, then scatters per-slot results back into
        the per-user carry tables."""
        out = self.inner.round_stacked(d_new, active, **meta)
        self._write_back()
        return out

    def _write_back(self) -> None:
        cohort = self.pool.slot_user
        vs = np.flatnonzero(cohort >= 0)
        if vs.size == 0:
            return
        cu = cohort[vs]
        idx = jnp.asarray(vs)
        if self.is_osafl:
            self.tables.scatter(cu, {
                "participated": jnp.take(self.inner.participated, idx),
                "scores": jnp.take(
                    jnp.asarray(self.inner.last_scores, jnp.float32), idx),
                "lam_prev": jnp.take(self.inner._lam_prev, idx)})
        else:
            self.tables.scatter(cu, {
                "participated": jnp.asarray(self.inner.participated)[idx]})
            self.sizes[cu] = self.inner.sizes[vs]
            self.kappas[cu] = self.inner.kappas[vs]
            if self.inner.hists is not None:
                if self.hists is None:
                    self.hists = np.zeros((self.U,
                                           self.inner.hists.shape[1]))
                self.hists[cu] = self.inner.hists[vs]
            self.has_hist[cu] = self.inner.has_hist[vs]

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Snapshot = the width-C inner server (slot-resident state), the
        slot map, and the per-user carry tables — no dense ``(U, N)`` ghost
        is ever materialized."""
        sd = {"inner": self.inner.state_dict(),
              "pool": self.pool.state_dict(),
              "tables": self.tables.state_dict()}
        if not self.is_osafl:
            sd["user_meta"] = {"sizes": self.sizes.copy(),
                               "kappas": self.kappas.copy(),
                               "hists": self.hists,
                               "has_hist": self.has_hist.copy()}
        return sd

    def load_state_dict(self, sd: dict) -> None:
        from repro.checkpoint.run_state import (CheckpointError,
                                                validate_cohort_shapes)
        missing = sorted(k for k in ("inner", "pool", "tables")
                         if k not in sd)
        if missing:
            raise CheckpointError(
                "not a sparse-cohort snapshot (missing "
                + ", ".join(missing)
                + "); dense-engine snapshots cannot restore into a "
                "cohort_size>0 run")
        if self.K >= 1:
            if "pools" not in sd["pool"]:
                raise CheckpointError(
                    "snapshot slot pool is flat (no per-cluster pools); it "
                    "cannot restore into a num_clusters"
                    f"={self.K} hierarchical run")
            # ClusterSlotPool.load_state_dict validates K/assign/sub-pools
        else:
            if "pools" in sd["pool"]:
                raise CheckpointError(
                    "snapshot slot pool is hierarchical (per-cluster "
                    "pools); it cannot restore into a flat "
                    "(num_clusters=0) run")
            validate_cohort_shapes(sd["pool"], self.U, self.C)
        self.pool.load_state_dict(sd["pool"])
        self.inner.load_state_dict(sd["inner"])
        self.tables.load_state_dict(sd["tables"])
        if not self.is_osafl:
            meta = sd["user_meta"]
            self.sizes = np.asarray(meta["sizes"], float).copy()
            self.kappas = np.asarray(meta["kappas"], float).copy()
            self.hists = (None if meta["hists"] is None
                          else np.asarray(meta["hists"], float).copy())
            self.has_hist = np.asarray(meta["has_hist"], bool).copy()
