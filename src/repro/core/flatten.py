"""Ravel/unravel codec between parameter pytrees and flat (N,) vectors.

The stacked-client engine keeps every client's contribution as one row of a
single (U, N) float32 buffer, so the whole server round (write-back, mean,
scores, scored SGD step) is dense linear algebra instead of O(U) Python tree
traversals. This module owns the only place where pytree structure meets the
flat representation: ``make_codec(params)`` freezes the treedef / leaf shapes
/ leaf dtypes of a parameter template and returns jit-traceable ``flatten`` /
``unflatten`` closures plus their vmapped stacked counterparts.

Flat vectors are always float32 (scores and SGD accumulation are f32 in the
loop engine too — see core/scores.py); ``unflatten`` casts each leaf back to
its template dtype.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class FlatCodec:
    """Bijection between one pytree layout and flat f32 vectors of length n."""
    n: int
    treedef: object
    shapes: Tuple[tuple, ...]
    dtypes: Tuple[object, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]

    def flatten(self, tree) -> jnp.ndarray:
        """Pytree (matching the template treedef) -> (n,) float32."""
        leaves = jax.tree.leaves(tree)
        return jnp.concatenate(
            [jnp.ravel(l).astype(jnp.float32) for l in leaves])

    def unflatten(self, vec: jnp.ndarray):
        """(n,) vector -> pytree with the template shapes/dtypes."""
        leaves = [vec[o:o + s].reshape(sh).astype(dt)
                  for o, s, sh, dt in zip(self.offsets, self.sizes,
                                          self.shapes, self.dtypes)]
        return jax.tree.unflatten(self.treedef, leaves)

    def flatten_stacked(self, stacked_tree) -> jnp.ndarray:
        """Pytree whose leaves carry a leading client axis -> (U, n) f32."""
        return jax.vmap(self.flatten)(stacked_tree)

    def unflatten_stacked(self, mat: jnp.ndarray):
        """(U, n) -> pytree with leaves (U, *leaf_shape)."""
        return jax.vmap(self.unflatten)(mat)


def scatter_updates(codec: FlatCodec, updates, num_clients: int):
    """Scatter a sparse list of client updates into a dense (U, n) float32
    matrix + participation mask. Each update needs `.uid` and `.d`, where
    `.d` is either a pytree matching the codec template or an already-flat
    (n,) row. Shared by every stacked server's sparse-round entry point."""
    active = np.zeros(num_clients, bool)
    d_new = np.zeros((num_clients, codec.n), np.float32)
    for up in updates:
        row = (up.d if getattr(up.d, "ndim", None) == 1
               else codec.flatten(up.d))
        d_new[up.uid] = np.asarray(row, np.float32)
        active[up.uid] = True
    return d_new, active


def make_codec(template) -> FlatCodec:
    leaves, treedef = jax.tree.flatten(template)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.asarray(l).dtype for l in leaves)
    sizes = tuple(int(np.prod(s, dtype=np.int64)) if s else 1 for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    return FlatCodec(n=int(sum(sizes)), treedef=treedef, shapes=shapes,
                     dtypes=dtypes, offsets=offsets, sizes=sizes)
