"""One-dispatch device-resident online OSAFL rounds (ROADMAP "One-dispatch
device-resident rounds + accelerator-native precision").

The multi-dispatch engine (``repro.harness.run`` on the stacked engine
with ``round_backend="dispatch"``) executes one online round as ~7 separate
device programs with host work in between: a host-NumPy Binomial arrival
draw, the stacked Gumbel request scan, the FIFO stage + commit scatters, the
scoped-f64 resource solve (host round-trip), host batch-slot sampling, the
vmapped local SGD, the scored server round, and an un-jitted eval. This
module fuses the whole round — and ``rounds_per_dispatch`` consecutive
rounds — into ONE jitted XLA executable:

    segment(carry) = lax.scan(round_body, carry, length=k)

with every per-round random draw moved on device (threefry):

  * arrival counts: Binomial(E_u, p_ac) as E_u summed Bernoulli draws —
    exact Binomial, replacing ``np.random.Generator.binomial``;
  * request samples: the Gumbel-trick scan body
    (``data/video_caching_stacked._draw_block``) at static warmup=0 — the
    engine refuses a cohort whose request windows are still cold
    (``warmup_deficit`` > 0), which the harness's initial fill guarantees
    never happens;
  * channel shadowing: Normal(0, 8 dB) per client;
  * local-SGD batch slots: uniform over each client's live FIFO window
    (the device twin of ``StackedOnlineBuffer.sample_slots``).

Per-round randomness is keyed ``fold_in(base_key, t)`` with t the ABSOLUTE
round index carried through the scan, so segmentation is invisible to the
trajectory: rounds [0, 2k) as one segment, two segments of k, or a resume
from a RunState snapshot at any segment boundary are bit-identical
(tests/test_round_fused.py).

The resource solve inlines ``core/resource_stacked.make_solver_core``,
batched over all (rounds x U) lanes of the segment AHEAD of the scan
(``_solve_segment``): the solve depends only on the per-round keys, never
on the model/buffer carry, and the solver is lane-elementwise (its masks
and init-point sweep never reduce across lanes), so hoisting is bit-exact
per round while keeping the whole segment one executable. Leaving it in
the scan body let XLA:CPU re-fuse the SCA chain into its SGD/aggregation
consumers and cost ~1.6x on the full round at U=256. Backends:

  * ``resource_backend="f32"``: the log-domain SNR reformulation — the whole
    program is f32/int32, compiles without ``enable_x64`` and can run on
    TPU/GPU. Non-finite decisions on feasible lanes (knife-edge configs) are
    flagged per round and surfaced as ``ResourceSolveError`` by the caller
    via ``FusedEngine.check_outputs``.
  * ``resource_backend="x64"``: the segment is traced/AOT-compiled under
    scoped ``enable_x64`` with the solve in f64 — the CPU parity oracle,
    bit-exact against the multi-dispatch engine when both are driven with
    the same device draws (the replay test).

``FusedEngine`` owns one AOT-compiled executable per distinct segment
length (``compiled_text`` exposes its optimized HLO for
``launch/hlo_analysis.dispatch_report``); ``repro/harness/experiments.py`` glues it
to the harness state + RunState checkpoints and ``benchmarks/bench_online.py``
times it and gates the single-dispatch claim.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.configs.base import FLConfig
from repro.core.buffer_stacked import BufState, _commit_impl, _stage_impl
from repro.core.client import make_local_train_body
from repro.core.osafl import make_stacked_round_body
from repro.core.resource import NetworkConfig, pathloss_linear
from repro.core.resource_stacked import (ClientSystemBatch,
                                         RESOURCE_BACKENDS,
                                         ResourceSolveError, make_solver_core)
from repro.data.video_caching_stacked import (StreamConsts, StreamState,
                                              _draw_block, warmup_deficit)
from repro.models.small import small_loss

# decorrelates the fused per-round key chain from every other PRNGKey(seed)
# consumer (model init, the request stream's own 0x726571 lineage)
ROUND_KEY_TAG = 0x0f5afe


def fused_base_key(seed: int) -> jnp.ndarray:
    """Root of the fused engine's per-round threefry chain for a run seed."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), ROUND_KEY_TAG)


def round_keys(base_key, t):
    """(k_arrivals, k_channel, k_slots) for absolute round ``t`` — shared by
    the in-scan round body and the multi-dispatch replay in the parity
    tests, so both paths consume identical device draws."""
    k = jax.random.fold_in(base_key, t)
    k_arr, k_chan, k_slots = jax.random.split(k, 3)
    return k_arr, k_chan, k_slots


def draw_counts(key, p_ac, width: int) -> jnp.ndarray:
    """Exact Binomial(width, p_ac[u]) arrival counts as ``width`` summed
    Bernoulli draws (the device replacement for
    ``data/online.binomial_arrivals_batched``)."""
    u = jax.random.uniform(key, (p_ac.shape[0], width), jnp.float32)
    return jnp.sum(u < p_ac[:, None], axis=1).astype(jnp.int32)


def draw_shadowing_db(key, num_users: int,
                      shadow_sigma_db: float = 8.0) -> jnp.ndarray:
    """Per-client log-normal shadowing draw in dB (the device twin of
    ``resource_stacked.sample_channels``' normal draw)."""
    return jax.random.normal(key, (num_users,), jnp.float32) * shadow_sigma_db


def draw_slots(key, size, head, cap, sample_shape: tuple) -> jnp.ndarray:
    """(U, *sample_shape) storage slots uniform over each client's live FIFO
    window — ``StackedOnlineBuffer.sample_slots`` with the host Generator
    replaced by a threefry uniform (empty buffers fall back to slot head)."""
    U = size.shape[0]
    lead = (U,) + (1,) * len(sample_shape)
    sz = jnp.maximum(size, 1).reshape(lead)
    u = jax.random.uniform(key, (U,) + tuple(sample_shape), jnp.float32)
    j = jnp.minimum(jnp.floor(u * sz).astype(jnp.int32), sz - 1)
    return (head.reshape(lead) + j) % cap.reshape(lead)


class FusedCarry(NamedTuple):
    """Everything one round mutates, as one device pytree: the server state
    (flat weights, (U, N) contribution buffer, participation flags, stale
    score carry), the FIFO buffer, the request-stream Markov state, and the
    absolute round index that keys the per-round randomness."""
    w: jnp.ndarray
    d_buffer: jnp.ndarray
    participated: jnp.ndarray
    lam_prev: jnp.ndarray
    buf: BufState
    stream: StreamState
    t: jnp.ndarray              # () int32 absolute round index


class FusedEngine:
    """Compiles and runs single-dispatch segments of the online OSAFL round.

    Construction takes only core/data-layer objects (no harness types);
    ``repro/harness/experiments.py`` adapts its setup namespace. Restrictions: the
    fused body is the OSAFL scored round over the stacked request stream, so
    ``fl.algorithm`` must be ``"osafl"`` and ``fl.request_backend``
    ``"stacked"``; the FIFO buffer must be unsharded (the segment is one
    single-device program)."""

    def __init__(self, *, fl: FLConfig, codec, model: str,
                 consts: StreamConsts, topk: int, dataset: int,
                 arrivals: int, batch: int, p_ac, sysb: ClientSystemBatch,
                 net: NetworkConfig, n_params: int, test_batch, alphas,
                 sketch_key, seed: int, use_resource_opt: bool = True,
                 resource_backend: str = "f32"):
        if fl.algorithm != "osafl":
            raise ValueError(
                "the fused round implements the OSAFL scored round only "
                f"(got algorithm={fl.algorithm!r}); run other algorithms "
                "with round_backend='dispatch'")
        if fl.request_backend != "stacked":
            raise ValueError(
                "the fused round draws requests with the stacked Gumbel "
                "sampler; set request_backend='stacked' "
                f"(got {fl.request_backend!r})")
        if fl.cohort_size:
            raise ValueError(
                "the fused round is dense-only: its carry bakes slot index "
                "== user id into one static program, which the sparse "
                "slot-pool engine (core/cohort.py) breaks by design; run "
                "cohort_size>0 with round_backend='dispatch' (a slot-"
                "indexed fused carry is a scoped ROADMAP follow-up)")
        if resource_backend not in RESOURCE_BACKENDS:
            raise ValueError(f"unknown resource backend {resource_backend!r} "
                             f"(expected one of {RESOURCE_BACKENDS})")
        self.fl = fl
        self.codec = codec
        self.model = model
        self.consts = consts
        self.topk = int(topk)
        self.dataset = int(dataset)
        self.arrivals = int(arrivals)
        self.batch = int(batch)
        self.use_resource_opt = bool(use_resource_opt)
        self.resource_backend = resource_backend
        self.p_ac = jnp.asarray(p_ac, jnp.float32)
        self.test_batch = jax.tree.map(jnp.asarray, test_batch)
        self.alphas = jnp.asarray(alphas, jnp.float32)
        self.sketch_key = jnp.asarray(sketch_key)
        self.base_key = fused_base_key(seed)
        self.net = net
        self.n_params = int(n_params)
        # the solve's constant columns live in the solve dtype up front so
        # the f32 program never touches f64 and the x64 trace never upcasts
        sdt = np.float64 if resource_backend == "x64" else np.float32
        self._sys_cols = tuple(
            np.asarray(a, sdt)
            for a in (sysb.c, sysb.s, sysb.f_max, sysb.p_max, sysb.e_bd))
        self._xi = np.asarray(pathloss_linear(sysb.distance), sdt)
        self._n_params_c = sdt(n_params)
        self._round_body = self._make_round_body()
        self._compiled_cache: dict = {}

    # -- the fused round -----------------------------------------------------
    def _make_round_body(self):
        fl = self.fl
        codec = self.codec
        model = self.model
        consts, topk, dataset = self.consts, self.topk, self.dataset
        arrivals, batch = self.arrivals, self.batch
        p_ac, alphas, sketch_key = self.p_ac, self.alphas, self.sketch_key
        base_key, test_batch = self.base_key, self.test_batch
        grad_fn = jax.grad(lambda p, b: small_loss(p, b, model)[0])
        one_client = make_local_train_body(grad_fn, fl.local_lr,
                                           fl.kappa_max, prox_mu=0.0)
        local = jax.vmap(one_client, in_axes=(None, 0, 0))
        srv_round = make_stacked_round_body(fl)

        def round_body(carry: FusedCarry, solved):
            kap_t, bad_solve = solved
            t = carry.t
            k_arr, _, k_slots = round_keys(base_key, t)
            # 1. arrivals: on-device Binomial counts + Gumbel-trick samples
            counts = draw_counts(k_arr, p_ac, arrivals)
            stream, xs, ys = _draw_block(consts, carry.stream, counts,
                                         width=arrivals, warmup=0,
                                         dataset=dataset, topk=topk)
            # 2. FIFO commit (the round-boundary scatter)
            buf = _commit_impl(_stage_impl(carry.buf, xs, ys, counts))
            # 3. this round's resource decisions, solved ahead of the scan
            # (_solve_segment) — the solve only depends on the round keys,
            # and keeping its graph out of the scan body stops XLA:CPU from
            # re-fusing the whole SCA chain into the SGD consumers (~1.6x
            # on the full round at U=256)
            kappas = kap_t.astype(jnp.int32)
            active = kappas >= 1
            # 4. masked kappa_u-step local SGD over the whole cohort
            slots = draw_slots(k_slots, buf.size, buf.head, buf.cap,
                               (fl.kappa_max, batch))
            uu = jnp.arange(p_ac.shape[0], dtype=jnp.int32
                            ).reshape(-1, 1, 1)
            batches = {"x": buf.x[uu, slots], "y": buf.y[uu, slots]}
            d, _ = local(codec.unflatten(carry.w), batches, kappas)
            upd = codec.flatten_stacked(d)
            # 5. eq. 19-21 scored aggregation
            w, dbuf, part, lam_use, lam = srv_round(
                carry.w, carry.d_buffer, carry.participated, carry.lam_prev,
                upd, active, alphas, sketch_key)
            # 6. eval (inside the scan: per-round history, still 1 dispatch)
            loss, m = small_loss(codec.unflatten(w), test_batch, model)
            out = {"test_loss": loss.astype(jnp.float32),
                   "test_acc": m["accuracy"].astype(jnp.float32),
                   "participants": jnp.sum(active).astype(jnp.int32),
                   "lam_use": lam_use.astype(jnp.float32),
                   "bad_solve": bad_solve}
            new_carry = FusedCarry(w, dbuf, part, lam, buf, stream,
                                   t + jnp.int32(1))
            return new_carry, out

        return round_body

    def _solve_segment(self, ts):
        """All ``len(ts)`` rounds' channel draws + resource solves, batched
        over (rounds x U) lanes: ``(kappas (k, U) in the solve dtype,
        bad_solve (k,) bool)``. The solve depends only on the per-round keys
        (never on the model/buffer carry), so the segment program runs it
        once ahead of the ``lax.scan`` — inside the same executable, but out
        of the scan body, where XLA:CPU would otherwise re-fuse the SCA
        chain into each of its SGD/aggregation consumers."""
        U = self.p_ac.shape[0]
        sdt = jnp.float64 if self.resource_backend == "x64" else jnp.float32
        if not self.use_resource_opt:
            k = ts.shape[0]
            return (jnp.full((k, U), self.fl.kappa_max, sdt),
                    jnp.zeros((k,), bool))
        base_key = self.base_key
        k_chans = jax.vmap(lambda t: round_keys(base_key, t)[1])(ts)
        gammas = jax.vmap(
            lambda kc: 10.0 ** (draw_shadowing_db(kc, U).astype(sdt)
                                / 10.0))(k_chans)
        k = ts.shape[0]
        solve = make_solver_core(self.net, self.resource_backend)
        tiled = tuple(jnp.tile(jnp.asarray(c), k) for c in self._sys_cols)
        kap, f, p, feas, _, _ = solve(*tiled, jnp.tile(
            jnp.asarray(self._xi), k), gammas.reshape(-1), self._n_params_c)
        bad = feas & ~(jnp.isfinite(kap) & jnp.isfinite(f)
                       & jnp.isfinite(p))
        return kap.reshape(k, U), jnp.any(bad.reshape(k, U), axis=1)

    def _make_segment(self, length: int):
        body = self._round_body
        solve_segment = self._solve_segment

        def segment(carry):
            ts = carry.t + jnp.arange(length, dtype=jnp.int32)
            return jax.lax.scan(body, carry, solve_segment(ts))

        return segment

    def _compiled(self, carry: FusedCarry, length: int):
        if length not in self._compiled_cache:
            seg = jax.jit(self._make_segment(length))
            if self.resource_backend == "x64":
                # scoped-x64 trace: the solve's f64 closure constants stay
                # f64; every carry/draw aval is explicitly typed so the
                # executable's signature is identical to the f32 program's
                with enable_x64():
                    compiled = seg.lower(carry).compile()
            else:
                compiled = seg.lower(carry).compile()
            self._compiled_cache[length] = compiled
        return self._compiled_cache[length]

    # -- public API ----------------------------------------------------------
    def init_carry(self, server, sbuf, rstream, t: int) -> FusedCarry:
        """Lift the harness's mutable state into a device carry at absolute
        round ``t``. Refuses cold request windows (the in-scan draw runs at
        static warmup=0) and sharded buffers (one single-device program)."""
        if sbuf.mesh is not None:
            raise ValueError("the fused round does not support mesh-sharded "
                             "buffers; use round_backend='dispatch'")
        deficit = warmup_deficit(rstream.state, self.dataset)
        if deficit:
            raise ValueError(
                f"fused rounds need a warm cohort window (worst-case warmup "
                f"deficit is {deficit}); fill the FIFO buffers before "
                "entering the fused engine")
        return FusedCarry(
            w=server.w, d_buffer=server.d_buffer,
            participated=jnp.asarray(server.participated),
            lam_prev=server._lam_prev,
            buf=sbuf.state, stream=rstream.state,
            t=jnp.asarray(t, jnp.int32))

    def run_segment(self, carry: FusedCarry, length: int):
        """Execute ``length`` rounds as one device dispatch. Returns the new
        carry and a dict of per-round output columns (length-leading)."""
        if length < 1:
            raise ValueError(f"segment length must be >= 1, got {length}")
        return self._compiled(carry, int(length))(carry)

    @staticmethod
    def check_outputs(outs: dict) -> None:
        """Raise ``ResourceSolveError`` if any round's f32 solve lost a
        feasible lane to non-finite kappa/f/p (knife-edge configs — the
        in-jit counterpart of ``resource_stacked._check_finite``)."""
        bad = np.asarray(outs["bad_solve"])
        if bad.any():
            rounds = np.flatnonzero(bad)
            raise ResourceSolveError(
                "fused resource solve produced non-finite kappa/f/p on "
                f"feasible clients in segment round(s) {rounds.tolist()}; "
                "for tight-deadline/knife-edge configurations run "
                "resource_backend='x64'")

    def write_back(self, carry: FusedCarry, outs: dict, server, sbuf,
                   rstream) -> None:
        """Push a segment-final carry back into the harness's mutable
        objects so checkpointing/eval see exactly the state the dispatch
        engine would hold after the same rounds."""
        server.w = carry.w
        server.d_buffer = carry.d_buffer
        server.participated = carry.participated
        server._lam_prev = carry.lam_prev
        server.last_scores = np.asarray(outs["lam_use"][-1])
        sbuf.state = carry.buf
        rstream.state = carry.stream

    def compiled_text(self, length: int) -> str:
        """Optimized HLO of the compiled ``length``-round segment (for
        ``launch/hlo_analysis.dispatch_report``); the segment must have been
        run (or compiled) first."""
        if length not in self._compiled_cache:
            raise ValueError(f"no compiled segment of length {length}; call "
                             "run_segment first")
        return self._compiled_cache[length].as_text()
