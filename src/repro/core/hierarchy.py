"""Hierarchical edge-cluster aggregation (DESIGN.md "Hierarchical
aggregation").

The paper's PS consumes every client's scored update directly (eq. 19-21),
which caps honest scale at "one PS, U slots". This module adds the edge tier
per Zhou et al., "Towards Scalable Wireless Federated Learning" (2310.05076):
the registered population is partitioned into ``K`` edge clusters, each
cluster runs the *same* scored reduction the flat PS ran — per-cluster mean,
``scored_reduce`` cosine scores, scored partial aggregate — and the PS then
combines the ``K`` cluster aggregates with cluster-level weights derived from
the identical eq. 19-21 machinery. OSAFL's online scores compose across
tiers instead of flattening; per-tier aggregation cost is O(C/K + K) rather
than O(C) at one PS, and clusters are the natural multi-host boundary.

Layout invariant — clusters are **contiguous slot blocks**. The width-C
stacked buffer is split into K equal blocks of ``B = C/K`` consecutive slots;
cluster ``k`` owns slots ``[k*B, (k+1)*B)``. On the dense path the user->
cluster map is the static contiguous partition (``u // (U/K)``), so user
rows already sit in their cluster's block. On the sparse-cohort path
``ClusterSlotPool`` keeps K per-cluster ``SlotPool``s so a cluster's
residents stay contiguous (and, on a pod mesh with ``K % client_rows == 0``,
each mesh shard holds only whole cluster blocks — no block ever straddles a
shard).

Bit-exactness anchors (tests/test_hierarchy.py):

  * ``num_clusters=0`` is the historical flat path, untouched.
  * ``num_clusters=1`` routes through the hierarchy plumbing with a single
    cluster and is bit-exact against the flat PS for all six algorithms:
    the tier-1 block ops are the flat ops on the full buffer (same
    ``jnp.mean``/``scored_reduce``/matvec), and the tier-2 combine takes the
    documented exact limit — a single cluster aggregate's cosine with its
    own mean is identically 1, so the PS step *is* the cluster aggregate
    (``step = g[0]``, no reduction applied).
  * Per-cluster score carries (``clam_prev``) checkpoint with the inner
    server state, so a K>1 run resumes bit-exactly from a streaming v2
    snapshot.

Cluster membership is scenario-drivable (``cluster_churn`` in
``scenarios/library.py``): a reassigned resident is evicted from its old
block and re-seated in the new one — its slot-resident contribution row and
FIFO dataset are reset (edge migration does not move data between edge
servers; the per-user score/staleness carries in ``CohortTables`` follow the
user). The per-cluster tier-2 carry stays with the *block*, i.e. with the
edge server, not with any member.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.baselines import STACKED_SERVERS
from repro.core.osafl import StackedOSAFLServer
from repro.core.scores import sketch_stacked


def contiguous_clusters(num_users: int, num_clusters: int) -> np.ndarray:
    """The static user->cluster map: K equal contiguous ranges. Requires
    ``K | U`` so every cluster has the same population share (and the dense
    (U, N) buffer splits into equal blocks)."""
    U, K = int(num_users), int(num_clusters)
    if K < 1 or U % K:
        raise ValueError(
            f"num_clusters must be >= 1 and divide the population "
            f"(got K={K}, U={U})")
    return (np.arange(U, dtype=np.int32) // (U // K)).astype(np.int32)


def sample_participants_clustered(rng: np.random.Generator,
                                  assign: np.ndarray, num_clusters: int,
                                  m: int, block: int,
                                  weights: Optional[np.ndarray] = None,
                                  available: Optional[np.ndarray] = None
                                  ) -> np.ndarray:
    """Stratified round-active sampling over the live cluster map: each
    cluster draws a budget proportional to its population share
    (``ceil(m * n_k / U)``, capped by its ``block`` slot capacity and its
    eligible members), via ``sample_participants`` on the member subsets in
    cluster order. At ``K <= 1`` this *delegates* to ``sample_participants``
    with the identical arguments — the same host-RNG consumption, which is
    what keeps the num_clusters=1 parity anchor bit-exact."""
    from repro.core.cohort import sample_participants
    if num_clusters <= 1:
        return sample_participants(rng, int(assign.shape[0]), m,
                                   weights=weights, available=available)
    U = int(assign.shape[0])
    picked = []
    for k in range(int(num_clusters)):
        members = np.flatnonzero(assign == k)
        if members.size == 0:
            continue
        m_k = min(int(block), int(members.size),
                  int(np.ceil(m * members.size / U)))
        w_k = None if weights is None else np.asarray(weights)[members]
        a_k = None if available is None else np.asarray(available)[members]
        idx = sample_participants(rng, int(members.size), m_k,
                                  weights=w_k, available=a_k)
        picked.append(members[idx])
    if not picked:
        return np.empty(0, np.int64)
    return np.sort(np.concatenate(picked))


class ClusterSlotPool:
    """K per-cluster ``SlotPool``s behind one global-slot interface.

    Cluster ``k`` owns the contiguous global slot block
    ``[k*B, (k+1)*B)`` with ``B = C/K``; users route to the sub-pool of
    their *current* cluster (``assign``, shared with the owning
    ``SparseCohortServer`` and mutated only through ``reassign``). Each
    sub-pool keeps the flat pool's FIFO semantics within its block, so at
    K=1 this degenerates to exactly one ``SlotPool(U, C)`` — the flat
    behavior, slot for slot."""

    def __init__(self, num_users: int, capacity: int, assign: np.ndarray,
                 num_clusters: int):
        from repro.core.cohort import SlotPool
        U, C, K = int(num_users), int(capacity), int(num_clusters)
        if K < 1 or C % K:
            raise ValueError(
                f"num_clusters must be >= 1 and divide cohort_size "
                f"(got K={K}, C={C})")
        assign = np.asarray(assign, np.int32)
        if assign.shape != (U,):
            raise ValueError(
                f"cluster map must have shape ({U},), got {assign.shape}")
        self.U, self.C, self.K = U, C, K
        self.B = C // K
        self.assign = assign                      # shared, mutated in place
        self.pools = [SlotPool(U, self.B) for _ in range(K)]

    # -- flat-pool interface -------------------------------------------------
    @property
    def user_slot(self) -> np.ndarray:
        """(U,) user -> *global* slot map (-1 = not resident)."""
        us = np.full(self.U, -1, np.int32)
        for k, p in enumerate(self.pools):
            r = p.user_slot >= 0
            us[r] = p.user_slot[r] + k * self.B
        return us

    @property
    def slot_user(self) -> np.ndarray:
        """(C,) global slot -> user map (-1 = free)."""
        return np.concatenate([p.slot_user for p in self.pools])

    @property
    def cohort(self) -> np.ndarray:
        return self.slot_user

    @property
    def occupancy(self) -> int:
        return sum(p.occupancy for p in self.pools)

    def resident(self, users) -> np.ndarray:
        return self.user_slot[np.asarray(users, np.int64)] >= 0

    def admit(self, users):
        """Route each user to its cluster's sub-pool; slots come back as
        *global* indices aligned with the input order (the same
        ``AdmitResult`` contract as the flat pool)."""
        from repro.core.cohort import AdmitResult
        users = np.asarray(users, np.int64).ravel()
        if users.size and (users.min() < 0 or users.max() >= self.U):
            raise ValueError(
                f"user ids must be in [0, {self.U}); got range "
                f"[{users.min()}, {users.max()}]")
        slots = np.empty(users.size, np.int32)
        newly = np.zeros(users.size, bool)
        evicted = []
        ks = self.assign[users] if users.size else np.empty(0, np.int32)
        for k in range(self.K):
            pos = np.flatnonzero(ks == k)
            if pos.size == 0:
                continue
            res = self.pools[k].admit(users[pos])
            slots[pos] = res.slots + k * self.B
            newly[pos] = res.newly
            if res.evicted.size:
                evicted.append(res.evicted)
        return AdmitResult(
            slots=slots, newly=newly,
            evicted=(np.concatenate(evicted).astype(np.int32)
                     if evicted else np.empty(0, np.int32)))

    def evict(self, users) -> np.ndarray:
        """Free the users' slots in their current clusters' sub-pools
        (non-residents are ignored). Returns the freed *global* slots."""
        users = np.asarray(users, np.int64).ravel()
        freed = []
        for k in range(self.K):
            sub = users[self.assign[users] == k]
            f = self.pools[k].evict(sub)
            if f.size:
                freed.append(f + k * self.B)
        return (np.concatenate(freed).astype(np.int32) if freed
                else np.empty(0, np.int32))

    def reassign(self, users, dest) -> np.ndarray:
        """Move users to new clusters: evict movers from their *old* blocks
        (while ``assign`` still routes there), then rewrite the map. Returns
        the subset of ``users`` that was resident (the callers re-admit
        those so residents migrate rather than silently vanish)."""
        users = np.asarray(users, np.int64).ravel()
        dest = np.asarray(dest, np.int64).ravel()
        if users.shape != dest.shape:
            raise ValueError("users and dest cluster ids must align")
        if dest.size and (dest.min() < 0 or dest.max() >= self.K):
            raise ValueError(
                f"destination clusters must be in [0, {self.K})")
        moving = dest != self.assign[users]
        users, dest = users[moving], dest[moving]
        was_res = self.resident(users)
        self.evict(users[was_res])
        self.assign[users] = dest.astype(np.int32)
        return users[was_res]

    def check(self) -> None:
        for k, p in enumerate(self.pools):
            p.check()
            res = np.flatnonzero(p.user_slot >= 0)
            stray = res[self.assign[res] != k]
            if stray.size:
                raise ValueError(
                    f"users {stray.tolist()} resident in cluster {k}'s "
                    f"block but assigned to clusters "
                    f"{self.assign[stray].tolist()}")

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        return {"assign": self.assign.copy(),
                "num_clusters": np.int64(self.K),
                "pools": [p.state_dict() for p in self.pools]}

    def load_state_dict(self, sd: dict) -> None:
        from repro.checkpoint.run_state import CheckpointError
        if int(sd.get("num_clusters", -1)) != self.K:
            raise CheckpointError(
                f"snapshot slot pool has num_clusters="
                f"{sd.get('num_clusters')!r}; the run expects K={self.K}")
        assign = np.asarray(sd["assign"], np.int32)
        if assign.shape != (self.U,):
            raise CheckpointError(
                f"snapshot cluster map has shape {assign.shape}; the run "
                f"registers U={self.U} users")
        pools = sd["pools"]
        if len(pools) != self.K:
            raise CheckpointError(
                f"snapshot holds {len(pools)} cluster pools; the run "
                f"expects {self.K}")
        self.assign[:] = assign
        for p, psd in zip(self.pools, pools):
            p.load_state_dict(psd)
        self.check()


def make_hier_round_body(fl: FLConfig, num_clusters: int):
    """The two-tier OSAFL round as one pure function

        rnd(w, buf, part_prev, lam_prev, clam_prev, d_new, active, alphas,
            key) -> (w, buf, part, lam_use, lam, clam_use, clam)

    Tier 1 (edge): the flat round's write-back/staleness refresh, then each
    cluster block scores its own slots against its own block mean — the
    identical op sequence as ``make_stacked_round_body`` applied per block
    (static K-way unroll inside one jit; at K=1 the single block IS the full
    buffer, so every op matches the flat body bit for bit). Each edge then
    forms its scored partial aggregate ``g_k = (alpha*lam)_k @ buf_k`` —
    the (K, N) matrix an edge tier would transmit to the PS.

    Tier 2 (PS): the K aggregates are scored with the same eq. 19-21
    machinery (cosine against the cluster-mean direction) and combined,
    ``step = clam_use @ g``; ``clam_prev`` is the cluster-level stale-score
    carry mirroring ``lam_prev``. At K=1 the combine takes the exact limit
    (one aggregate's cosine with its own mean is identically 1):
    ``step = g[0]``, bit-exact vs the flat scored SGD step.
    """
    from repro.kernels.ops import _interpret
    from repro.kernels.ref import scored_reduce_reference
    from repro.kernels.scored_reduce import scored_reduce
    interpret = _interpret()
    K = int(num_clusters)
    if K < 1:
        raise ValueError(f"num_clusters must be >= 1, got {K}")

    def scores_of(rows, key):
        """eq. 19-21 lambda scores of a (n, N) row block against its own
        mean — the flat body's scoring, applied to any tier's rows."""
        if fl.score_sketch_dim:
            sk = sketch_stacked(rows, key, fl.score_sketch_dim)
            mean = jnp.mean(sk, axis=0)
            dots = sk @ mean
            norms = jnp.sum(sk * sk, axis=1)
            msq = jnp.sum(mean * mean)
        else:
            mean = jnp.mean(rows, axis=0)
            if fl.score_backend == "kernel":
                dots, norms, msq = scored_reduce(rows, mean,
                                                 interpret=interpret)
            else:
                dots, norms, msq = scored_reduce_reference(rows, mean)
        cos = dots / jnp.maximum(jnp.sqrt(norms) * jnp.sqrt(msq), 1e-12)
        return (fl.chi + cos) / (fl.chi + 1.0)

    def rnd(w, buf, part_prev, lam_prev, clam_prev, d_new, active, alphas,
            key):
        part = part_prev | active
        buf = jnp.where(active[:, None], d_new, buf)
        # Algorithm 2 line 17: refresh never-participated slots
        refresh = (w / fl.local_lr if fl.literal_init_buffer
                   else jnp.zeros_like(w))
        buf = jnp.where(part[:, None], buf, refresh[None, :])
        B = buf.shape[0] // K
        blk = [slice(k * B, (k + 1) * B) for k in range(K)]
        # tier 1: per-cluster eq. 19-21 scores on the cluster's own slots
        lam = jnp.concatenate([scores_of(buf[b], key) for b in blk])
        lam_use = lam_prev if fl.stale_scores else lam
        # each edge's scored partial aggregate — what it transmits to the PS
        g = jnp.stack([(alphas[b] * lam_use[b]) @ buf[b] for b in blk])
        if K == 1:
            # exact limit: cos(g_0, mean(g)) = cos(g_0, g_0) = 1, so the
            # combine is the aggregate itself — bit-exact vs the flat step
            clam = jnp.ones((1,), jnp.float32)
            clam_use = clam_prev if fl.stale_scores else clam
            step = g[0]
        else:
            # tier 2: the SAME score machinery over the K cluster aggregates
            clam = scores_of(g, key)
            clam_use = clam_prev if fl.stale_scores else clam
            step = clam_use @ g
        w = w - fl.global_lr * fl.local_lr * step
        return w, buf, part, lam_use, lam, clam_use, clam

    return rnd


class HierStackedOSAFLServer(StackedOSAFLServer):
    """``StackedOSAFLServer`` with the two-tier round body: same state plus
    the (K,) cluster-level score carry ``clam_prev`` (checkpointed) and the
    per-round cluster scores in ``last_cluster_scores``. Rows are expected
    in cluster-block order (slot ``k*B + i`` belongs to cluster ``k``)."""

    def __init__(self, params, fl: FLConfig, num_clients: int,
                 alphas=None, seed: int = 0):
        K = int(fl.num_clusters)
        if K < 1 or num_clients % K:
            raise ValueError(
                f"num_clusters must be >= 1 and divide the stacked width "
                f"(got K={K}, width={num_clients})")
        super().__init__(params, fl, num_clients, alphas=alphas, seed=seed)
        self.K = K
        self._clam_prev = jnp.ones(K, jnp.float32)
        self.last_cluster_scores = np.ones(K)
        self._round_fn = jax.jit(make_hier_round_body(fl, K))

    def round_stacked(self, d_new, active):
        (self.w, self.d_buffer, self.participated, lam_use, self._lam_prev,
         clam_use, self._clam_prev) = self._round_fn(
            self.w, self.d_buffer, self.participated, self._lam_prev,
            self._clam_prev, d_new, jnp.asarray(active), self.alphas,
            self._sketch_key)
        self.last_scores = np.asarray(lam_use)
        self.last_cluster_scores = np.asarray(clam_use)
        return self.w

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        sd = super().state_dict()
        sd["clam_prev"] = self._clam_prev
        return sd

    def load_state_dict(self, sd: dict) -> None:
        if sd.get("clam_prev") is None:
            from repro.checkpoint.run_state import CheckpointError
            raise CheckpointError(
                "snapshot has no cluster-score carry (clam_prev) — it was "
                "not written by a hierarchical (num_clusters>0) run")
        super().load_state_dict(sd)
        self._clam_prev = jnp.asarray(sd["clam_prev"])
        self.last_cluster_scores = np.asarray(self._clam_prev)


def _hier_baseline(base):
    """Two-tier variant of a stacked baseline: the flat aggregation matvec
    ``ws @ buffer`` becomes per-cluster partial aggregates summed at the PS.
    Every weighting rule (FedAvg's 1/U, FedNova's pk, FedDisco's alpha)
    composes unchanged — the blocked sum is the same linear combination, so
    K>1 differs from flat only by float re-association, and K=1 returns the
    single block's matvec itself (bit-exact vs flat)."""

    class Hier(base):
        def __init__(self, params, fl: FLConfig, num_clients: int,
                     seed: int = 0):
            K = int(fl.num_clusters)
            if K < 1 or num_clients % K:
                raise ValueError(
                    f"num_clusters must be >= 1 and divide the stacked "
                    f"width (got K={K}, width={num_clients})")
            super().__init__(params, fl, num_clients, seed=seed)
            self.K = K

        def cluster_aggregates(self, ws) -> jnp.ndarray:
            """(K, N) per-cluster partial aggregates under weights ``ws`` —
            the edge-tier traffic a deployment would actually transmit."""
            B = self.buffer.shape[0] // self.K
            w32 = jnp.asarray(ws, jnp.float32)
            return jnp.stack([
                w32[k * B:(k + 1) * B] @ self.buffer[k * B:(k + 1) * B]
                for k in range(self.K)])

        def _weighted(self, ws) -> jnp.ndarray:
            g = self.cluster_aggregates(ws)
            return g[0] if self.K == 1 else jnp.sum(g, axis=0)

    Hier.__name__ = "Hier" + base.__name__
    Hier.__qualname__ = Hier.__name__
    return Hier


HIER_SERVERS = {alg: _hier_baseline(cls)
                for alg, cls in STACKED_SERVERS.items()}


def make_hier_server(params, fl: FLConfig, num_clients: int, seed: int = 0):
    """The hierarchical counterpart of ``baselines.make_server``'s stacked
    branch: width = the stacked buffer width (U dense, C sparse-inner)."""
    if fl.algorithm == "osafl":
        return HierStackedOSAFLServer(params, fl, num_clients, seed=seed)
    if fl.algorithm in HIER_SERVERS:
        return HIER_SERVERS[fl.algorithm](params, fl, num_clients, seed=seed)
    raise ValueError(f"unknown algorithm {fl.algorithm!r}")
