"""Online score computation (paper eqs. 19-21, 34-35).

Exact scores: lambda_u = (chi + cos(d_mean, d_u)) / (chi + 1), Delta_u = lambda_u.
Sketched scores (beyond-paper, §Perf): cosine on a k-dim Rademacher projection
of each update — an unbiased inner-product estimator (Johnson-Lindenstrauss),
reducing the score's communication/memory from O(N) to O(k).
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def tree_dot(a, b) -> jnp.ndarray:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return sum(jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
               for x, y in zip(la, lb))


def tree_norm(a) -> jnp.ndarray:
    return jnp.sqrt(tree_dot(a, a))


def tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def cosine(a, b, eps: float = 1e-12) -> jnp.ndarray:
    return tree_dot(a, b) / jnp.maximum(tree_norm(a) * tree_norm(b), eps)


def lambda_scores(updates: Sequence, chi: float = 1.0) -> np.ndarray:
    """Paper eqs. 19-21: d_mean = (1/U) sum_u d_u; lambda in [0, 1]."""
    U = len(updates)
    d_mean = tree_scale(updates[0], 1.0 / U)
    for d in updates[1:]:
        d_mean = tree_add(d_mean, tree_scale(d, 1.0 / U))
    lam = np.array([float((chi + cosine(d_mean, d)) / (chi + 1.0))
                    for d in updates])
    return lam


def sketch_tree(tree, key, k: int) -> jnp.ndarray:
    """k-dim count-sketch of a pytree: bucket j%k after a random sign flip,
    s_b = sum_{j: b(j)=b} sign_j * x_j. Unbiased inner-product estimator with
    O(N) work and O(N) transient memory (signs are leaf-sized, not k*N).
    The key fixes the signs so sketches are comparable across clients/rounds."""
    out = jnp.zeros((k,), jnp.float32)
    for i, leaf in enumerate(jax.tree.leaves(tree)):
        lk = jax.random.fold_in(key, i)
        flat = leaf.reshape(-1).astype(jnp.float32)
        pad = (-flat.size) % k
        flat = jnp.pad(flat, (0, pad))
        signs = jax.random.rademacher(lk, flat.shape, jnp.float32)
        out = out + jnp.sum((flat * signs).reshape(-1, k), axis=0)
    return out


def lambda_scores_sketched(sketches: jnp.ndarray, chi: float = 1.0
                           ) -> np.ndarray:
    """sketches: (U, k). Same formula on the projected updates."""
    mean = jnp.mean(sketches, axis=0)
    dots = sketches @ mean
    norms = jnp.linalg.norm(sketches, axis=1) * jnp.linalg.norm(mean)
    cos = dots / jnp.maximum(norms, 1e-12)
    return np.asarray((chi + cos) / (chi + 1.0))


def sketch_stacked(mat: jnp.ndarray, key, k: int) -> jnp.ndarray:
    """Count-sketch every row of a stacked (U, N) update matrix at once:
    the single-leaf specialization of ``sketch_tree`` (same fold_in(key, 0)
    sign stream), vectorized over clients. Returns (U, k)."""
    U, N = mat.shape
    lk = jax.random.fold_in(key, 0)
    pad = (-N) % k
    m = mat.astype(jnp.float32)
    if pad:
        m = jnp.pad(m, ((0, 0), (0, pad)))
    signs = jax.random.rademacher(lk, (N + pad,), jnp.float32)
    return jnp.sum((m * signs).reshape(U, -1, k), axis=1)
