"""Per-client joint resource optimization (paper Section II-C, Appendix B).

Each client jointly optimizes (local SGD rounds kappa, CPU frequency f,
transmit power p) to maximize

    eps * kappa / (0.5 v n nbar c s f^2)  +  (1-eps) * omega log2(1+SNR(p)) / p

s.t. deadline t_th and energy budget e_bd (eqs. 5/37). We implement the
paper's alternating solution exactly:

  * Lemma 1: kappa* = min{kappa_max, min{J1, J2}}  (closed form, eq. 39/42)
  * Lemma 2: f*     = deadline lower bound          (closed form, eq. 44/48)
  * power: SCA on the linearized problem (eqs. 50-52). After linearization the
    objective is affine in p and the constraints carve an interval, so each SCA
    step is solved exactly at an interval endpoint (no external solver needed —
    replaces the paper's CVXPY call with the same math).

Clients for which the problem is infeasible are *stragglers* (kappa = 0).
Everything is plain NumPy — it runs once per client per round on the host,
exactly like the paper's edge devices would.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

FPP = 32  # floating point precision (bits)

# Knife-edge slacks. The alternating solve parks its iterates exactly on two
# constraint boundaries: Lemma 2's f makes the deadline exactly binding at the
# current p, so (a) J2 equals kappa *exactly* in exact arithmetic and
# floor(J2) flips kappa-1 vs kappa on last-ulp rounding, and (b) the SCA's
# minimum-deadline power p_lo equals the current p exactly, so at p = p_max
# the p_lo > p_max infeasibility check is a coin flip on float noise. The
# slacks keep both decisions on the exact-arithmetic side (and deterministic
# across float implementations — the batched core/resource_stacked.py must
# match this module exactly); any resulting constraint excess is O(slack)
# relative, inside the 1e-6 feasibility-check slack.
_J_SLACK = 1e-7
_P_SLACK = 1e-9


@dataclass
class ClientSystem:
    """Static per-client system configuration (paper Section V-A3)."""
    c: float            # CPU cycles per bit
    s: float            # sample size (bits)
    f_max: float        # max CPU frequency (Hz)
    p_max: float        # max transmit power (W)
    e_bd: float         # energy budget (J)
    distance: float     # to BS (m)


@dataclass
class ChannelState:
    """Per-round wireless channel: large-scale path gain Xi and shadowing Gamma
    (linear scale)."""
    xi: float
    gamma: float


@dataclass
class NetworkConfig:
    omega: float = 3 * 180e3       # bandwidth (Hz)
    noise_psd_dbm: float = -174.0  # thermal noise PSD (dBm/Hz)
    noise_figure_db: float = 7.0
    t_th: float = 200.0            # deadline (s)
    kappa_max: int = 5
    v: float = 2e-28               # effective capacitance
    n: int = 32                    # number of mini-batches
    nbar: int = 5                  # mini-batch size
    eps: float = 0.5               # objective trade-off epsilon
    sca_iters: int = 8
    outer_iters: int = 6
    tol: float = 1e-6

    @property
    def noise_power(self) -> float:
        return 10 ** ((self.noise_psd_dbm + self.noise_figure_db - 30) / 10) \
            * self.omega


def pathloss_linear(distance_m) -> float:
    """3GPP-style urban path loss at 2.4 GHz: PL(dB)=128.1+37.6 log10(d_km).
    Elementwise — accepts a scalar or an (U,) array of distances."""
    pl_db = 128.1 + 37.6 * np.log10(np.maximum(distance_m, 1.0) / 1000.0)
    return 10 ** (-pl_db / 10)


def sample_channel(rng: np.random.Generator, sys: ClientSystem,
                   shadow_sigma_db: float = 8.0) -> ChannelState:
    gamma = 10 ** (rng.normal(0.0, shadow_sigma_db) / 10)
    return ChannelState(xi=pathloss_linear(sys.distance), gamma=gamma)


def _rate(net: NetworkConfig, ch: ChannelState, p: float) -> float:
    """omega * log2(1 + Xi*Gamma*p / (omega*xi^2)) — bits/s."""
    snr = ch.xi * ch.gamma * p / net.noise_power
    return net.omega * np.log2(1.0 + snr)


def _upload_time(net, ch, p, n_params) -> float:
    return n_params * (FPP + 1) / max(_rate(net, ch, p), 1e-12)


def _upload_energy(net, ch, p, n_params) -> float:
    return _upload_time(net, ch, p, n_params) * p


def _comp_coeff(net: NetworkConfig, sys: ClientSystem) -> float:
    """n*nbar*c*s — cycles per local SGD round."""
    return net.n * net.nbar * sys.c * sys.s


def optimal_kappa(net, sys, ch, f, p, n_params) -> int:
    """Lemma 1 (eq. 42)."""
    cc = _comp_coeff(net, sys)
    e_up = _upload_energy(net, ch, p, n_params)
    t_up = _upload_time(net, ch, p, n_params)
    j1 = (sys.e_bd - e_up) / (0.5 * net.v * cc * f ** 2)
    j2 = f * (net.t_th - t_up) / cc
    k = min(net.kappa_max, int(np.floor(min(j1, j2) + _J_SLACK)))
    return max(k, 0)


def optimal_frequency(net, sys, ch, kappa, p, n_params) -> float:
    """Lemma 2 (eq. 48): the deadline lower bound (objective decreasing in f)."""
    cc = _comp_coeff(net, sys)
    r = _rate(net, ch, p)
    denom = net.t_th * r - n_params * (FPP + 1)
    if denom <= 0:
        return np.inf  # infeasible: upload alone exceeds the deadline
    return cc * kappa * r / denom


def _sca_power(net, sys, ch, kappa, f, n_params, p0) -> Optional[float]:
    """SCA for the power subproblem (eqs. 50-52). Each iteration the linearized
    objective is affine in p -> optimum at an endpoint of the feasible interval."""
    g = ch.xi * ch.gamma / net.noise_power   # SNR slope: snr = g*p
    Nb = n_params * (FPP + 1)
    e_cp = 0.5 * net.v * _comp_coeff(net, sys) * kappa * f ** 2
    # (52c)/(11c): minimum power so the upload meets the deadline given kappa,f
    t_cp = _comp_coeff(net, sys) * kappa / f
    t_left = net.t_th - t_cp
    if t_left <= 0:
        return None
    snr_min = 2.0 ** (Nb / (net.omega * t_left)) - 1.0
    p_lo = snr_min / g
    if p_lo > sys.p_max * (1 + _P_SLACK):
        return None
    p_lo = min(p_lo, sys.p_max)
    p = max(min(p0, sys.p_max), p_lo, 1e-6)
    for _ in range(net.sca_iters):
        ln = np.log1p(g * p)
        # ee(p) ~ affine: slope of omega*log2(1+gp)/p at p (eq. 50)
        obj_slope = (net.omega / np.log(2)) * (g / (p * (1 + g * p))
                                               - ln / p ** 2)
        # ebar(p) ~ affine: upload energy linearization (eq. 51)
        e_at = Nb * np.log(2) / net.omega * (p / ln)
        e_slope = Nb * np.log(2) / net.omega * (1 / ln - g * p /
                                                (ln ** 2 * (1 + g * p)))
        # energy constraint: e_cp + e_at + e_slope*(pp - p) <= e_bd
        p_hi = sys.p_max
        if e_slope > 0:
            p_hi = min(p_hi, p + (sys.e_bd - e_cp - e_at) / e_slope)
        if p_hi < p_lo - 1e-12:
            return None
        p_new = p_hi if obj_slope >= 0 else p_lo
        p_new = float(np.clip(p_new, p_lo, sys.p_max))
        if abs(p_new - p) < net.tol:
            p = p_new
            break
        p = 0.5 * (p + p_new)   # damped update for stability
    # verify true (non-linearized) constraints
    if (_upload_energy(net, ch, p, n_params) + e_cp <= sys.e_bd * (1 + 1e-6)
            and t_cp + _upload_time(net, ch, p, n_params)
            <= net.t_th * (1 + 1e-6)):
        return p
    return None


@dataclass
class ResourceDecision:
    kappa: int
    f: float
    p: float
    feasible: bool
    t_total: float = 0.0
    e_total: float = 0.0


def optimize_client(net: NetworkConfig, sys: ClientSystem, ch: ChannelState,
                    n_params: int) -> ResourceDecision:
    """Algorithm 1/4 with a small sweep over initial power points (the paper's
    algorithm takes "initial points f^0, p^0" as input; a bad initial p can make
    the first kappa projection infeasible even when the problem is not)."""
    best = ResourceDecision(0, sys.f_max, sys.p_max, False)
    for frac in (1.0, 0.1, 0.01, 1e-3, 1e-4):
        cand = _optimize_from(net, sys, ch, n_params, sys.p_max * frac)
        if cand.feasible and (not best.feasible or cand.kappa > best.kappa):
            best = cand
    return best


def _optimize_from(net: NetworkConfig, sys: ClientSystem, ch: ChannelState,
                   n_params: int, p0: float) -> ResourceDecision:
    f, p = sys.f_max, p0
    best = ResourceDecision(0, f, p, False)
    for _ in range(net.outer_iters):
        kappa = optimal_kappa(net, sys, ch, f, p, n_params)
        if kappa < 1:
            break
        f_new = optimal_frequency(net, sys, ch, kappa, p, n_params)
        if not np.isfinite(f_new) or f_new > sys.f_max:
            # cannot meet the deadline at this kappa; try fewer local rounds
            ok = False
            for k2 in range(kappa - 1, 0, -1):
                f_new = optimal_frequency(net, sys, ch, k2, p, n_params)
                if np.isfinite(f_new) and f_new <= sys.f_max:
                    kappa, ok = k2, True
                    break
            if not ok:
                break
        f = float(np.clip(f_new, 1e6, sys.f_max))
        p_new = _sca_power(net, sys, ch, kappa, f, n_params, p)
        if p_new is None:
            break
        p = p_new
        t_cp = _comp_coeff(net, sys) * kappa / f
        e_cp = 0.5 * net.v * _comp_coeff(net, sys) * kappa * f ** 2
        t_tot = t_cp + _upload_time(net, ch, p, n_params)
        e_tot = e_cp + _upload_energy(net, ch, p, n_params)
        if t_tot <= net.t_th * (1 + 1e-6) and e_tot <= sys.e_bd * (1 + 1e-6):
            best = ResourceDecision(kappa, f, p, True, t_tot, e_tot)
    return best


def make_clients(rng: np.random.Generator, num_clients: int,
                 cell_radius_m: float = 1000.0) -> list[ClientSystem]:
    """Sample the paper's client population (Section V-A3)."""
    out = []
    for _ in range(num_clients):
        out.append(ClientSystem(
            c=rng.uniform(25, 40),
            s=101_376.0,                          # Dataset-1 bits/sample (Table I)
            f_max=rng.uniform(1.0, 1.8) * 1e9,
            p_max=10 ** (rng.uniform(20, 30) / 10) / 1000,   # 20-30 dBm -> W
            e_bd=rng.uniform(1.2, 2.5),
            distance=cell_radius_m * np.sqrt(rng.uniform(0.01, 1.0)),
        ))
    return out


def optimize_round(rng: np.random.Generator, net: NetworkConfig,
                   clients: list[ClientSystem], n_params: int
                   ) -> list[ResourceDecision]:
    """One FL round: sample channels and solve (5) for every client."""
    return [optimize_client(net, sys, sample_channel(rng, sys), n_params)
            for sys in clients]
