"""Modified FL baselines (paper Algorithms 6-10).

All baselines share the paper's resource-optimization front end (clients solve
(5) for kappa) and the stale-contribution buffers; only the aggregation rule
differs:

  M-FedAvg   (Alg. 6):  w^{t+1} = (1/U) sum_u w[u]
  M-FedProx  (Alg. 7):  FedAvg aggregation; proximal term mu/2 ||w - w^t||^2
                         in the *local* objective (client-side, see client.py)
  M-FedNova  (Alg. 8):  w^{t+1} = w^t - eta * tau~ * (sum_u p_u k_u) *
                                   sum_u (p_u k_u / sum p k) d[u]
                         (requires D_u and kappa_u at the CS — violates the
                         paper's privacy assumption; kept for comparison)
  M-AFA-CD   (Alg. 9):  w^{t+1} = w^t - eta_g * (1/U) sum_u d[u]
  M-FedDisco (Alg. 10): w^{t+1} = sum_u alpha_u w[u],
                         alpha_u = ReLU(p_u - a*disco_u + b) / sum(...)
                         (requires the client label histogram — also violates
                         the privacy assumption)
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.flatten import make_codec, scatter_updates
from repro.core.osafl import ClientUpdate
from repro.core.scores import (tree_add, tree_scale, tree_sub,
                               tree_zeros_like)


class _BufferedServer:
    """Common machinery: per-client contribution buffers + staleness rules."""

    buffers_hold_weights = True      # False => buffers hold normalized grads d

    def __init__(self, params, fl: FLConfig, num_clients: int, seed: int = 0):
        self.params = params
        self.fl = fl
        self.U = num_clients
        self.participated = np.zeros(num_clients, bool)
        if self.buffers_hold_weights:
            self.buffer: List = [params for _ in range(num_clients)]
        else:
            init_d = (tree_scale(params, 1.0 / fl.local_lr)
                      if fl.literal_init_buffer else tree_zeros_like(params))
            self.buffer = [init_d for _ in range(num_clients)]
        self.meta: List[Optional[ClientUpdate]] = [None] * num_clients

    def _ingest(self, updates: Sequence[ClientUpdate], weights: bool):
        for up in updates:
            self.buffer[up.uid] = up.d
            self.participated[up.uid] = True
            self.meta[up.uid] = up
        for u in range(self.U):
            if not self.participated[u]:
                if weights:
                    self.buffer[u] = self.params           # averaging no-op
                elif self.fl.literal_init_buffer:
                    self.buffer[u] = tree_scale(self.params,
                                                1.0 / self.fl.local_lr)
                else:
                    self.buffer[u] = tree_zeros_like(self.params)

    def _mean(self, items, ws):
        out = tree_zeros_like(self.params)
        for it, w in zip(items, ws):
            out = tree_add(out, tree_scale(it, float(w)))
        return out

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Snapshot params, contribution buffers, participation flags and the
        sticky per-client metadata (sizes/kappas/histograms stale-held by
        FedNova/FedDisco). The buffered pytree ``d`` inside ``meta`` is never
        read back, so only the scalar fields are serialized."""
        meta = [None if m is None else
                {"uid": int(m.uid), "kappa": int(m.kappa),
                 "data_size": int(m.data_size), "label_hist": m.label_hist}
                for m in self.meta]
        return {"params": self.params, "buffer": list(self.buffer),
                "participated": self.participated, "meta": meta}

    def load_state_dict(self, sd: dict) -> None:
        as_dev = lambda t: jax.tree.map(jnp.asarray, t)  # noqa: E731
        self.params = as_dev(sd["params"])
        self.buffer = [as_dev(b) for b in sd["buffer"]]
        self.participated = np.asarray(sd["participated"], bool)
        self.meta = [None if m is None else ClientUpdate(
            uid=int(m["uid"]), d=None, kappa=int(m["kappa"]),
            data_size=int(m["data_size"]),
            label_hist=(None if m["label_hist"] is None
                        else np.asarray(m["label_hist"])))
            for m in sd["meta"]]


class FedAvgServer(_BufferedServer):
    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates, weights=True)
        self.params = self._mean(self.buffer, np.full(self.U, 1.0 / self.U))
        return self.params


class FedProxServer(FedAvgServer):
    """Aggregation identical to FedAvg; clients add the proximal term."""
    local_prox = True


class FedNovaServer(_BufferedServer):
    buffers_hold_weights = False

    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates, weights=False)
        sizes = np.array([self.meta[u].data_size if self.meta[u] else 1
                          for u in range(self.U)], float)
        p = sizes / sizes.sum()
        kap = np.array([self.meta[u].kappa if self.meta[u] else 1
                        for u in range(self.U)], float)
        pk = p * kap
        tau_eff = self.fl.fednova_slowdown * pk.sum()
        w = self.fl.local_lr * tau_eff * pk / pk.sum()
        self.params = tree_sub(self.params, self._mean(self.buffer, w))
        return self.params


class AFACDServer(_BufferedServer):
    buffers_hold_weights = False

    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates, weights=False)
        w = np.full(self.U, self.fl.global_lr * self.fl.local_lr / self.U)
        self.params = tree_sub(self.params, self._mean(self.buffer, w))
        return self.params


class FedDiscoServer(_BufferedServer):
    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates, weights=True)
        sizes = np.array([self.meta[u].data_size if self.meta[u] else 1
                          for u in range(self.U)], float)
        p = sizes / sizes.sum()
        disco = np.zeros(self.U)
        for u in range(self.U):
            h = self.meta[u].label_hist if self.meta[u] is not None else None
            if h is not None:
                uniform = np.full_like(h, 1.0 / len(h))
                disco[u] = float(np.linalg.norm(h - uniform))
        a, b = self.fl.feddisco_a, self.fl.feddisco_b
        alpha = np.maximum(p - a * disco + b, 0.0)
        alpha = alpha / max(alpha.sum(), 1e-12)
        self.params = self._mean(self.buffer, alpha)
        return self.params


# ---------------------------------------------------------------------------
# Stacked (vectorized) baselines: same aggregation rules on the (U, N) flat
# buffer used by StackedOSAFLServer. The ingest (write-back + staleness
# refresh) is dense masked arithmetic; every aggregation is one matvec over
# the stacked buffer instead of an O(U) Python tree loop.
# ---------------------------------------------------------------------------


class _StackedBufferedServer:
    """Stacked counterpart of ``_BufferedServer``: one (U, N) f32 buffer plus
    sticky per-client metadata arrays (data sizes, kappas, label histograms —
    the loop servers keep the last seen ``ClientUpdate`` forever; here the
    scalar fields live in dense arrays instead)."""

    buffers_hold_weights = True      # False => buffers hold normalized grads d

    def __init__(self, params, fl: FLConfig, num_clients: int, seed: int = 0):
        self.fl = fl
        self.U = num_clients
        self.codec = make_codec(params)
        self.w = self.codec.flatten(params)
        self.participated = np.zeros(num_clients, bool)
        self.buffer = jnp.tile(self.init_row()[None, :], (num_clients, 1))
        self.sizes = np.ones(num_clients)        # loop default: size 1
        self.kappas = np.ones(num_clients)
        self.hists = None                        # lazily sized (U, C)
        self.has_hist = np.zeros(num_clients, bool)

    @property
    def params(self):
        return self.codec.unflatten(self.w)

    def init_row(self) -> jnp.ndarray:
        """The (N,) refresh value of a slot with no live contribution: the
        current global weights for weight-averaging servers (an averaging
        no-op), the staleness refresh for gradient-buffer servers. The
        sparse-cohort engine (``core/cohort.py``) writes this into a slot at
        admission — eviction drops the slot-resident contribution."""
        if self.buffers_hold_weights:
            return self.w
        return (self.w / self.fl.local_lr if self.fl.literal_init_buffer
                else jnp.zeros_like(self.w))

    def _ingest(self, updates: Sequence[ClientUpdate]):
        d_new, active = scatter_updates(self.codec, updates, self.U)
        for up in updates:
            self.sizes[up.uid] = up.data_size    # loop meta semantics: the
            self.kappas[up.uid] = up.kappa       # last seen update sticks
            if up.label_hist is not None:
                if self.hists is None:
                    self.hists = np.zeros((self.U, len(up.label_hist)))
                self.hists[up.uid] = up.label_hist
                self.has_hist[up.uid] = True
        self._ingest_stacked(jnp.asarray(d_new), active)

    def _ingest_stacked(self, d_new: jnp.ndarray, active):
        """Dense path: write back active rows, refresh never-participated."""
        active = np.asarray(active, bool)
        self.participated |= active
        part = jnp.asarray(self.participated)
        buf = jnp.where(jnp.asarray(active)[:, None], d_new, self.buffer)
        self.buffer = jnp.where(part[:, None], buf, self.init_row()[None, :])

    def _weighted(self, ws) -> jnp.ndarray:
        return jnp.asarray(ws, jnp.float32) @ self.buffer

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Snapshot the flat weights, the (U, N) buffer, participation flags
        and the dense sticky-metadata arrays (loop ``meta`` semantics)."""
        return {"w": self.w, "buffer": self.buffer,
                "participated": self.participated,
                "sizes": self.sizes, "kappas": self.kappas,
                "hists": self.hists, "has_hist": self.has_hist}

    def load_state_dict(self, sd: dict) -> None:
        self.w = jnp.asarray(sd["w"])
        self.buffer = jnp.asarray(sd["buffer"])
        self.participated = np.asarray(sd["participated"], bool)
        self.sizes = np.asarray(sd["sizes"], float)
        self.kappas = np.asarray(sd["kappas"], float)
        self.hists = (None if sd["hists"] is None
                      else np.asarray(sd["hists"], float))
        self.has_hist = np.asarray(sd["has_hist"], bool)


class StackedFedAvgServer(_StackedBufferedServer):
    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates)
        self.w = self._weighted(np.full(self.U, 1.0 / self.U))
        return self.params

    def round_stacked(self, d_new: jnp.ndarray, active) -> jnp.ndarray:
        self._ingest_stacked(d_new, active)
        self.w = self._weighted(np.full(self.U, 1.0 / self.U))
        return self.w


class StackedFedProxServer(StackedFedAvgServer):
    """Aggregation identical to FedAvg; clients add the proximal term."""
    local_prox = True


class StackedFedNovaServer(_StackedBufferedServer):
    buffers_hold_weights = False

    def _nova_weights(self) -> np.ndarray:
        p = self.sizes / self.sizes.sum()
        pk = p * self.kappas
        tau_eff = self.fl.fednova_slowdown * pk.sum()
        return self.fl.local_lr * tau_eff * pk / pk.sum()

    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates)
        self.w = self.w - self._weighted(self._nova_weights())
        return self.params

    def round_stacked(self, d_new, active, sizes=None, kappas=None):
        # merge metadata for ACTIVE clients only: the loop engine's meta is
        # "last seen update sticks", so inactive slots keep their old values
        act = np.asarray(active, bool)
        if sizes is not None:
            self.sizes = np.where(act, np.asarray(sizes, float), self.sizes)
        if kappas is not None:
            self.kappas = np.where(act, np.asarray(kappas, float),
                                   self.kappas)
        self._ingest_stacked(d_new, active)
        self.w = self.w - self._weighted(self._nova_weights())
        return self.w


class StackedAFACDServer(_StackedBufferedServer):
    buffers_hold_weights = False

    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates)
        lr = self.fl.global_lr * self.fl.local_lr
        self.w = self.w - self._weighted(np.full(self.U, lr / self.U))
        return self.params

    def round_stacked(self, d_new, active) -> jnp.ndarray:
        self._ingest_stacked(d_new, active)
        lr = self.fl.global_lr * self.fl.local_lr
        self.w = self.w - self._weighted(np.full(self.U, lr / self.U))
        return self.w


class StackedFedDiscoServer(_StackedBufferedServer):
    def _disco_weights(self) -> np.ndarray:
        p = self.sizes / self.sizes.sum()
        disco = np.zeros(self.U)
        if self.hists is not None:
            h = self.hists
            uniform = np.full_like(h, 1.0 / h.shape[1])
            disco = np.where(self.has_hist,
                             np.linalg.norm(h - uniform, axis=1), 0.0)
        alpha = np.maximum(p - self.fl.feddisco_a * disco
                           + self.fl.feddisco_b, 0.0)
        return alpha / max(alpha.sum(), 1e-12)

    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates)
        self.w = self._weighted(self._disco_weights())
        return self.params

    def round_stacked(self, d_new, active, sizes=None, hists=None):
        act = np.asarray(active, bool)
        if sizes is not None:
            self.sizes = np.where(act, np.asarray(sizes, float), self.sizes)
        if hists is not None:
            hists = np.asarray(hists, float)
            if self.hists is None:
                self.hists = np.zeros_like(hists)
            self.hists = np.where(act[:, None], hists, self.hists)
            self.has_hist |= act
        self._ingest_stacked(d_new, active)
        self.w = self._weighted(self._disco_weights())
        return self.w


STACKED_SERVERS = {
    "fedavg": StackedFedAvgServer,
    "fedprox": StackedFedProxServer,
    "fednova": StackedFedNovaServer,
    "afa_cd": StackedAFACDServer,
    "feddisco": StackedFedDiscoServer,
}

SERVERS = {
    "fedavg": FedAvgServer,
    "fedprox": FedProxServer,
    "fednova": FedNovaServer,
    "afa_cd": AFACDServer,
    "feddisco": FedDiscoServer,
}


def make_server(params, fl: FLConfig, num_clients: int, seed: int = 0,
                mesh=None):
    from repro.core.osafl import OSAFLServer, StackedOSAFLServer
    if fl.cohort_size:
        # sparse-cohort engine: a width-C stacked server behind an active-slot
        # pool with per-user carry tables (optionally NamedSharding-split over
        # the mesh's client axes). Imported lazily — core/cohort.py imports
        # the stacked servers from this module.
        from repro.core.cohort import SparseCohortServer
        if fl.engine != "stacked":
            raise ValueError(
                "cohort_size>0 needs the stacked engine (the loop servers "
                f"are dense per-user oracles; got engine={fl.engine!r})")
        return SparseCohortServer(params, fl, num_clients, seed=seed,
                                  mesh=mesh)
    if fl.engine == "stacked":
        if fl.num_clusters >= 1:
            # hierarchical edge-cluster tier: the two-tier round bodies
            # (core/hierarchy.py; num_clusters=1 is the flat-parity anchor)
            from repro.core.hierarchy import make_hier_server
            return make_hier_server(params, fl, num_clients, seed=seed)
        if fl.algorithm == "osafl":
            return StackedOSAFLServer(params, fl, num_clients, seed=seed)
        return STACKED_SERVERS[fl.algorithm](params, fl, num_clients,
                                             seed=seed)
    if fl.num_clusters >= 1:
        raise ValueError(
            "num_clusters>=1 needs the stacked engine (the loop servers "
            f"are flat per-user oracles; got engine={fl.engine!r})")
    if fl.algorithm == "osafl":
        return OSAFLServer(params, fl, num_clients, seed=seed)
    return SERVERS[fl.algorithm](params, fl, num_clients, seed=seed)
