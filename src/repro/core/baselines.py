"""Modified FL baselines (paper Algorithms 6-10).

All baselines share the paper's resource-optimization front end (clients solve
(5) for kappa) and the stale-contribution buffers; only the aggregation rule
differs:

  M-FedAvg   (Alg. 6):  w^{t+1} = (1/U) sum_u w[u]
  M-FedProx  (Alg. 7):  FedAvg aggregation; proximal term mu/2 ||w - w^t||^2
                         in the *local* objective (client-side, see client.py)
  M-FedNova  (Alg. 8):  w^{t+1} = w^t - eta * tau~ * (sum_u p_u k_u) *
                                   sum_u (p_u k_u / sum p k) d[u]
                         (requires D_u and kappa_u at the CS — violates the
                         paper's privacy assumption; kept for comparison)
  M-AFA-CD   (Alg. 9):  w^{t+1} = w^t - eta_g * (1/U) sum_u d[u]
  M-FedDisco (Alg. 10): w^{t+1} = sum_u alpha_u w[u],
                         alpha_u = ReLU(p_u - a*disco_u + b) / sum(...)
                         (requires the client label histogram — also violates
                         the privacy assumption)
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import FLConfig
from repro.core.osafl import ClientUpdate
from repro.core.scores import (tree_add, tree_scale, tree_sub,
                               tree_zeros_like)


class _BufferedServer:
    """Common machinery: per-client contribution buffers + staleness rules."""

    buffers_hold_weights = True      # False => buffers hold normalized grads d

    def __init__(self, params, fl: FLConfig, num_clients: int, seed: int = 0):
        self.params = params
        self.fl = fl
        self.U = num_clients
        self.participated = np.zeros(num_clients, bool)
        if self.buffers_hold_weights:
            self.buffer: List = [params for _ in range(num_clients)]
        else:
            init_d = (tree_scale(params, 1.0 / fl.local_lr)
                      if fl.literal_init_buffer else tree_zeros_like(params))
            self.buffer = [init_d for _ in range(num_clients)]
        self.meta: List[Optional[ClientUpdate]] = [None] * num_clients

    def _ingest(self, updates: Sequence[ClientUpdate], weights: bool):
        for up in updates:
            self.buffer[up.uid] = up.d
            self.participated[up.uid] = True
            self.meta[up.uid] = up
        for u in range(self.U):
            if not self.participated[u]:
                if weights:
                    self.buffer[u] = self.params           # averaging no-op
                elif self.fl.literal_init_buffer:
                    self.buffer[u] = tree_scale(self.params,
                                                1.0 / self.fl.local_lr)
                else:
                    self.buffer[u] = tree_zeros_like(self.params)

    def _mean(self, items, ws):
        out = tree_zeros_like(self.params)
        for it, w in zip(items, ws):
            out = tree_add(out, tree_scale(it, float(w)))
        return out


class FedAvgServer(_BufferedServer):
    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates, weights=True)
        self.params = self._mean(self.buffer, np.full(self.U, 1.0 / self.U))
        return self.params


class FedProxServer(FedAvgServer):
    """Aggregation identical to FedAvg; clients add the proximal term."""
    local_prox = True


class FedNovaServer(_BufferedServer):
    buffers_hold_weights = False

    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates, weights=False)
        sizes = np.array([self.meta[u].data_size if self.meta[u] else 1
                          for u in range(self.U)], float)
        p = sizes / sizes.sum()
        kap = np.array([self.meta[u].kappa if self.meta[u] else 1
                        for u in range(self.U)], float)
        pk = p * kap
        tau_eff = self.fl.fednova_slowdown * pk.sum()
        w = self.fl.local_lr * tau_eff * pk / pk.sum()
        self.params = tree_sub(self.params, self._mean(self.buffer, w))
        return self.params


class AFACDServer(_BufferedServer):
    buffers_hold_weights = False

    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates, weights=False)
        w = np.full(self.U, self.fl.global_lr * self.fl.local_lr / self.U)
        self.params = tree_sub(self.params, self._mean(self.buffer, w))
        return self.params


class FedDiscoServer(_BufferedServer):
    def round(self, updates: Sequence[ClientUpdate]):
        self._ingest(updates, weights=True)
        sizes = np.array([self.meta[u].data_size if self.meta[u] else 1
                          for u in range(self.U)], float)
        p = sizes / sizes.sum()
        disco = np.zeros(self.U)
        for u in range(self.U):
            h = self.meta[u].label_hist if self.meta[u] is not None else None
            if h is not None:
                uniform = np.full_like(h, 1.0 / len(h))
                disco[u] = float(np.linalg.norm(h - uniform))
        a, b = self.fl.feddisco_a, self.fl.feddisco_b
        alpha = np.maximum(p - a * disco + b, 0.0)
        alpha = alpha / max(alpha.sum(), 1e-12)
        self.params = self._mean(self.buffer, alpha)
        return self.params


SERVERS = {
    "fedavg": FedAvgServer,
    "fedprox": FedProxServer,
    "fednova": FedNovaServer,
    "afa_cd": AFACDServer,
    "feddisco": FedDiscoServer,
}


def make_server(params, fl: FLConfig, num_clients: int, seed: int = 0):
    from repro.core.osafl import OSAFLServer
    if fl.algorithm == "osafl":
        return OSAFLServer(params, fl, num_clients, seed=seed)
    return SERVERS[fl.algorithm](params, fl, num_clients, seed=seed)
