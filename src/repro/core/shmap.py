"""Shared mesh/shard_map plumbing for the client-sharded code paths.

Everything that maps cohort rows onto the ``('pod','data')`` client axes of a
mesh lives behind these three helpers so `core/pod.py` (train steps) and
`core/buffer_stacked.py` (sharded FIFO storage) agree on one convention:

  * ``client_axes(mesh)`` — the subset of ('pod','data') present on a mesh;
    every ``(U, ...)`` cohort array is split over exactly these axes.
  * ``client_rows(mesh)`` — the number of shards the client dimension is cut
    into (U must be a multiple; each shard holds U/rows whole clients).
  * ``shard_map(...)`` — version-compatible wrapper: jax >= 0.6 exports
    ``jax.shard_map`` taking ``axis_names``/``check_vma``; 0.4.x has the
    experimental API taking ``check_rep``. Replication checks are off in both
    — the pod engines emit unreplicated per-client scalars, and the buffer
    ops are purely row-local.
"""
from __future__ import annotations

import inspect

try:                                    # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_KWARGS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """Version-compatible shard_map (see module docstring)."""
    if "check_vma" in _SM_KWARGS:
        kw = dict(check_vma=False)
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def use_mesh(mesh):
    """Version-compatible ambient-mesh context: jax >= 0.5 wants
    ``jax.sharding.set_mesh`` (the ``Mesh`` context manager is being phased
    out); 0.4.x has no ``set_mesh``, where ``Mesh`` itself is the context
    manager. Usage: ``with use_mesh(mesh): ...``."""
    import jax
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def client_axes(mesh) -> tuple:
    """The mesh axes the client (cohort) dimension is split over."""
    return tuple(n for n in ("pod", "data") if n in mesh.axis_names)


def client_rows(mesh) -> int:
    """Number of client-axis shards (devices along the client axes)."""
    n = 1
    for a in client_axes(mesh):
        n *= mesh.shape[a]
    return n


def client_sharding(mesh, ndim: int = 1):
    """Explicit ``NamedSharding`` for a ``(U, ...)``-leading cohort array:
    the leading (client) dimension split over the mesh's client axes, every
    trailing dimension replicated. One definition shared by the sharded FIFO
    buffer (``core/buffer_stacked.py``) and the sparse-cohort per-user tables
    (``core/cohort.py``) so both lay clients out identically."""
    from jax.sharding import NamedSharding, PartitionSpec
    axes = client_axes(mesh)
    if not axes:
        raise ValueError(
            f"mesh {mesh} has no client axis (expected 'pod' or 'data' "
            f"in {mesh.axis_names})")
    return NamedSharding(mesh, PartitionSpec(axes, *([None] * (ndim - 1))))
