from repro.core.osafl import ClientUpdate, OSAFLServer, StackedOSAFLServer
from repro.core.baselines import make_server
from repro.core.client import local_train, make_vmapped_local_train
from repro.core.buffer import OnlineBuffer, binomial_arrivals
from repro.core.buffer_stacked import StackedOnlineBuffer
from repro.core.flatten import FlatCodec, make_codec
from repro.core.resource_stacked import (ClientSystemBatch,
                                         optimize_clients_batched,
                                         optimize_round_batched,
                                         sample_channels, stack_clients)

__all__ = ["ClientUpdate", "OSAFLServer", "StackedOSAFLServer", "make_server",
           "local_train", "make_vmapped_local_train", "OnlineBuffer",
           "binomial_arrivals", "StackedOnlineBuffer", "FlatCodec",
           "make_codec", "ClientSystemBatch", "optimize_clients_batched",
           "optimize_round_batched", "sample_channels", "stack_clients"]
