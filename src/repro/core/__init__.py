from repro.core.osafl import ClientUpdate, OSAFLServer, StackedOSAFLServer
from repro.core.baselines import make_server
from repro.core.client import local_train, make_vmapped_local_train
from repro.core.buffer import OnlineBuffer, binomial_arrivals
from repro.core.flatten import FlatCodec, make_codec

__all__ = ["ClientUpdate", "OSAFLServer", "StackedOSAFLServer", "make_server",
           "local_train", "make_vmapped_local_train", "OnlineBuffer",
           "binomial_arrivals", "FlatCodec", "make_codec"]
