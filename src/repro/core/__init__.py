from repro.core.osafl import ClientUpdate, OSAFLServer
from repro.core.baselines import make_server
from repro.core.client import local_train
from repro.core.buffer import OnlineBuffer, binomial_arrivals

__all__ = ["ClientUpdate", "OSAFLServer", "make_server", "local_train",
           "OnlineBuffer", "binomial_arrivals"]
