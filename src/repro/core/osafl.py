"""OSAFL server (paper Algorithm 2).

The CS keeps a per-client contribution buffer d[u], initialized to w^0/eta
(Algorithm 2 line 1). Participating clients overwrite their slot; clients that
have never participated have their slot refreshed to w^t/eta. Scores
Delta_u^t = lambda_u^t (eq. 35) are computed on the *buffer* (eq. 19 averages
all retained contributions) and the global model takes the scored SGD step
(eq. 17): w^{t+1} = w^t - eta~ * eta * sum_u alpha_u Delta_u d[u].
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.scores import (lambda_scores, lambda_scores_sketched,
                               sketch_tree, tree_add, tree_scale, tree_sub,
                               tree_zeros_like)


@dataclass
class ClientUpdate:
    uid: int
    d: object                        # normalized accumulated gradient pytree
    kappa: int
    data_size: int = 0
    label_hist: Optional[np.ndarray] = None   # only consumed by M-FedDisco


class OSAFLServer:
    """Paper-faithful cross-device engine (small models, CPU)."""

    def __init__(self, params, fl: FLConfig, num_clients: int,
                 alphas: Optional[np.ndarray] = None, seed: int = 0):
        self.params = params
        self.fl = fl
        self.U = num_clients
        self.alphas = (np.full(num_clients, 1.0 / num_clients)
                       if alphas is None else alphas)
        # Algorithm 2 line 1 (literal): d[u] <- w^0/eta. The literal reading
        # treats a never-participated client as owning the zero model and
        # sign-flips the global weights under heavy straggling; the default
        # here is the no-op reading (zero update). EXPERIMENTS.md documents
        # the deviation; literal_init_buffer=True restores Algorithm 2.
        init_d = (tree_scale(params, 1.0 / fl.local_lr)
                  if fl.literal_init_buffer else tree_zeros_like(params))
        self.d_buffer: List = [init_d for _ in range(num_clients)]
        self.participated = np.zeros(num_clients, bool)
        self.last_scores = np.ones(num_clients)
        self._sketch_key = jax.random.PRNGKey(seed)

    def round(self, updates: Sequence[ClientUpdate]) -> dict:
        fl = self.fl
        for up in updates:
            self.d_buffer[up.uid] = up.d
            self.participated[up.uid] = True
        for u in range(self.U):
            if not self.participated[u]:
                # Algorithm 2 line 17: refresh never-participated slots
                self.d_buffer[u] = (
                    tree_scale(self.params, 1.0 / fl.local_lr)
                    if fl.literal_init_buffer
                    else tree_zeros_like(self.params))
        if fl.score_sketch_dim:
            sk = jnp.stack([sketch_tree(d, self._sketch_key,
                                        fl.score_sketch_dim)
                            for d in self.d_buffer])
            lam = lambda_scores_sketched(sk, fl.chi)
        else:
            lam = lambda_scores(self.d_buffer, fl.chi)
        if fl.stale_scores:
            # single-pass pod engine semantics: weight THIS round's updates
            # with the PREVIOUS round's scores (lam becomes next round's)
            lam, self._lam_next = getattr(self, "_lam_next",
                                          np.ones(self.U)), lam
        self.last_scores = lam
        step = tree_zeros_like(self.params)
        for u in range(self.U):
            w = float(self.alphas[u] * lam[u])
            step = tree_add(step, tree_scale(self.d_buffer[u], w))
        lr = fl.global_lr * fl.local_lr
        self.params = tree_sub(self.params, tree_scale(step, lr))
        return self.params
