"""OSAFL server (paper Algorithm 2).

The CS keeps a per-client contribution buffer d[u], initialized to w^0/eta
(Algorithm 2 line 1). Participating clients overwrite their slot; clients that
have never participated have their slot refreshed to w^t/eta. Scores
Delta_u^t = lambda_u^t (eq. 35) are computed on the *buffer* (eq. 19 averages
all retained contributions) and the global model takes the scored SGD step
(eq. 17): w^{t+1} = w^t - eta~ * eta * sum_u alpha_u Delta_u d[u].
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.flatten import FlatCodec, make_codec, scatter_updates
from repro.core.scores import (lambda_scores, lambda_scores_sketched,
                               sketch_stacked, sketch_tree, tree_add,
                               tree_scale, tree_sub, tree_zeros_like)


def make_stacked_round_body(fl: FLConfig):
    """The whole stacked OSAFL round — buffer write-back, never-participated
    refresh, eq. 19-21 scores, scored SGD step — as one pure function

        rnd(w, buf, part_prev, lam_prev, d_new, active, alphas, key)
            -> (w, buf, part, lam_use, lam)

    shared by ``StackedOSAFLServer`` (which jits it stand-alone) and the
    one-dispatch engine (``core/round_fused.py``, which inlines it into the
    fused per-round program). Scoring routes through the Pallas kernel or
    the jnp reference per ``fl.score_backend``.
    """
    from repro.kernels.ops import _interpret
    from repro.kernels.ref import scored_reduce_reference
    from repro.kernels.scored_reduce import scored_reduce
    interpret = _interpret()

    def rnd(w, buf, part_prev, lam_prev, d_new, active, alphas, key):
        part = part_prev | active
        buf = jnp.where(active[:, None], d_new, buf)
        # Algorithm 2 line 17: refresh never-participated slots
        refresh = (w / fl.local_lr if fl.literal_init_buffer
                   else jnp.zeros_like(w))
        buf = jnp.where(part[:, None], buf, refresh[None, :])
        if fl.score_sketch_dim:
            sk = sketch_stacked(buf, key, fl.score_sketch_dim)
            mean = jnp.mean(sk, axis=0)
            dots = sk @ mean
            norms = jnp.sum(sk * sk, axis=1)
            msq = jnp.sum(mean * mean)
        else:
            mean = jnp.mean(buf, axis=0)
            if fl.score_backend == "kernel":
                dots, norms, msq = scored_reduce(buf, mean,
                                                 interpret=interpret)
            else:
                dots, norms, msq = scored_reduce_reference(buf, mean)
        cos = dots / jnp.maximum(jnp.sqrt(norms) * jnp.sqrt(msq), 1e-12)
        lam = (fl.chi + cos) / (fl.chi + 1.0)
        # stale_scores: weight THIS round's buffer with the PREVIOUS
        # round's scores (single-pass pod engine semantics)
        lam_use = lam_prev if fl.stale_scores else lam
        step = (alphas * lam_use) @ buf
        w = w - fl.global_lr * fl.local_lr * step
        return w, buf, part, lam_use, lam

    return rnd


@dataclass
class ClientUpdate:
    uid: int
    d: object                        # normalized accumulated gradient pytree
    kappa: int
    data_size: int = 0
    label_hist: Optional[np.ndarray] = None   # only consumed by M-FedDisco


class OSAFLServer:
    """Paper-faithful cross-device engine (small models, CPU)."""

    def __init__(self, params, fl: FLConfig, num_clients: int,
                 alphas: Optional[np.ndarray] = None, seed: int = 0):
        self.params = params
        self.fl = fl
        self.U = num_clients
        self.alphas = (np.full(num_clients, 1.0 / num_clients)
                       if alphas is None else alphas)
        # Algorithm 2 line 1 (literal): d[u] <- w^0/eta. The literal reading
        # treats a never-participated client as owning the zero model and
        # sign-flips the global weights under heavy straggling; the default
        # here is the no-op reading (zero update). EXPERIMENTS.md documents
        # the deviation; literal_init_buffer=True restores Algorithm 2.
        init_d = (tree_scale(params, 1.0 / fl.local_lr)
                  if fl.literal_init_buffer else tree_zeros_like(params))
        self.d_buffer: List = [init_d for _ in range(num_clients)]
        self.participated = np.zeros(num_clients, bool)
        self.last_scores = np.ones(num_clients)
        self._sketch_key = jax.random.PRNGKey(seed)

    def round(self, updates: Sequence[ClientUpdate]) -> dict:
        fl = self.fl
        for up in updates:
            self.d_buffer[up.uid] = up.d
            self.participated[up.uid] = True
        for u in range(self.U):
            if not self.participated[u]:
                # Algorithm 2 line 17: refresh never-participated slots
                self.d_buffer[u] = (
                    tree_scale(self.params, 1.0 / fl.local_lr)
                    if fl.literal_init_buffer
                    else tree_zeros_like(self.params))
        if fl.score_sketch_dim:
            sk = jnp.stack([sketch_tree(d, self._sketch_key,
                                        fl.score_sketch_dim)
                            for d in self.d_buffer])
            lam = lambda_scores_sketched(sk, fl.chi)
        else:
            lam = lambda_scores(self.d_buffer, fl.chi)
        if fl.stale_scores:
            # single-pass pod engine semantics: weight THIS round's updates
            # with the PREVIOUS round's scores (lam becomes next round's)
            lam, self._lam_next = getattr(self, "_lam_next",
                                          np.ones(self.U)), lam
        self.last_scores = lam
        step = tree_zeros_like(self.params)
        for u in range(self.U):
            w = float(self.alphas[u] * lam[u])
            step = tree_add(step, tree_scale(self.d_buffer[u], w))
        lr = fl.global_lr * fl.local_lr
        self.params = tree_sub(self.params, tree_scale(step, lr))
        return self.params

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Snapshot of everything a round mutates: params, the per-client
        contribution buffer, participation (staleness) flags, eq. 19-21
        scores and the stale-score carry (see repro/checkpoint)."""
        return {"params": self.params,
                "d_buffer": list(self.d_buffer),
                "participated": self.participated,
                "last_scores": np.asarray(self.last_scores),
                "lam_next": getattr(self, "_lam_next", None),
                "sketch_key": np.asarray(self._sketch_key)}

    def load_state_dict(self, sd: dict) -> None:
        as_dev = lambda t: jax.tree.map(jnp.asarray, t)  # noqa: E731
        self.params = as_dev(sd["params"])
        self.d_buffer = [as_dev(d) for d in sd["d_buffer"]]
        self.participated = np.asarray(sd["participated"], bool)
        self.last_scores = np.asarray(sd["last_scores"])
        if sd.get("lam_next") is not None:
            self._lam_next = np.asarray(sd["lam_next"])
        else:
            self.__dict__.pop("_lam_next", None)
        self._sketch_key = jnp.asarray(sd["sketch_key"])


class StackedOSAFLServer:
    """Vectorized Algorithm 2: the same semantics as ``OSAFLServer`` (which is
    kept as the exact-parity reference), but every client's contribution is a
    row of one (U, N) float32 buffer and the whole round — buffer write-back,
    never-participated refresh, scores, scored SGD step — is a single jitted
    function. Scoring routes through the fused Pallas kernel
    ``kernels/scored_reduce.py`` (``fl.score_backend="kernel"``, interpret
    mode on CPU) or the pure-jnp oracle ``kernels/ref.py``
    (``fl.score_backend="reference"``).

    Two entry points:
      * ``round(updates)`` — drop-in for the loop server: a sparse list of
        ``ClientUpdate`` pytrees (or pre-flattened (N,) rows) is scattered
        into the dense buffer.
      * ``round_stacked(d_new, active)`` — the scale path: a dense (U, N)
        update matrix (e.g. from ``client.make_vmapped_local_train``) plus a
        participation mask, with no per-client Python work at all.
    """

    def __init__(self, params, fl: FLConfig, num_clients: int,
                 alphas: Optional[np.ndarray] = None, seed: int = 0):
        self.fl = fl
        self.U = num_clients
        self.codec: FlatCodec = make_codec(params)
        self.alphas = jnp.asarray(
            np.full(num_clients, 1.0 / num_clients) if alphas is None
            else alphas, jnp.float32)
        self.w = self.codec.flatten(params)
        self.d_buffer = jnp.tile(self.init_row()[None, :], (num_clients, 1))
        self.participated = jnp.zeros(num_clients, bool)
        self.last_scores = np.ones(num_clients)
        self._lam_prev = jnp.ones(num_clients, jnp.float32)
        self._sketch_key = jax.random.PRNGKey(seed)
        self._round_fn = jax.jit(make_stacked_round_body(fl))

    @property
    def params(self):
        return self.codec.unflatten(self.w)

    def init_row(self) -> jnp.ndarray:
        """The (N,) refresh value of a slot holding no live contribution
        (Algorithm 2 line 17 semantics): w/eta under the literal init, zeros
        otherwise. The sparse-cohort engine (``core/cohort.py``) writes this
        into a slot at admission — an evicted client's contribution row is
        slot-resident and lost, so a readmitted client restarts from it."""
        return (self.w / self.fl.local_lr if self.fl.literal_init_buffer
                else jnp.zeros_like(self.w))

    def round_stacked(self, d_new: jnp.ndarray, active) -> jnp.ndarray:
        """d_new: (U, N) f32 update matrix; active: (U,) bool mask. Returns
        the new flat global weights (use ``.params`` for the pytree view)."""
        (self.w, self.d_buffer, self.participated, lam_use,
         self._lam_prev) = self._round_fn(
            self.w, self.d_buffer, self.participated, self._lam_prev,
            d_new, jnp.asarray(active), self.alphas, self._sketch_key)
        self.last_scores = np.asarray(lam_use)
        return self.w

    def round(self, updates: Sequence[ClientUpdate]) -> dict:
        d_new, active = scatter_updates(self.codec, updates, self.U)
        self.round_stacked(jnp.asarray(d_new), active)
        return self.params

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Flat-vector counterpart of ``OSAFLServer.state_dict``: the global
        weights, the (U, N) contribution buffer, participation flags and both
        score vectors (current + stale-score carry)."""
        return {"w": self.w, "d_buffer": self.d_buffer,
                "participated": self.participated,
                "last_scores": np.asarray(self.last_scores),
                "lam_prev": self._lam_prev,
                "sketch_key": np.asarray(self._sketch_key)}

    def load_state_dict(self, sd: dict) -> None:
        self.w = jnp.asarray(sd["w"])
        self.d_buffer = jnp.asarray(sd["d_buffer"])
        self.participated = jnp.asarray(np.asarray(sd["participated"], bool))
        self.last_scores = np.asarray(sd["last_scores"])
        self._lam_prev = jnp.asarray(sd["lam_prev"])
        self._sketch_key = jnp.asarray(sd["sketch_key"])
