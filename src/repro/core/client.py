"""Client-side local training (paper eqs. 14-16).

A client synchronizes to the global model, performs kappa_u^t mini-batch SGD
steps on its current FIFO dataset, and returns the *normalized accumulated
gradient* d_u^t = (w^{t,0} - w^{t,kappa}) / (eta * kappa). Supports the
FedProx proximal local objective (Algorithm 7).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffer import OnlineBuffer
from repro.core.scores import tree_scale, tree_sub


@partial(jax.jit, static_argnames=("grad_fn", "prox_mu"))
def _sgd_step(params, batch, lr, grad_fn, prox_mu=0.0, global_params=None):
    g = grad_fn(params, batch)
    if prox_mu:
        g = jax.tree.map(lambda gg, w, w0: gg + prox_mu * (w - w0),
                         g, params, global_params)
    return jax.tree.map(lambda w, gg: w - lr * gg, params, g)


def local_train(global_params, grad_fn: Callable, buffer: OnlineBuffer,
                kappa: int, lr: float, batch_size: int,
                rng: np.random.Generator, prox_mu: float = 0.0
                ) -> Tuple[dict, dict]:
    """Run kappa local SGD steps. Returns (d_u, w_final)."""
    params = global_params
    for _ in range(kappa):
        bx, by = buffer.sample_batch(rng, batch_size)
        batch = {"x": jnp.asarray(bx), "y": jnp.asarray(by)}
        params = _sgd_step(params, batch, lr, grad_fn,
                           prox_mu=prox_mu,
                           global_params=global_params if prox_mu else None)
    d = tree_scale(tree_sub(global_params, params), 1.0 / (lr * kappa))
    return d, params


def make_local_train_body(grad_fn: Callable, lr: float, kappa_max: int,
                          prox_mu: float = 0.0) -> Callable:
    """One client's masked local-SGD body,
    ``one_client(global_params, batch_u, kappa_u) -> (d_u, w_u)`` with
    ``batch_u`` leaves of shape (kappa_max, B, ...): kappa_u real SGD steps
    (steps past kappa_u are masked no-ops; kappa_u == 0 — a straggler —
    yields d_u = 0) and the normalized accumulated gradient. This is the
    single per-client unit of work; ``make_vmapped_local_train`` vmaps it
    for the stacked engine and the pod online steps (``core/pod.py``) run it
    per mesh row inside shard_map / a client scan, so all engines share the
    exact same local-training math.
    """

    def one_client(global_params, batch_u, kappa_u):
        def body(params, inp):
            batch_t, t = inp
            stepped = _sgd_step(
                params, batch_t, lr, grad_fn, prox_mu=prox_mu,
                global_params=global_params if prox_mu else None)
            params = jax.tree.map(
                lambda n, o: jnp.where(t < kappa_u, n, o), stepped, params)
            return params, None

        steps = jnp.arange(kappa_max)
        params, _ = jax.lax.scan(body, global_params, (batch_u, steps))
        denom = lr * jnp.maximum(kappa_u, 1).astype(jnp.float32)
        d = jax.tree.map(lambda w0, w: (w0 - w) / denom,
                         global_params, params)
        return d, params

    return one_client


def make_vmapped_local_train(grad_fn: Callable, lr: float, kappa_max: int,
                             prox_mu: float = 0.0) -> Callable:
    """Vectorized local training for the stacked engine: every client runs its
    kappa_u local SGD steps in lockstep under one ``jax.vmap``, so a whole
    cohort trains in a single XLA computation instead of U Python loops.

    Returns a jitted ``fn(global_params, batches, kappas) -> (d, w)`` where
    ``batches`` is a pytree with leaves of shape (U, kappa_max, B, ...),
    ``kappas`` is (U,) int with values in [0, kappa_max], and the outputs are
    stacked pytrees with a leading client axis. Semantics match
    ``local_train`` step-for-step on the same batch sequence (the per-client
    body is ``make_local_train_body``).
    """
    one_client = make_local_train_body(grad_fn, lr, kappa_max,
                                       prox_mu=prox_mu)
    return jax.jit(jax.vmap(one_client, in_axes=(None, 0, 0)))
