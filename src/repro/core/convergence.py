"""Theorem 1 convergence-bound calculator (paper eq. 24) and special cases.

Used (a) as an analysis tool over recorded training runs, (b) by the tests to
verify the structural claims of the theory (B_u >= 0, FedAvg reduction under
IID + equal kappa + Delta = 1, error-term scaling with kappa), and (c) by the
score optimizer derivation check (eq. 34: stationarity of the Lagrangian).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class BoundHypers:
    beta: float = 1.0        # smoothness
    sigma2: float = 0.1      # stochastic-gradient variance bound
    rho1: float = 1.0        # gradient dissimilarity (multiplicative)
    rho2: float = 0.0        # gradient dissimilarity (additive)
    eta: float = 0.05        # local lr
    eta_g: float = 1.0       # global lr


def b_term(delta: np.ndarray, lam: np.ndarray) -> np.ndarray:
    """B_u^t = (Delta - lam)^2 + lam^2 >= 0."""
    return (delta - lam) ** 2 + lam ** 2


def a_term(h: BoundHypers, alpha, kappa, B) -> float:
    """A^t = 1 - 16 rho1 beta^2 eta^2 sum_u alpha_u kappa_u^2 B_u."""
    return float(1.0 - 16 * h.rho1 * (h.beta * h.eta) ** 2
                 * np.sum(alpha * kappa ** 2 * B))


def round_bound(h: BoundHypers, loss_t: float, loss_t1: float,
                alpha: np.ndarray, kappa: np.ndarray, delta: np.ndarray,
                lam: np.ndarray, phi: np.ndarray, dshift: np.ndarray
                ) -> dict:
    """One round's bracket of eq. 24, returned per error source."""
    B = b_term(delta, lam)
    A = a_term(h, alpha, kappa, B)
    descent = 2.0 * (loss_t - loss_t1) / (h.eta * h.eta_g)
    sgd_noise = h.beta * h.eta * h.sigma2 * np.sum(
        alpha * (h.eta_g * alpha * delta ** 2 + 4 * h.beta * h.eta * kappa * B))
    shift_err = 32 * (h.beta * h.eta) ** 2 * np.sum(alpha * B * phi * kappa ** 2)
    hetero_err = 16 * h.rho2 * (h.beta * h.eta) ** 2 * np.sum(
        alpha * dshift * B * kappa ** 2)
    total = (descent + sgd_noise + shift_err + hetero_err) / max(A, 1e-9)
    return {"A": A, "descent": descent, "sgd_noise": sgd_noise,
            "shift_err": shift_err, "hetero_err": hetero_err, "total": total}


def average_bound(h: BoundHypers, rounds: list[dict]) -> float:
    """(1/T) sum_t bracket_t — the Theorem 1 right-hand side."""
    return float(np.mean([r["total"] for r in rounds]))


def lr_condition(h: BoundHypers, kappa_max: int) -> bool:
    """Theorem 1 prerequisites: eta*eta_g <= 1/beta and eta < 1/(2 sqrt2 beta k)."""
    return (h.eta * h.eta_g <= 1.0 / h.beta + 1e-12 and
            h.eta < 1.0 / (2 * np.sqrt(2) * h.beta * kappa_max))


def fedavg_bound(h: BoundHypers, loss_t: float, loss_t1: float,
                 alpha: np.ndarray, kappa: int, phi: np.ndarray) -> float:
    """Special case eq. 26 (Delta=1, IID, equal kappa)."""
    descent = 2.0 * (loss_t - loss_t1) / (h.eta * h.eta_g)
    noise = h.beta * h.eta * h.sigma2 * np.sum(
        alpha * (h.eta_g * alpha + 4 * h.beta * h.eta * kappa))
    shift = 32 * (h.beta * h.eta * kappa) ** 2 * np.sum(alpha * phi)
    return float(descent + noise + shift)


def optimal_delta(h: BoundHypers, alpha_u: float, kappa_u: float,
                  lam_u: float, phi_u: float, dshift_u: float,
                  gamma_u: float = 0.0) -> float:
    """Eq. 34: Delta_u = (gamma_u + C_u lam_u) / (2 beta eta eta_g sigma2
    alpha_u^2 + C_u). With gamma_u = 0 this approaches lam_u (eq. 35)."""
    bek = h.beta * h.eta * kappa_u
    C = (8 * alpha_u * kappa_u * (h.beta * h.eta) ** 2 * h.sigma2
         + 64 * alpha_u * phi_u * bek ** 2
         + 32 * h.rho2 * alpha_u * dshift_u * bek ** 2
         + 32 * h.rho1 * alpha_u * bek ** 2)
    return float((gamma_u + C * lam_u) /
                 (2 * h.beta * h.eta * h.eta_g * h.sigma2 * alpha_u ** 2 + C))
