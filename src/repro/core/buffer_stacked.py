"""Stacked time-varying FIFO client datasets (paper Section II-A, vectorized).

All U clients' bounded datasets live in one ``(U, D, ...)`` device array
(D = max capacity) with per-client capacity/head/size pointer arrays.
Arrivals are staged during the round and applied FIFO at the round boundary
by one jitted scatter — the closed form of ``core/buffer.py``'s sequential
``_insert`` loop:

  * staged sample j lands in slot ``(head + size + j) mod cap``;
  * of an over-capacity commit only the last ``cap`` staged samples survive
    (earlier ones would be immediately overwritten), so the rest are dropped
    before the scatter and no slot is written twice;
  * ``size`` grows to ``min(size + n, cap)`` and ``head`` advances by the
    overflow ``max(size + n - cap, 0)``.

``core/buffer.py`` remains the semantic oracle: the stacked state (dataset
contents in FIFO order, size, label histogram) must match it exactly over
multi-round runs including wrap-around (tests/test_online_stacked.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class BufState(NamedTuple):
    """Device-array state of all U buffers (a pytree for the jitted ops)."""
    x: jnp.ndarray          # (U, D, *feat) feature storage
    y: jnp.ndarray          # (U, D) labels
    cap: jnp.ndarray        # (U,) int32 per-client capacity D_u (immutable)
    size: jnp.ndarray      # (U,) int32
    head: jnp.ndarray      # (U,) int32 FIFO eviction pointer (oldest sample)
    staged_x: jnp.ndarray   # (U, S, *feat) within-round temp buffer
    staged_y: jnp.ndarray   # (U, S)
    staged_n: jnp.ndarray   # (U,) int32


@jax.jit
def _stage(state: BufState, x_new, y_new, counts) -> BufState:
    """Append ``counts[u]`` of client u's padded arrival rows to its staged
    buffer. Rows beyond counts[u] are padding and are dropped via an
    out-of-range scatter index."""
    U, S = state.staged_y.shape
    j = jnp.arange(x_new.shape[1], dtype=jnp.int32)
    pos = state.staged_n[:, None] + j[None, :]
    pos = jnp.where(j[None, :] < counts[:, None], pos, S)
    uu = jnp.arange(U, dtype=jnp.int32)[:, None]
    return state._replace(
        staged_x=state.staged_x.at[uu, pos].set(x_new, mode="drop"),
        staged_y=state.staged_y.at[uu, pos].set(y_new, mode="drop"),
        staged_n=state.staged_n + counts.astype(state.staged_n.dtype))


@jax.jit
def _commit(state: BufState) -> BufState:
    """Apply all staged arrivals FIFO at the round boundary (one scatter)."""
    U, S = state.staged_y.shape
    D = state.y.shape[1]
    n, c, h, s = state.staged_n, state.cap, state.head, state.size
    j = jnp.arange(S, dtype=jnp.int32)
    # keep only the last cap staged samples; they land in distinct slots
    keep = (j[None, :] < n[:, None]) & (j[None, :] >= (n - c)[:, None])
    slot = ((h + s)[:, None] + j[None, :]) % c[:, None]
    slot = jnp.where(keep, slot, D)
    uu = jnp.arange(U, dtype=jnp.int32)[:, None]
    return state._replace(
        x=state.x.at[uu, slot].set(state.staged_x, mode="drop"),
        y=state.y.at[uu, slot].set(state.staged_y, mode="drop"),
        size=jnp.minimum(s + n, c),
        head=(h + jnp.maximum(s + n - c, 0)) % c,
        staged_n=jnp.zeros_like(n))


@partial(jax.jit, static_argnums=1)
def _histograms(state: BufState, num_classes: int) -> jnp.ndarray:
    """(U, C) normalized label histograms over each client's live window."""
    D = state.y.shape[1]
    p = jnp.arange(D, dtype=jnp.int32)[None, :]
    c, h, s = state.cap[:, None], state.head[:, None], state.size[:, None]
    live = (p < c) & (((p - h) % c) < s)
    onehot = jax.nn.one_hot(state.y, num_classes, dtype=jnp.float32)
    hist = jnp.sum(onehot * live[..., None], axis=1)
    return hist / jnp.maximum(jnp.sum(hist, axis=1, keepdims=True), 1.0)


@dataclass
class StackedOnlineBuffer:
    """Vectorized counterpart of ``OnlineBuffer`` for a whole cohort."""
    state: BufState
    num_classes: int
    last_hist: Optional[np.ndarray] = None

    @classmethod
    def create(cls, capacities, feature_shape: tuple, num_classes: int,
               stage_capacity: Optional[int] = None, dtype=np.float32,
               label_dtype=np.int64) -> "StackedOnlineBuffer":
        caps = np.asarray(capacities, np.int32)
        U, D = caps.shape[0], int(caps.max())
        S = int(stage_capacity) if stage_capacity else D
        feat = tuple(feature_shape)
        dtype = jax.dtypes.canonicalize_dtype(dtype)
        label_dtype = jax.dtypes.canonicalize_dtype(label_dtype)
        state = BufState(
            x=jnp.zeros((U, D) + feat, dtype),
            y=jnp.zeros((U, D), label_dtype),
            cap=jnp.asarray(caps),
            size=jnp.zeros(U, jnp.int32),
            head=jnp.zeros(U, jnp.int32),
            staged_x=jnp.zeros((U, S) + feat, dtype),
            staged_y=jnp.zeros((U, S), label_dtype),
            staged_n=jnp.zeros(U, jnp.int32))
        return cls(state=state, num_classes=num_classes)

    # -- staging (within-round arrivals go to the temp buffer) ---------------
    def stage(self, x_new, y_new, counts) -> None:
        """x_new (U, A, *feat) / y_new (U, A) padded rows; counts (U,) valid
        prefixes. Total staged per client must fit ``stage_capacity``."""
        counts = np.asarray(counts)
        S = self.state.staged_y.shape[1]
        staged = np.asarray(self.state.staged_n) + counts
        if staged.max(initial=0) > S:
            raise ValueError(f"staged {int(staged.max())} > stage_capacity "
                             f"{S}; raise stage_capacity at create()")
        self.state = _stage(self.state, jnp.asarray(x_new),
                            jnp.asarray(y_new),
                            jnp.asarray(counts, jnp.int32))

    def commit(self) -> int:
        """Apply staged arrivals FIFO. Returns total #ingested (cohort)."""
        n = int(np.asarray(self.state.staged_n).sum())
        self.state = _commit(self.state)
        return n

    # -- views ----------------------------------------------------------------
    @property
    def sizes(self) -> np.ndarray:
        return np.asarray(self.state.size)

    @property
    def heads(self) -> np.ndarray:
        return np.asarray(self.state.head)

    @property
    def capacities(self) -> np.ndarray:
        return np.asarray(self.state.cap)

    def dataset(self, u: int) -> Tuple[np.ndarray, np.ndarray]:
        """Client u's live samples in FIFO order (oracle ``dataset()``)."""
        h, s, c = int(self.heads[u]), int(self.sizes[u]),\
            int(self.capacities[u])
        idx = (h + np.arange(s)) % c
        return (np.asarray(self.state.x[u])[idx],
                np.asarray(self.state.y[u])[idx])

    def label_histograms(self) -> np.ndarray:
        return np.asarray(_histograms(self.state, self.num_classes))

    def distribution_shifts(self) -> np.ndarray:
        """(U,) empirical Phi_u^t proxies (oracle ``distribution_shift``)."""
        h = self.label_histograms()
        shift = (np.zeros(h.shape[0]) if self.last_hist is None
                 else np.sum((h - self.last_hist) ** 2, axis=1))
        self.last_hist = h
        return shift

    # -- batch sampling ---------------------------------------------------------
    def sample_slots(self, rng: np.random.Generator, sample_shape: tuple
                     ) -> np.ndarray:
        """(U, *sample_shape) storage slots, uniform over each client's live
        window (empty buffers fall back to slot head)."""
        size = np.maximum(self.sizes, 1)
        U = size.shape[0]
        lead = (U,) + (1,) * len(sample_shape)
        j = rng.integers(0, size.reshape(lead),
                         size=(U,) + tuple(sample_shape))
        return (self.heads.reshape(lead) + j) % self.capacities.reshape(lead)

    def gather(self, slots: np.ndarray) -> dict:
        """Device gather of sampled slots -> batch pytree {x, y} with leaves
        (U, *sample_shape, ...) for the vmapped local trainer."""
        U = slots.shape[0]
        uu = np.arange(U).reshape((U,) + (1,) * (slots.ndim - 1))
        slots = jnp.asarray(slots)
        return {"x": self.state.x[uu, slots], "y": self.state.y[uu, slots]}

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Full snapshot of the cohort state: storage tensors, per-client
        capacity/head/size pointers, staged-but-uncommitted arrivals and the
        shift-proxy memory. Everything needed for a mid-stream resume to be
        bit-identical, including wrap-around and over-capacity staging."""
        s = self.state
        return {
            "x": s.x, "y": s.y, "cap": s.cap, "size": s.size, "head": s.head,
            "staged_x": s.staged_x, "staged_y": s.staged_y,
            "staged_n": s.staged_n,
            "num_classes": int(self.num_classes),
            "last_hist": self.last_hist,
        }

    def load_state_dict(self, sd: dict) -> None:
        """Restore a ``state_dict`` snapshot (full overwrite; the staged
        arrivals resume exactly where they were, committed or not)."""
        self.state = BufState(
            x=jnp.asarray(sd["x"]), y=jnp.asarray(sd["y"]),
            cap=jnp.asarray(sd["cap"]), size=jnp.asarray(sd["size"]),
            head=jnp.asarray(sd["head"]),
            staged_x=jnp.asarray(sd["staged_x"]),
            staged_y=jnp.asarray(sd["staged_y"]),
            staged_n=jnp.asarray(sd["staged_n"]))
        self.num_classes = int(sd["num_classes"])
        lh = sd["last_hist"]
        self.last_hist = None if lh is None else np.asarray(lh)
