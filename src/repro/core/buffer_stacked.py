"""Stacked time-varying FIFO client datasets (paper Section II-A, vectorized).

All U clients' bounded datasets live in one ``(U, D, ...)`` device array
(D = max capacity) with per-client capacity/head/size pointer arrays.
Arrivals are staged during the round and applied FIFO at the round boundary
by one jitted scatter — the closed form of ``core/buffer.py``'s sequential
``_insert`` loop:

  * staged sample j lands in slot ``(head + size + j) mod cap``;
  * of an over-capacity commit only the last ``cap`` staged samples survive
    (earlier ones would be immediately overwritten), so the rest are dropped
    before the scatter and no slot is written twice;
  * ``size`` grows to ``min(size + n, cap)`` and ``head`` advances by the
    overflow ``max(size + n - cap, 0)``.

``core/buffer.py`` remains the semantic oracle: the stacked state (dataset
contents in FIFO order, size, label histogram) must match it exactly over
multi-round runs including wrap-around (tests/test_online_stacked.py).

Mesh-sharded mode (DESIGN.md §3 "Online arrivals"): ``create(..., mesh=...)``
(or ``shard(mesh)``) lays the whole state out over the mesh's
``('pod','data')`` client axes — storage, staging and the cap/head/size
pointer arrays are all ``(U, ...)``-leading, so every leaf gets
``NamedSharding(mesh, P(client_axes, None, ...))`` and each shard owns
U/rows whole clients. Staging and the FIFO commit are purely row-local, so
the sharded ops are the *same* ``_stage``/``_commit`` bodies wrapped in
``shard_map``: per-shard jitted scatters, no cross-shard communication and
no host gather of storage. The pod train steps (``core/pod.py`` online mode)
then sample minibatches from each row's own shard in place.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.shmap import (client_axes, client_rows, client_sharding,
                              shard_map)


class BufState(NamedTuple):
    """Device-array state of all U buffers (a pytree for the jitted ops)."""
    x: jnp.ndarray          # (U, D, *feat) feature storage
    y: jnp.ndarray          # (U, D) labels
    cap: jnp.ndarray        # (U,) int32 per-client capacity D_u (immutable)
    size: jnp.ndarray      # (U,) int32
    head: jnp.ndarray      # (U,) int32 FIFO eviction pointer (oldest sample)
    staged_x: jnp.ndarray   # (U, S, *feat) within-round temp buffer
    staged_y: jnp.ndarray   # (U, S)
    staged_n: jnp.ndarray   # (U,) int32


def _stage_impl(state: BufState, x_new, y_new, counts) -> BufState:
    """Append ``counts[u]`` of client u's padded arrival rows to its staged
    buffer. Rows beyond counts[u] are padding and are dropped via an
    out-of-range scatter index. Row-local: safe to run per shard."""
    U, S = state.staged_y.shape
    j = jnp.arange(x_new.shape[1], dtype=jnp.int32)
    pos = state.staged_n[:, None] + j[None, :]
    pos = jnp.where(j[None, :] < counts[:, None], pos, S)
    uu = jnp.arange(U, dtype=jnp.int32)[:, None]
    return state._replace(
        staged_x=state.staged_x.at[uu, pos].set(x_new, mode="drop"),
        staged_y=state.staged_y.at[uu, pos].set(y_new, mode="drop"),
        staged_n=state.staged_n + counts.astype(state.staged_n.dtype))


_stage = jax.jit(_stage_impl)


def _commit_impl(state: BufState) -> BufState:
    """Apply all staged arrivals FIFO at the round boundary (one scatter).
    Row-local: safe to run per shard."""
    U, S = state.staged_y.shape
    D = state.y.shape[1]
    n, c, h, s = state.staged_n, state.cap, state.head, state.size
    j = jnp.arange(S, dtype=jnp.int32)
    # keep only the last cap staged samples; they land in distinct slots
    keep = (j[None, :] < n[:, None]) & (j[None, :] >= (n - c)[:, None])
    slot = ((h + s)[:, None] + j[None, :]) % c[:, None]
    slot = jnp.where(keep, slot, D)
    uu = jnp.arange(U, dtype=jnp.int32)[:, None]
    return state._replace(
        x=state.x.at[uu, slot].set(state.staged_x, mode="drop"),
        y=state.y.at[uu, slot].set(state.staged_y, mode="drop"),
        size=jnp.minimum(s + n, c),
        head=(h + jnp.maximum(s + n - c, 0)) % c,
        staged_n=jnp.zeros_like(n))


_commit = jax.jit(_commit_impl)


@partial(jax.jit, static_argnums=1)
def _histograms(state: BufState, num_classes: int) -> jnp.ndarray:
    """(U, C) normalized label histograms over each client's live window."""
    D = state.y.shape[1]
    p = jnp.arange(D, dtype=jnp.int32)[None, :]
    c, h, s = state.cap[:, None], state.head[:, None], state.size[:, None]
    live = (p < c) & (((p - h) % c) < s)
    onehot = jax.nn.one_hot(state.y, num_classes, dtype=jnp.float32)
    hist = jnp.sum(onehot * live[..., None], axis=1)
    return hist / jnp.maximum(jnp.sum(hist, axis=1, keepdims=True), 1.0)


@dataclass
class StackedOnlineBuffer:
    """Vectorized counterpart of ``OnlineBuffer`` for a whole cohort."""
    state: BufState
    num_classes: int
    last_hist: Optional[np.ndarray] = None
    mesh: Optional[object] = None             # set by shard(); None = 1 host
    _stage_fn: Optional[object] = field(default=None, repr=False)
    _commit_fn: Optional[object] = field(default=None, repr=False)
    _shardings: Optional[BufState] = field(default=None, repr=False)

    @classmethod
    def create(cls, capacities, feature_shape: tuple, num_classes: int,
               stage_capacity: Optional[int] = None, dtype=np.float32,
               label_dtype=np.int64, mesh=None,
               depth: Optional[int] = None) -> "StackedOnlineBuffer":
        """``depth`` overrides the allocated storage depth D (default: the
        max initial capacity). The sparse-cohort harness sizes slot storage
        to the *population*-wide capacity max so any later-admitted client's
        D_u fits the row it is reassigned (``reset_rows``)."""
        caps = np.asarray(capacities, np.int32)
        U, D = caps.shape[0], int(depth if depth is not None else caps.max())
        if int(caps.max()) > D:
            raise ValueError(
                f"storage depth {D} is smaller than the largest initial "
                f"capacity {int(caps.max())}")
        S = int(stage_capacity) if stage_capacity else D
        feat = tuple(feature_shape)
        dtype = jax.dtypes.canonicalize_dtype(dtype)
        label_dtype = jax.dtypes.canonicalize_dtype(label_dtype)
        state = BufState(
            x=jnp.zeros((U, D) + feat, dtype),
            y=jnp.zeros((U, D), label_dtype),
            cap=jnp.asarray(caps),
            size=jnp.zeros(U, jnp.int32),
            head=jnp.zeros(U, jnp.int32),
            staged_x=jnp.zeros((U, S) + feat, dtype),
            staged_y=jnp.zeros((U, S), label_dtype),
            staged_n=jnp.zeros(U, jnp.int32))
        buf = cls(state=state, num_classes=num_classes)
        return buf.shard(mesh) if mesh is not None else buf

    # -- mesh-sharded mode ---------------------------------------------------
    def shard(self, mesh) -> "StackedOnlineBuffer":
        """Lay the whole cohort state out over ``mesh``'s client axes: every
        ``(U, ...)``-leading leaf is split over ``('pod','data')`` so each
        shard owns U/rows whole clients, and stage/commit become per-shard
        jitted scatters (the unchanged row-local ``_stage``/``_commit``
        bodies under ``shard_map`` — no cross-shard communication, no host
        gather of storage). Returns ``self`` for chaining."""
        axes = client_axes(mesh)
        if not axes:
            raise ValueError(
                f"mesh {mesh} has no client axis (expected 'pod' or 'data' "
                f"in {mesh.axis_names})")
        rows = client_rows(mesh)
        U = int(self.state.y.shape[0])
        if U % rows:
            raise ValueError(
                f"cohort size {U} is not divisible by the mesh's {rows} "
                "client rows; each shard must own whole clients")

        def spec(leaf):
            return P(axes, *([None] * (leaf.ndim - 1)))

        shardings = jax.tree.map(
            lambda leaf: client_sharding(mesh, leaf.ndim), self.state)
        state_specs = jax.tree.map(spec, self.state)
        self.state = jax.device_put(self.state, shardings)
        self.mesh = mesh
        self._shardings = shardings
        self._stage_fn = jax.jit(shard_map(
            _stage_impl, mesh=mesh,
            in_specs=(state_specs, spec(self.state.staged_x),
                      spec(self.state.staged_y), P(axes)),
            out_specs=state_specs, axis_names=set(axes)))
        self._commit_fn = jax.jit(shard_map(
            _commit_impl, mesh=mesh, in_specs=(state_specs,),
            out_specs=state_specs, axis_names=set(axes)))
        return self

    # -- staging (within-round arrivals go to the temp buffer) ---------------
    def stage(self, x_new, y_new, counts) -> None:
        """x_new (U, A, *feat) / y_new (U, A) padded rows; counts (U,) valid
        prefixes. Total staged per client must fit ``stage_capacity``."""
        counts = np.asarray(counts)
        S = self.state.staged_y.shape[1]
        staged = np.asarray(self.state.staged_n) + counts
        if staged.max(initial=0) > S:
            raise ValueError(f"staged {int(staged.max())} > stage_capacity "
                             f"{S}; raise stage_capacity at create()")
        fn = self._stage_fn if self._stage_fn is not None else _stage
        self.state = fn(self.state, jnp.asarray(x_new), jnp.asarray(y_new),
                        jnp.asarray(counts, jnp.int32))

    def commit(self) -> int:
        """Apply staged arrivals FIFO. Returns total #ingested (cohort)."""
        n = int(np.asarray(self.state.staged_n).sum())
        fn = self._commit_fn if self._commit_fn is not None else _commit
        self.state = fn(self.state)
        return n

    # -- slot reassignment (sparse-cohort admissions) ------------------------
    def reset_rows(self, rows, capacities) -> None:
        """Reassign storage rows to new clients (slot-pool admission,
        ``core/cohort.py``): each row's capacity becomes the incoming
        client's D_u and its FIFO window and staging empty out. The storage
        tensors are reused in place — the evicted client's samples are dead
        (size = 0 masks them from the live window, histograms and slot
        sampling) and are overwritten as the new resident's arrivals land.
        The shift-proxy memory (``last_hist``) keeps the evicted row until
        the next ``distribution_shifts`` call; the sparse harness does not
        consume it."""
        rows = np.asarray(rows, np.int64).ravel()
        if rows.size == 0:
            return
        caps = np.asarray(capacities, np.int32).ravel()
        if caps.shape != rows.shape:
            raise ValueError(
                f"reset_rows needs one capacity per row (got {rows.size} "
                f"rows, {caps.size} capacities)")
        D = int(self.state.y.shape[1])
        if caps.min(initial=1) < 1 or caps.max(initial=0) > D:
            raise ValueError(
                f"reassigned capacities must lie in [1, {D}] (the allocated "
                f"storage depth); got [{caps.min()}, {caps.max()}]")
        idx = jnp.asarray(rows)
        zero = jnp.zeros(rows.size, jnp.int32)
        st = self.state._replace(
            cap=self.state.cap.at[idx].set(jnp.asarray(caps)),
            size=self.state.size.at[idx].set(zero),
            head=self.state.head.at[idx].set(zero),
            staged_n=self.state.staged_n.at[idx].set(zero))
        if self.mesh is not None:
            # pin the pointer arrays back to their explicit layout — the
            # out-of-jit scatters above don't owe us sharding preservation
            st = st._replace(
                cap=jax.device_put(st.cap, self._shardings.cap),
                size=jax.device_put(st.size, self._shardings.size),
                head=jax.device_put(st.head, self._shardings.head),
                staged_n=jax.device_put(st.staged_n,
                                        self._shardings.staged_n))
        self.state = st

    # -- views ----------------------------------------------------------------
    @property
    def sizes(self) -> np.ndarray:
        return np.asarray(self.state.size)

    @property
    def heads(self) -> np.ndarray:
        return np.asarray(self.state.head)

    @property
    def capacities(self) -> np.ndarray:
        return np.asarray(self.state.cap)

    def dataset(self, u: int) -> Tuple[np.ndarray, np.ndarray]:
        """Client u's live samples in FIFO order (oracle ``dataset()``)."""
        h, s, c = int(self.heads[u]), int(self.sizes[u]),\
            int(self.capacities[u])
        idx = (h + np.arange(s)) % c
        return (np.asarray(self.state.x[u])[idx],
                np.asarray(self.state.y[u])[idx])

    def label_histograms(self) -> np.ndarray:
        return np.asarray(_histograms(self.state, self.num_classes))

    def distribution_shifts(self) -> np.ndarray:
        """(U,) empirical Phi_u^t proxies (oracle ``distribution_shift``)."""
        h = self.label_histograms()
        shift = (np.zeros(h.shape[0]) if self.last_hist is None
                 else np.sum((h - self.last_hist) ** 2, axis=1))
        self.last_hist = h
        return shift

    # -- batch sampling ---------------------------------------------------------
    def sample_slots(self, rng: np.random.Generator, sample_shape: tuple
                     ) -> np.ndarray:
        """(U, *sample_shape) storage slots, uniform over each client's live
        window (empty buffers fall back to slot head)."""
        size = np.maximum(self.sizes, 1)
        U = size.shape[0]
        lead = (U,) + (1,) * len(sample_shape)
        j = rng.integers(0, size.reshape(lead),
                         size=(U,) + tuple(sample_shape))
        return (self.heads.reshape(lead) + j) % self.capacities.reshape(lead)

    def gather(self, slots: np.ndarray) -> dict:
        """Device gather of sampled slots -> batch pytree {x, y} with leaves
        (U, *sample_shape, ...) for the vmapped local trainer."""
        U = slots.shape[0]
        uu = np.arange(U).reshape((U,) + (1,) * (slots.ndim - 1))
        slots = jnp.asarray(slots)
        return {"x": self.state.x[uu, slots], "y": self.state.y[uu, slots]}

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Full snapshot of the cohort state: storage tensors, per-client
        capacity/head/size pointers, staged-but-uncommitted arrivals and the
        shift-proxy memory. Everything needed for a mid-stream resume to be
        bit-identical, including wrap-around and over-capacity staging.
        Mesh-sharded tensors are returned as the live device arrays — the
        checkpoint writer pulls them per addressable shard off the round
        loop (``checkpoint/streaming.py``), so a sharded buffer never
        host-gathers; ``load_state_dict`` re-shards on restore."""
        s = self.state
        return {
            **dict(s._asdict()),
            "num_classes": int(self.num_classes),
            "last_hist": self.last_hist,
        }

    def load_state_dict(self, sd: dict) -> None:
        """Restore a ``state_dict`` snapshot (full overwrite; the staged
        arrivals resume exactly where they were, committed or not). The
        snapshot's storage/pointer arrays are shape- and dtype-checked
        against the live buffer's layout (a snapshot only fits the cohort
        shape it came from), then re-laid out over the mesh when the live
        buffer is sharded."""
        from repro.checkpoint.run_state import CheckpointError
        cur = self.state._asdict()
        missing = sorted(set(cur) - set(sd))
        if missing:
            raise CheckpointError(
                "buffer snapshot is missing keys: " + ", ".join(missing))
        loaded = {}
        for k, want in cur.items():
            got = np.asarray(sd[k])
            if tuple(got.shape) != tuple(want.shape):
                raise CheckpointError(
                    f"buffer snapshot {k!r} has shape {tuple(got.shape)}; "
                    f"the live buffer expects {tuple(want.shape)}")
            if got.dtype != np.dtype(want.dtype):
                raise CheckpointError(
                    f"buffer snapshot {k!r} has dtype {got.dtype}; the live "
                    f"buffer expects {np.dtype(want.dtype)}")
            loaded[k] = jnp.asarray(got)
        state = BufState(**loaded)
        if self.mesh is not None:
            state = jax.device_put(state, self._shardings)
        self.state = state
        self.num_classes = int(sd["num_classes"])
        lh = sd["last_hist"]
        self.last_hist = None if lh is None else np.asarray(lh)
