"""The declarative experiment-configuration compatibility matrix.

One ``ExperimentConfig`` drives five engines (loop oracle, stacked, pod,
fused-stacked, centralized genie) times a request backend, a round backend, a
resource backend, the sparse slot pool, the hierarchical cluster tier and the
scenario layer — not every point of that grid is implemented, and the
rejection rules used to live as ~10 ad-hoc ``ValueError``s scattered through
the ``run_*`` bodies. This module is the single source of truth instead:
``RULES`` is the ordered list of incompatibility predicates, ``resolve()``
evaluates them and returns a ``ResolvedPlan`` (the engine/backed combination
the run will actually execute, with a one-line ``describe()`` the harness
logs and the smoke tools print), and ``ExperimentConfigError`` is the one
uniform error:

    invalid experiment configuration [rule-key]: why

Every ``why`` keeps the load-bearing vocabulary of the historical messages
("request_backend", "slot-pool", "dense-only", ...) — the error *format*
changed, the contracts tests match on did not. Rule order is part of the
contract: the first matching rule names the failure, so broad capability
gaps (e.g. "the fused round is stacked-engine-only") outrank narrower ones.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

ENGINES = ("auto", "loop", "stacked", "pod", "centralized")
POD_ENGINES = ("exact_tp", "recompute", "stale", "fedavg")
ALL_ALGS = ("osafl", "fedavg", "fedprox", "fednova", "afa_cd", "feddisco")

_ENGINE_NOUN = {"loop": "the loop oracle (run_experiment)",
                "centralized": "the centralized genie (run_centralized_sgd)"}


class ExperimentConfigError(ValueError):
    """An ``ExperimentConfig``/algorithm combination outside the implemented
    grid, named by the matrix rule that rejected it."""

    def __init__(self, key: str, why: str):
        self.key = key
        super().__init__(f"invalid experiment configuration [{key}]: {why}")


@dataclasses.dataclass(frozen=True)
class ResolvedPlan:
    """The validated engine/backend combination a run will execute. ``scn``
    is the parsed (unbound) scenario, carried so callers do not re-parse."""
    alg: str
    engine: str                 # loop | stacked | pod | centralized (resolved)
    request_backend: str
    round_backend: str
    resource_backend: str
    pod_engine: Optional[str]   # pod engine flavor; None off the pod path
    cohort_size: int
    participation: float
    num_clusters: int
    num_clients: int
    scenario: str
    scn: object = dataclasses.field(repr=False, default=None)

    def describe(self) -> str:
        """One log line naming the resolved combination — the smoke tools
        and ``launch/dryrun.py --online`` print it so a CI failure names the
        lane's actual configuration."""
        bits = [f"engine={self.engine}"]
        if self.pod_engine:
            bits.append(f"pod_engine={self.pod_engine}")
        bits += [f"alg={self.alg}",
                 f"request={self.request_backend}",
                 f"round={self.round_backend}",
                 f"resource={self.resource_backend}"]
        if self.cohort_size:
            bits.append(f"cohort={self.cohort_size}/{self.num_clients}")
        if self.participation != 1.0:
            bits.append(f"participation={self.participation}")
        if self.num_clusters:
            bits.append(f"clusters={self.num_clusters}")
        if self.scenario:
            bits.append(f"scenario={self.scenario!r}")
        return " ".join(bits)


class Rule(NamedTuple):
    key: str
    bad: Callable[["ResolvedPlan"], bool]     # True = reject
    why: Callable[["ResolvedPlan"], str]


def _oracle(p: ResolvedPlan) -> str:
    return _ENGINE_NOUN.get(p.engine, p.engine)


#: The compatibility matrix, in rejection-priority order. Evaluated against
#: the *resolved* plan (engine "auto" already picked), first match raises.
RULES = (
    Rule("engine",
         lambda p: p.engine not in ENGINES[1:],
         lambda p: f"unknown engine {p.engine!r} "
                   f"(expected one of {ENGINES[1:]})"),
    Rule("algorithm",
         lambda p: p.engine != "centralized" and p.alg not in ALL_ALGS,
         lambda p: f"unknown algorithm {p.alg!r} "
                   f"(expected one of {ALL_ALGS})"),
    Rule("request-backend",
         lambda p: p.request_backend not in ("python", "stacked"),
         lambda p: f"unknown request_backend {p.request_backend!r} "
                   "(expected 'python' or 'stacked')"),
    Rule("round-backend",
         lambda p: p.round_backend not in ("dispatch", "fused"),
         lambda p: f"unknown round_backend {p.round_backend!r} "
                   "(expected 'dispatch' or 'fused')"),
    Rule("resource-backend",
         lambda p: p.resource_backend not in ("x64", "f32"),
         lambda p: f"unknown resource backend {p.resource_backend!r} "
                   "(expected 'x64' or 'f32')"),
    Rule("pod-engine",
         lambda p: p.engine == "pod" and p.pod_engine not in POD_ENGINES,
         lambda p: f"unknown pod_engine {p.pod_engine!r} "
                   f"(expected one of {POD_ENGINES})"),
    Rule("cohort-size",
         lambda p: p.cohort_size
         and not 1 <= p.cohort_size <= p.num_clients,
         lambda p: f"cohort_size must satisfy 1 <= C <= num_clients "
                   f"(got C={p.cohort_size}, "
                   f"num_clients={p.num_clients})"),
    Rule("participation",
         lambda p: not 0.0 < p.participation <= 1.0,
         lambda p: f"participation must lie in (0, 1] "
                   f"(got {p.participation})"),
    Rule("participation-pool",
         lambda p: p.participation < 1.0 and not p.cohort_size,
         lambda p: "participation sampling needs the slot-pool engine: set "
                   "cohort_size (cohort_size=num_clients keeps every user "
                   "resident and only samples the round-active subset)"),
    Rule("num-clusters",
         lambda p: p.num_clusters < 0,
         lambda p: f"num_clusters must be >= 0 (got {p.num_clusters})"),
    Rule("oracle-requests",
         lambda p: p.engine in ("loop", "centralized")
         and p.request_backend != "python",
         lambda p: f"{_oracle(p)} draws from the per-client oracle streams "
                   "and only supports request_backend='python'; the stacked "
                   "Gumbel sampler needs the stacked or pod engine "
                   f"(got {p.request_backend!r})"),
    Rule("oracle-cohort",
         lambda p: p.engine == "loop" and p.cohort_size > 0,
         lambda p: f"{_oracle(p)} is the dense per-client oracle; the "
                   "sparse slot-pool engine (cohort_size/participation) "
                   "needs the stacked or pod engine"),
    Rule("fused-engine",
         lambda p: p.round_backend == "fused" and p.engine != "stacked",
         lambda p: "the fused one-dispatch round runs on the stacked "
                   "engine only; the loop and pod harnesses need "
                   f"round_backend='dispatch' (got engine={p.engine!r})"),
    Rule("rounds-per-dispatch", lambda p: False, lambda p: ""),  # run-time
    Rule("fused-alg",
         lambda p: p.round_backend == "fused" and p.alg != "osafl",
         lambda p: "the fused round implements the OSAFL scored round only "
                   f"(got algorithm={p.alg!r}); run other algorithms with "
                   "round_backend='dispatch'"),
    Rule("fused-requests",
         lambda p: p.round_backend == "fused"
         and p.request_backend != "stacked",
         lambda p: "the fused round draws requests with the stacked Gumbel "
                   "sampler; set request_backend='stacked' "
                   f"(got {p.request_backend!r})"),
    Rule("fused-cohort",
         lambda p: p.round_backend == "fused" and p.cohort_size > 0,
         lambda p: "the fused round is dense-only; run cohort_size>0 with "
                   "round_backend='dispatch' (see core/round_fused.py and "
                   "the ROADMAP hierarchical-aggregation follow-up)"),
    Rule("fused-hierarchy",
         lambda p: p.round_backend == "fused" and p.num_clusters >= 1,
         lambda p: "the fused round aggregates single-tier; run "
                   "num_clusters>=1 with round_backend='dispatch' "
                   "(core/hierarchy.py)"),
    Rule("hier-engine",
         lambda p: p.num_clusters >= 1
         and p.engine in ("loop", "centralized"),
         lambda p: "num_clusters>=1 needs the stacked or pod engine (the "
                   "two-tier round bodies are stacked-buffer ops; got "
                   f"engine={p.engine!r})"),
    Rule("hier-population",
         lambda p: p.num_clusters >= 1
         and p.num_clients % p.num_clusters != 0,
         lambda p: f"num_clusters must divide num_clients (got "
                   f"K={p.num_clusters}, num_clients={p.num_clients}); "
                   "clusters are equal contiguous population blocks"),
    Rule("hier-cohort",
         lambda p: p.num_clusters >= 1 and p.cohort_size
         and p.cohort_size % p.num_clusters != 0,
         lambda p: f"num_clusters must divide cohort_size (got "
                   f"K={p.num_clusters}, C={p.cohort_size}); each cluster "
                   "owns an equal contiguous slot block"),
    Rule("scenario-engine",
         lambda p: p.scn is not None and not p.scn.is_null
         and p.engine in ("loop", "centralized"),
         lambda p: f"{_oracle(p)} does not apply scenario perturbations "
                   f"(got scenario={p.scenario!r}); run scenarios on the "
                   "stacked or pod engine with round_backend='dispatch'"),
    Rule("scenario-fused",
         lambda p: p.round_backend == "fused"
         and p.scn is not None and not p.scn.is_null,
         lambda p: "the fused round does not apply scenario perturbations "
                   f"(got scenario={p.scenario!r}); run scenarios with "
                   "round_backend='dispatch'"),
    Rule("cluster-churn",
         lambda p: p.scn is not None
         and getattr(p.scn, "moves_clusters", False)
         and p.num_clusters > 1 and not p.cohort_size,
         lambda p: "cluster membership churn needs the slot-pool engine: "
                   "set cohort_size>0 so a mover can re-seat in its new "
                   "cluster's slot block (the dense buffer has no "
                   "user->slot indirection)"),
)


def resolve(alg: str, xc, mesh=None, pod_engine: Optional[str] = None,
            rounds_per_dispatch: Optional[int] = None) -> ResolvedPlan:
    """Validate ``(alg, xc)`` against the matrix and return the resolved
    plan. ``engine="auto"`` resolves to ``"pod"`` when a mesh is passed and
    ``"stacked"`` otherwise (``alg="centralized"`` forces the genie).
    ``pod_engine`` overrides ``xc.pod_engine`` (the deprecated pod shim's
    keyword). Raises ``ExperimentConfigError`` on the first matching rule.
    """
    from repro.scenarios import parse_scenario
    engine = xc.engine
    if engine == "auto":
        if alg == "centralized":
            engine = "centralized"
        else:
            engine = "pod" if mesh is not None else "stacked"
    scn = parse_scenario(xc.scenario, seed=xc.seed)
    plan = ResolvedPlan(
        alg=alg, engine=engine,
        request_backend=xc.request_backend,
        round_backend=xc.round_backend,
        resource_backend=xc.resource_backend,
        pod_engine=(pod_engine if pod_engine is not None
                    else getattr(xc, "pod_engine", "exact_tp"))
        if engine == "pod" else None,
        cohort_size=int(xc.cohort_size),
        participation=float(xc.participation),
        num_clusters=int(getattr(xc, "num_clusters", 0)),
        num_clients=int(xc.num_clients),
        scenario=xc.scenario, scn=scn)
    for rule in RULES:
        if rule.key == "rounds-per-dispatch":
            # positional placeholder: rpd is checked by the fused body (it
            # may be overridden per call), listed here so the matrix sweep
            # covers the key
            if (plan.round_backend == "fused"
                    and int(xc.rounds_per_dispatch) < 1):
                raise ExperimentConfigError(
                    rule.key, "rounds_per_dispatch must be >= 1, got "
                    f"{xc.rounds_per_dispatch}")
            continue
        if rule.bad(plan):
            raise ExperimentConfigError(rule.key, rule.why(plan))
    return plan
