"""The unified FL-experiment harness (moved from ``benchmarks/common.py``).

One facade, ``run(alg, xc)``, fronts every engine the repo has grown:

  * ``loop`` — the paper-faithful per-client oracle (v1 blocking snapshots;
    the write-path anchor for v1→v2 checkpoint read compat);
  * ``stacked`` — the vectorized (U, N) engine, with ``round_backend="fused"``
    folding whole rounds into one device dispatch and
    ``cohort_size``/``participation`` switching on the sparse slot-pool
    engine;
  * ``pod`` — the mesh-sharded online harness (``pod_engine`` flavors);
  * ``centralized`` — the pooled-data genie baseline.

``ExperimentConfig.engine`` picks one (``"auto"`` = pod when a mesh is
passed, stacked otherwise); ``repro.harness.compat`` owns the declarative
compatibility matrix that used to live as scattered ``ValueError``s in the
four ``run_*`` entry points. Those old entry points survive as thin
deprecation shims at the bottom of this module (and re-exported from
``benchmarks.common``) so existing callers keep working.

Hierarchy: ``xc.num_clusters`` routes the stacked/pod server through the
two-tier edge-cluster aggregation in ``core/hierarchy.py`` (K=1 is the
bit-exact flat-parity anchor); on the sparse engine the slot pool becomes K
per-cluster blocks, participation sampling stratifies over the live cluster
map, and the ``cluster_churn`` scenario drives membership moves.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from dataclasses import dataclass
from pathlib import Path
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.checkpoint import CheckpointError
from repro.configs.base import FLConfig
from repro.core.baselines import make_server
from repro.core.buffer import OnlineBuffer, binomial_arrivals
from repro.core.buffer_stacked import StackedOnlineBuffer
from repro.core.client import local_train, make_vmapped_local_train
from repro.core.cohort import sample_participants
from repro.core.hierarchy import sample_participants_clustered
from repro.core.osafl import ClientUpdate
from repro.core.pod import (make_fedavg_train_step, make_pod_batch_fn,
                            make_recompute_train_step,
                            make_stale_score_train_step, make_tp_train_step)
from repro.core.resource import (NetworkConfig, make_clients, optimize_round)
from repro.core.resource_stacked import optimize_round_batched, stack_clients
from repro.core.round_fused import FusedEngine
from repro.core.shmap import client_rows
from repro.data.online import (binomial_arrivals_batched, dataset_layout,
                               draw_arrival_batch, load_streams_state,
                               pad_arrival_batch, streams_state_dict)
from repro.data.video_caching import make_population
from repro.data.video_caching_stacked import StackedRequestStream
from repro.harness.compat import (ALL_ALGS, POD_ENGINES,
                                  ExperimentConfigError, ResolvedPlan,
                                  resolve)
from repro.models.small import init_small, small_loss
from repro.scenarios import parse_scenario

_LOG = logging.getLogger("repro.harness")

MODEL_PARAMS = {"fcn": 3_900_000, "cnn": 1_100_000, "squeezenet": 740_000,
                "lstm": 430_000, "mlp": 18_000}


# ---------------------------------------------------------------------------
# checkpoint/resume plumbing (RunState snapshots — see DESIGN.md)
# ---------------------------------------------------------------------------

def checkpoint_path(checkpoint_dir, t: int) -> Path:
    """Canonical snapshot location for the state after round t (1-based:
    a snapshot named round_00003 holds the state with rounds 0-2 done)."""
    return Path(checkpoint_dir) / f"round_{t:05d}"


def _validate_ckpt_args(save_every_k, checkpoint_dir,
                        keep_last=None) -> None:
    if bool(save_every_k) != (checkpoint_dir is not None):
        raise ValueError(
            "save_every_k and checkpoint_dir must be passed together "
            f"(got save_every_k={save_every_k!r}, "
            f"checkpoint_dir={checkpoint_dir!r})")
    if keep_last is not None:
        if not save_every_k:
            raise ValueError(
                "keep_last requires save_every_k/checkpoint_dir (there is "
                "nothing to prune without periodic snapshots)")
        if not isinstance(keep_last, int) or keep_last < 1:
            raise ValueError(
                f"keep_last must be a positive int, got {keep_last!r}")


def _make_ckpt_writer(save_every_k, checkpoint_async: bool, keep_last):
    """The harness's checkpoint writer, or None when checkpointing is off.
    Async (default) = the v2 per-shard background writer: ``submit`` on the
    round loop only walks the state tree, ``close()`` at harness exit is
    the drain barrier that makes resume determinism hold. Blocking = the
    synchronous v1 npz path (the write oracle ``bench_serve.py`` measures
    the async writer against, and the harness-level v1→v2 read-compat
    anchor)."""
    if not save_every_k:
        return None
    if checkpoint_async:
        return checkpoint.AsyncCheckpointWriter(keep_last=keep_last)
    return checkpoint.BlockingCheckpointWriter(keep_last=keep_last)


def _run_shape(xc: "ExperimentConfig", eval_samples: int) -> dict:
    """Everything that must match between the saving and the resuming run
    for the trajectory to continue bit-exactly: the whole ExperimentConfig
    (resume re-derives population/capacities/test set/system params from
    it) except ``rounds`` — resuming into a longer run is the point — and
    except the engine-selection fields ``engine``/``pod_engine`` (the
    executing engine is the snapshot's top-level ``engine`` tag, and the pod
    flavor lives in the pod harness's mesh-layout extra — both already
    compared; a deprecation-shim run pins ``engine`` while a ``run()`` call
    may leave it ``"auto"``, and the two must stay mutually resumable) —
    plus the eval set size. JSON-normalized so it compares against a loaded
    snapshot."""
    cfg = dataclasses.asdict(xc)
    cfg.pop("rounds")
    cfg.pop("engine")
    cfg.pop("pod_engine")
    cfg["capacity"] = list(cfg["capacity"])
    cfg["eval_samples"] = int(eval_samples)
    return cfg


def _check_snapshot(snap: dict, engine: str, alg: str,
                    xc: "ExperimentConfig", eval_samples: int,
                    extra: dict = None) -> None:
    """A snapshot is only resumable into the exact run shape it came from.
    Config fields added after a snapshot was written are absent from its
    saved config; such a run behaved like the field's default, so the
    default is what the snapshot is compared as (keeps pre-existing
    checkpoints resumable when ExperimentConfig grows). ``extra`` holds
    harness-specific shape keys outside ExperimentConfig (the pod harness's
    engine flavor + mesh layout), compared with no default-filling."""
    got = dict(snap.get("config") or {}, engine=snap.get("engine"),
               alg=snap.get("alg"))
    want = dict(_run_shape(xc, eval_samples), engine=engine, alg=alg,
                **(extra or {}))
    base = dataclasses.asdict(ExperimentConfig())
    for k in want:                  # _run_shape owns which fields compare
        if k not in got and k in base:
            got[k] = (list(base[k]) if isinstance(base[k], tuple)
                      else base[k])
    bad = sorted(k for k in set(got) | set(want)
                 if got.get(k) != want.get(k))
    if bad:
        raise CheckpointError(
            "cannot resume: snapshot and run disagree on "
            + ", ".join(f"{k} ({got.get(k)!r} vs {want.get(k)!r})"
                        for k in bad))
    if int(snap["next_round"]) > xc.rounds:
        raise CheckpointError(
            f"snapshot already holds {snap['next_round']} rounds, the run "
            f"asks for {xc.rounds}")


def resume_smoke_config(rounds: int, num_clients: int = 8
                        ) -> "ExperimentConfig":
    """Canonical small online run for the resume-determinism checks — one
    definition shared by tests/test_checkpoint_resume.py and the CI smoke
    tools/resume_smoke.py so they always cover the same run shape."""
    return ExperimentConfig(model="mlp", dataset=2, num_clients=num_clients,
                            rounds=rounds, capacity=(12, 24), arrivals=4,
                            batch=8, seed=5)


@dataclass
class ExperimentConfig:
    model: str = "fcn"
    dataset: int = 1                  # 1 | 2
    num_clients: int = 12
    rounds: int = 25
    capacity: tuple = (80, 160)       # D_u range (reduced from paper 320-640)
    arrivals: int = 8                 # E_u (paper: ceil(32 p_u))
    local_lr: float = 0.1
    global_lr: float = 16.0   # paper tunes 20-35; 16 is stable at T=25
    batch: int = 16
    topk: int = 1                     # K (request-model randomness)
    seed: int = 0
    use_resource_opt: bool = True
    engine: str = "auto"              # auto | loop | stacked | pod |
                                      # centralized — which harness run()
                                      # dispatches to. "auto" = pod when a
                                      # mesh is passed, stacked otherwise
                                      # ("centralized" as the alg forces the
                                      # genie). The deprecated run_* shims
                                      # pin it.
    pod_engine: str = "exact_tp"      # pod local-train flavor (POD_ENGINES);
                                      # consulted on the pod engine only
    request_backend: str = "python"   # python (per-user oracle streams) |
                                      # stacked (batched Gumbel-trick sampler,
                                      # stacked/pod engines only)
    round_backend: str = "dispatch"   # dispatch (multi-program round) |
                                      # fused (one-dispatch device-resident
                                      # round, core/round_fused.py; requires
                                      # alg=osafl + request_backend=stacked,
                                      # stacked engine only)
    resource_backend: str = "x64"     # x64 (scoped-f64 parity oracle) |
                                      # f32 (log-domain, accelerator-native)
    rounds_per_dispatch: int = 1      # fused backend: rounds folded into one
                                      # device dispatch between eval/
                                      # checkpoint boundaries
    cohort_size: int = 0              # C: sparse active-slot pool capacity
                                      # (core/cohort.py). 0 = dense (every
                                      # registered user materialized); >0 =
                                      # only C slots are round-live and
                                      # per-user tables carry the rest.
                                      # cohort_size=num_clients is bit-exact
                                      # vs the dense engines (the parity
                                      # anchor, tests/test_cohort.py).
    participation: float = 1.0        # round-active fraction of the pool
                                      # (Dinh et al. partial participation;
                                      # <1 needs cohort_size>0)
    num_clusters: int = 0             # K: hierarchical edge-cluster
                                      # aggregation (core/hierarchy.py).
                                      # 0 = flat PS (historical path); 1 =
                                      # one cluster through the two-tier
                                      # round body (bit-exact vs flat — the
                                      # parity anchor, tests/
                                      # test_hierarchy.py); >1 = K edge
                                      # clusters score-reduce locally and
                                      # the PS combines the K aggregates
                                      # with cluster-level eq. 19-21 scores.
                                      # Stacked/pod engines, dispatch round
                                      # only; K must divide num_clients (and
                                      # cohort_size when the pool is on).
    cell_radius_m: float = 600.0      # milder than Fig.3's 1 km so the
                                      # reduced-round runs see participants
    scenario: str = ""                # wireless-world scenario spec
                                      # (src/repro/scenarios/): "" = none,
                                      # "null" = empty scenario through the
                                      # hook plumbing (bit-exact vs ""),
                                      # else "+"-composed named
                                      # perturbations seeded by xc.seed.
                                      # Stacked/pod engines only; the fused
                                      # round, the loop oracle and the genie
                                      # accept only ""/"null".

    def validate(self, alg: str = "osafl", mesh=None) -> ResolvedPlan:
        """Check this config against the compatibility matrix
        (``repro.harness.compat.RULES``) for algorithm ``alg`` and return
        the resolved plan; raises ``ExperimentConfigError`` (a
        ``ValueError``) naming the first violated rule."""
        return resolve(alg, self, mesh=mesh)


def _draw(stream, n, dataset):
    return (stream.draw_dataset1(n) if dataset == 1
            else stream.draw_dataset2(n))


# ---------------------------------------------------------------------------
# engine bodies (validated: run() resolves the plan before dispatching here)
# ---------------------------------------------------------------------------

def _run_loop(alg: str, xc: "ExperimentConfig", eval_samples: int,
              save_every_k, checkpoint_dir, resume_from, keep_last):
    """The per-client loop-oracle engine (see ``run_experiment``). Always
    writes synchronous v1 snapshots — it is the write-path anchor for v1→v2
    checkpoint read compat."""
    model = xc.model
    cat, streams = make_population(xc.seed, xc.num_clients, topk=xc.topk)
    rng = np.random.default_rng(xc.seed)
    feat_shape, dtype = dataset_layout(xc.dataset)
    bufs = []
    for s in streams:
        cap = int(rng.integers(*xc.capacity))
        buf = OnlineBuffer.create(cap, feat_shape, 100, dtype=dtype)
        x, y = _draw(s, cap, xc.dataset)
        buf.stage(x, y)
        buf.commit()
        bufs.append(buf)
    # online evaluation: the clients' own *future* requests (paper setting —
    # predicting an unseen user's preference-driven stream is not the task)
    per = max(eval_samples // xc.num_clients, 20)
    tests = [_draw(s, per, xc.dataset) for s in streams]
    tx = np.concatenate([t[0] for t in tests])
    ty = np.concatenate([t[1] for t in tests])
    test_batch = {"x": jnp.asarray(tx), "y": jnp.asarray(ty)}

    grad_fn = jax.grad(lambda p, b: small_loss(p, b, model)[0])
    params = init_small(jax.random.PRNGKey(xc.seed), model)
    glr = xc.global_lr if alg in ("osafl", "afa_cd") else 1.0
    fl = FLConfig(num_clients=xc.num_clients, local_lr=xc.local_lr,
                  global_lr=glr, algorithm=alg)
    server = make_server(params, fl, xc.num_clients, seed=xc.seed)

    net = NetworkConfig()
    clients_sys = make_clients(rng, xc.num_clients,
                               cell_radius_m=xc.cell_radius_m)
    n_params = MODEL_PARAMS.get(model, 1_000_000)

    writer = _make_ckpt_writer(save_every_k, False, keep_last)
    history, start_round = [], 0
    if resume_from is not None:
        snap = checkpoint.load_run_state(resume_from)
        _check_snapshot(snap, "loop", alg, xc, eval_samples)
        checkpoint.set_generator_state(rng, snap["rng"])
        server.load_state_dict(snap["server"])
        for b, sd in zip(bufs, snap["buffers"]):
            b.load_state_dict(sd)
        load_streams_state(streams, snap["streams"])
        history = list(snap["history"])
        start_round = int(snap["next_round"])
    for t in range(start_round, xc.rounds):
        t_start = time.perf_counter()
        if xc.use_resource_opt:
            decisions = optimize_round(rng, net, clients_sys, n_params)
        updates = []
        for c, s in enumerate(streams):
            n = binomial_arrivals(rng, xc.arrivals, s.user.p_ac)
            if n:
                x, y = _draw(s, n, xc.dataset)
                bufs[c].stage(x, y)
            bufs[c].commit()
            kappa = decisions[c].kappa if xc.use_resource_opt else 5
            if kappa < 1:
                continue                      # straggler
            d, w = local_train(
                server.params, grad_fn, bufs[c], kappa, fl.local_lr,
                xc.batch, rng,
                prox_mu=fl.fedprox_mu if alg == "fedprox" else 0.0)
            upd = d if alg in ("osafl", "fednova", "afa_cd") else w
            updates.append(ClientUpdate(
                c, upd, kappa, data_size=bufs[c].size,
                label_hist=bufs[c].label_histogram()))
        server.round(updates)
        loss, m = small_loss(server.params, test_batch, model)
        history.append({"round": t, "test_loss": float(loss),
                        "test_acc": float(m["accuracy"]),
                        "participants": len(updates),
                        "round_s": time.perf_counter() - t_start})
        if save_every_k and (t + 1) % save_every_k == 0:
            writer.submit(
                checkpoint_path(checkpoint_dir, t + 1),
                {"engine": "loop", "alg": alg,
                 "config": _run_shape(xc, eval_samples), "next_round": t + 1,
                 "rng": checkpoint.generator_state(rng),
                 "server": server.state_dict(),
                 "buffers": [b.state_dict() for b in bufs],
                 "streams": streams_state_dict(streams),
                 "history": history},
                metadata={"engine": "loop", "alg": alg, "round": t + 1})
    return history


def _stacked_setup(alg: str, xc: "ExperimentConfig", eval_samples: int,
                   mesh=None, stale_scores: bool = False) -> SimpleNamespace:
    """Deterministic run setup shared by the stacked and pod engine bodies:
    population + request streams, capacities, FIFO-buffer initial fill, eval
    set, params/server, system params. One code path so the two harnesses
    consume the host RNG in exactly the same order — the 1-device-mesh
    metric parity between them rests on it. The only knobs that differ are
    ``mesh`` (the pod harness shards the buffer) and ``stale_scores`` (the
    pod stale engine's server-side score lag); neither touches an RNG.
    Config compatibility is the caller's job (``run()`` resolves the plan
    before dispatching; ``build_fused_engine`` resolves its fused shape)."""
    stacked_req = xc.request_backend == "stacked"
    model = xc.model
    U = xc.num_clients
    sparse = xc.cohort_size > 0
    C = xc.cohort_size if sparse else U
    K = int(xc.num_clusters)
    # scenario layer: pure seeded perturbation schedule (hooks fire only when
    # a perturbation applies, so ""/"null" keep the historical code path —
    # the null-parity anchor, tests/test_scenarios.py)
    scn = parse_scenario(xc.scenario, seed=xc.seed)
    if scn is not None:
        scn.bind(U)
    arr_width = scn.arrival_width(xc.arrivals) if scn else xc.arrivals
    cat, streams = make_population(xc.seed, U, topk=xc.topk)
    rstream = (StackedRequestStream.from_streams(cat, streams, seed=xc.seed)
               if stacked_req else None)
    rng = np.random.default_rng(xc.seed)
    feat_shape, dtype = dataset_layout(xc.dataset)
    lo, hi = xc.capacity
    caps = rng.integers(lo, max(hi, lo + 1), size=U)
    if scn is not None:
        caps = scn.setup_capacities(caps)
    server_fl = FLConfig(num_clients=U, local_lr=xc.local_lr,
                         global_lr=(xc.global_lr
                                    if alg in ("osafl", "afa_cd") else 1.0),
                         algorithm=alg, engine="stacked",
                         request_backend=xc.request_backend,
                         round_backend=xc.round_backend,
                         resource_backend=xc.resource_backend,
                         cohort_size=xc.cohort_size,
                         participation=xc.participation,
                         num_clusters=K,
                         scenario=xc.scenario,
                         stale_scores=stale_scores)
    server = make_server(init_small(jax.random.PRNGKey(xc.seed), xc.model),
                         server_fl, U, seed=xc.seed,
                         mesh=mesh if sparse else None)
    if sparse:
        # initial residents: the first C users in slot order — under
        # hierarchy, the first C/K members of each cluster so every block
        # starts full (== arange(C) at K<=1 with the contiguous static map,
        # the dense-parity and flat-parity anchors)
        server.admit(server.initial_residents())
    cohort0 = server.cohort if sparse else np.arange(U)
    sbuf = StackedOnlineBuffer.create(
        caps[cohort0] if sparse else caps, feat_shape, 100,
        stage_capacity=arr_width, dtype=dtype, mesh=mesh,
        # slot storage must fit any later-admitted resident's capacity
        depth=int(caps.max()) if sparse else None)
    # initial fill (residents only): FIFO commits compose, so ingest the
    # cap_u seed samples in arrival-width chunks rather than sizing the
    # staging area (kept for the whole run) for caps.max()
    if stacked_req:
        filled = np.zeros(U, np.int64)
        target = np.zeros(U, np.int64)
        target[cohort0] = caps[cohort0]
        while (filled < target).any():
            chunk = np.minimum(target - filled, xc.arrivals)
            xs, ys, cnt = rstream.draw(chunk, xc.dataset, xc.arrivals)
            sbuf.stage(xs[cohort0], ys[cohort0], cnt[cohort0])
            sbuf.commit()
            filled += chunk
    else:
        init = [_draw(streams[u], int(caps[u]), xc.dataset) for u in cohort0]
        for off in range(0, int(caps[cohort0].max()), xc.arrivals):
            chunk = [(x[off:off + xc.arrivals], y[off:off + xc.arrivals])
                     if off < len(y) else None for x, y in init]
            sbuf.stage(*pad_arrival_batch(chunk, xc.arrivals, xc.dataset))
            sbuf.commit()
    p_ac = np.array([s.user.p_ac for s in streams])

    per = max(eval_samples // U, 4)
    if stacked_req:
        ex, ey, _ = rstream.draw(np.full(U, per), xc.dataset, per)
        test_batch = {"x": ex.reshape((U * per,) + ex.shape[2:]),
                      "y": ey.reshape(U * per)}
    else:
        tests = [_draw(s, per, xc.dataset) for s in streams]
        test_batch = {
            "x": jnp.asarray(np.concatenate([t[0] for t in tests])),
            "y": jnp.asarray(np.concatenate([t[1] for t in tests]))}

    grad_fn = jax.grad(lambda p, b: small_loss(p, b, model)[0])
    fl = server_fl

    net = NetworkConfig()
    sysb = stack_clients(make_clients(rng, U,
                                      cell_radius_m=xc.cell_radius_m))
    if scn is not None:
        sysb = scn.setup_system(sysb)
    n_params = MODEL_PARAMS.get(model, 1_000_000)
    return SimpleNamespace(
        stacked_req=stacked_req, model=model, U=U, streams=streams,
        rstream=rstream, rng=rng, caps=caps, sbuf=sbuf, p_ac=p_ac,
        test_batch=test_batch, grad_fn=grad_fn, fl=fl, server=server,
        scn=scn, arr_width=arr_width,
        codec=server.codec,
        weights_alg=alg in ("fedavg", "fedprox", "feddisco"),
        prox_mu=fl.fedprox_mu if alg == "fedprox" else 0.0,
        net=net, sysb=sysb, n_params=n_params,
        # sparse-cohort bookkeeping (dense: sparse=False, C=U, no resample).
        # m_active is the flat participation target; the clustered sampler
        # draws ceil(m * n_k / U) per cluster, so a K-cluster round seats at
        # most m + K - 1 users (one rounding unit per cluster)
        sparse=sparse, C=C, K=K,
        m_active=max(1, int(round(xc.participation * C))),
        resample=sparse and (C < U or xc.participation < 1.0))


def _resume_stacked(s: SimpleNamespace, snap: dict) -> tuple:
    """Overwrite the deterministic setup's mutable state from a RunState
    snapshot (shared by the stacked and pod engine bodies; the caller has
    already ``_check_snapshot``-ed it)."""
    checkpoint.set_generator_state(s.rng, snap["rng"])
    s.server.load_state_dict(snap["server"])
    s.sbuf.load_state_dict(snap["buffer"])
    if s.stacked_req:
        s.rstream.load_state_dict(snap["streams"])
    else:
        load_streams_state(s.streams, snap["streams"])
    return list(snap["history"]), int(snap["next_round"])


def _gather_sys(sysb, rows):
    """Cohort rows of a ``ClientSystemBatch`` (every field is (U,))."""
    return dataclasses.replace(
        sysb, **{f.name: getattr(sysb, f.name)[rows]
                 for f in dataclasses.fields(sysb)})


def _draw_round_inputs(s: SimpleNamespace, xc: "ExperimentConfig",
                       t: int) -> tuple:
    """One round of host-side draws, in the canonical order: (sparse only)
    scenario cluster moves + the round-active cohort sample + slot-pool
    admissions, then arrival counts + samples (staged and committed FIFO),
    the resource-optimizer kappas, the straggler mask, and the local-SGD
    batch slots. Returns ``(req_s, kappas, active, slots)`` — all arrays
    slot-indexed (width C; the dense path is the C = U identity). At
    cohort_size=num_clients with full participation the sparse branch
    consumes the host RNG in exactly the dense order (identity gathers, no
    cohort sample), which is what makes the parity anchor bit-exact.

    The scenario layer (``s.scn``, src/repro/scenarios/) perturbs this
    round's inputs at five points — the cluster map (hierarchical runs:
    membership churn, scenario-RNG only), the participation sample
    (availability masks + selection weights), the arrival process (E_u /
    p_ac), the resource-config rows, and the final active mask. Scenario
    draws come from the scenario's own pure (seed, round)-keyed streams,
    never ``s.rng``, and each hook leaves its input untouched when it does
    not fire — so a null scenario consumes the host RNG in exactly the
    unscenarioed order (bit-exact, tests/test_scenarios.py)."""
    t0 = time.perf_counter()
    scn = s.scn
    if (s.sparse and s.K >= 1 and scn is not None and scn.moves_clusters):
        # membership churn first: this round's participation sample and
        # admissions see the round-t cluster map. Movers re-seat in their
        # new block immediately; like any admission, the reassigned slot's
        # FIFO window resets to the incoming user's capacity.
        mv = scn.round_cluster_moves(t, s.U, s.K)
        if mv is not None:
            moved, res = s.server.apply_cluster_moves(*mv)
            if res is not None and res.newly.any():
                s.sbuf.reset_rows(res.slots[res.newly],
                                  s.caps[moved[res.newly]])
    avail = scn.round_available(t, s.U) if scn is not None else None
    sel = None
    if s.sparse:
        if s.resample:
            weights = (scn.round_selection_weights(t, s.U)
                       if scn is not None else None)
            if s.K >= 1:
                # stratified over the live cluster map; delegates verbatim
                # to sample_participants at K=1 (RNG-stream parity)
                sel = sample_participants_clustered(
                    s.rng, s.server.assign, s.K, s.m_active, s.C // s.K,
                    weights=weights, available=avail)
            else:
                sel = sample_participants(s.rng, s.U, s.m_active,
                                          weights=weights, available=avail)
            res = s.server.admit(sel)
            if res.newly.any():
                # a reassigned slot loses the evicted resident's dataset:
                # reset its FIFO window to the incoming user's capacity
                s.sbuf.reset_rows(res.slots[res.newly],
                                  s.caps[sel[res.newly]])
        cohort = s.server.cohort
        p_ac = s.p_ac[cohort]
    else:
        cohort, p_ac = None, s.p_ac
    e_u = xc.arrivals
    if scn is not None:
        e_u, p_ac = scn.round_arrivals(t, e_u, p_ac)
    if avail is not None:
        # departed users generate no arrivals this round
        p_ac = p_ac * (avail[cohort] if s.sparse else avail)
    counts = binomial_arrivals_batched(s.rng, e_u, p_ac)
    if s.stacked_req:
        if s.sparse:
            # the stacked stream state stays (U,)-wide; non-residents draw
            # a zero count so their streams do not advance
            full = np.zeros(s.U, counts.dtype)
            full[cohort] = counts
            xs, ys, cnt = s.rstream.draw(full, xc.dataset, s.arr_width)
            arrivals = (xs[cohort], ys[cohort], cnt[cohort])
        else:
            arrivals = s.rstream.draw(counts, xc.dataset, s.arr_width)
        jax.block_until_ready(arrivals[1])   # honest request_gen_s
    else:
        streams = ([s.streams[u] for u in cohort] if s.sparse
                   else s.streams)
        arrivals = draw_arrival_batch(streams, counts, xc.dataset,
                                      width=s.arr_width)
    req_s = time.perf_counter() - t0
    s.sbuf.stage(*arrivals)
    s.sbuf.commit()
    if xc.use_resource_opt:
        sysb = s.sysb
        if scn is not None:
            sysb = scn.round_system(t, sysb)
        sysb = _gather_sys(sysb, cohort) if s.sparse else sysb
        kappas = optimize_round_batched(s.rng, s.net, sysb, s.n_params,
                                        backend=xc.resource_backend).kappa
    else:
        kappas = np.full(s.C, s.fl.kappa_max)
    active = kappas >= 1                    # kappa = 0 => straggler
    if avail is not None:
        # departed users do not report an update either
        active = active & (avail[cohort] if s.sparse else avail)
    if sel is not None:
        # only the sampled round-active users train; carried residents idle.
        # A freshly admitted slot with zero arrivals has nothing to train on.
        sel_mask = np.zeros(s.C, bool)
        sel_mask[s.server.pool.user_slot[sel]] = True
        active = active & sel_mask & (s.sbuf.sizes > 0)
    slots = s.sbuf.sample_slots(s.rng, (s.fl.kappa_max, xc.batch))
    return req_s, kappas, active, slots


def _server_round(s: SimpleNamespace, alg: str, upd, active, kappas) -> None:
    if alg == "fednova":
        # round_stacked merges sizes/kappas for active clients only, so
        # stragglers keep their last-seen kappa (loop meta semantics)
        s.server.round_stacked(upd, active, sizes=s.sbuf.sizes,
                               kappas=kappas)
    elif alg == "feddisco":
        s.server.round_stacked(upd, active, sizes=s.sbuf.sizes,
                               hists=s.sbuf.label_histograms())
    else:
        s.server.round_stacked(upd, active)


def build_fused_engine(alg: str, xc: "ExperimentConfig",
                       eval_samples: int = 400) -> tuple:
    """Deterministic setup + a ``core/round_fused.FusedEngine`` over it:
    ``(engine, s)`` with ``s`` the ``_stacked_setup`` namespace the engine's
    carries are initialized from / written back to. Shared by the fused
    branch of the stacked engine and the bench/HLO tooling
    (``bench_online.py`` compiles a segment and feeds its optimized HLO to
    ``launch/hlo_analysis.dispatch_report``). Validates the fused shape of
    the compatibility matrix up front, whatever ``xc.round_backend`` says —
    calling this IS choosing the fused round."""
    resolve(alg, dataclasses.replace(xc, engine="stacked",
                                     round_backend="fused"))
    s = _stacked_setup(alg, xc, eval_samples)
    engine = FusedEngine(
        fl=s.fl, codec=s.codec, model=s.model, consts=s.rstream.consts,
        topk=s.rstream.topk, dataset=xc.dataset, arrivals=xc.arrivals,
        batch=xc.batch, p_ac=s.p_ac, sysb=s.sysb, net=s.net,
        n_params=s.n_params, test_batch=s.test_batch, alphas=s.server.alphas,
        sketch_key=s.server._sketch_key, seed=xc.seed,
        use_resource_opt=xc.use_resource_opt,
        resource_backend=xc.resource_backend)
    return engine, s


def _run_fused(alg: str, xc: "ExperimentConfig", eval_samples: int,
               save_every_k, checkpoint_dir, resume_from, checkpoint_async,
               keep_last):
    """The ``round_backend="fused"`` body of the stacked engine: the same
    trajectory state and RunState checkpoints, but rounds execute in
    single-dispatch segments of up to ``xc.rounds_per_dispatch`` (truncated
    at checkpoint boundaries, which are segment boundaries by construction —
    the per-round keying makes the truncation invisible to the trajectory).
    History rows mirror the dispatch engine's; per-round host draws don't
    exist, so ``request_gen_s`` is 0 and ``round_s`` is the fully-synced
    segment wall clock divided by its length."""
    engine, s = build_fused_engine(alg, xc, eval_samples)
    writer = _make_ckpt_writer(save_every_k, checkpoint_async, keep_last)
    history, start_round = [], 0
    if resume_from is not None:
        snap = checkpoint.load_run_state(resume_from)
        _check_snapshot(snap, "stacked", alg, xc, eval_samples)
        history, start_round = _resume_stacked(s, snap)
    carry = engine.init_carry(s.server, s.sbuf, s.rstream, start_round)
    t, outs = start_round, None
    try:
        while t < xc.rounds:
            seg = min(xc.rounds_per_dispatch, xc.rounds - t)
            if save_every_k:
                boundary = (t // save_every_k + 1) * save_every_k
                seg = min(seg, boundary - t)
            t_start = time.perf_counter()
            carry, outs = engine.run_segment(carry, seg)
            outs = jax.tree.map(np.asarray, outs)   # sync: honest round_s
            seg_s = time.perf_counter() - t_start
            engine.check_outputs(outs)
            for i in range(seg):
                history.append({"round": t + i,
                                "test_loss": float(outs["test_loss"][i]),
                                "test_acc": float(outs["test_acc"][i]),
                                "participants": int(outs["participants"][i]),
                                "request_gen_s": 0.0,
                                "round_s": seg_s / seg})
            t += seg
            if save_every_k and t % save_every_k == 0:
                engine.write_back(carry, outs, s.server, s.sbuf, s.rstream)
                writer.submit(
                    checkpoint_path(checkpoint_dir, t),
                    {"engine": "stacked", "alg": alg,
                     "config": _run_shape(xc, eval_samples), "next_round": t,
                     "rng": checkpoint.generator_state(s.rng),
                     "server": s.server.state_dict(),
                     "buffer": s.sbuf.state_dict(),
                     "streams": s.rstream.state_dict(),
                     "history": history},
                    metadata={"engine": "stacked", "alg": alg, "round": t})
        if writer is not None:
            writer.close()          # drain barrier: all snapshots committed
    finally:
        if writer is not None:
            writer.shutdown()
    if outs is not None:
        engine.write_back(carry, outs, s.server, s.sbuf, s.rstream)
    return history


def _run_stacked(alg: str, xc: "ExperimentConfig", eval_samples: int,
                 save_every_k, checkpoint_dir, resume_from, checkpoint_async,
                 keep_last):
    """The dispatch-round stacked engine body (see the deprecated
    ``run_vectorized_experiment`` shim for the full semantics docstring —
    unchanged by the ``run()`` facade)."""
    s = _stacked_setup(alg, xc, eval_samples)
    local_step = make_vmapped_local_train(
        s.grad_fn, s.fl.local_lr, s.fl.kappa_max, prox_mu=s.prox_mu)

    writer = _make_ckpt_writer(save_every_k, checkpoint_async, keep_last)
    history, start_round = [], 0
    if resume_from is not None:
        snap = checkpoint.load_run_state(resume_from)
        _check_snapshot(snap, "stacked", alg, xc, eval_samples)
        history, start_round = _resume_stacked(s, snap)
    try:
        for t in range(start_round, xc.rounds):
            t_start = time.perf_counter()
            req_s, kappas, active, slots = _draw_round_inputs(s, xc, t)
            d, w = local_step(s.server.params, s.sbuf.gather(slots),
                              jnp.asarray(kappas))
            upd = s.codec.flatten_stacked(w if s.weights_alg else d)
            _server_round(s, alg, upd, active, kappas)
            loss, m = small_loss(s.server.params, s.test_batch, s.model)
            # round_s feeds the bench gates: block on every async output of
            # the round (the server round's weights + the committed buffer),
            # not just the eval loss
            jax.block_until_ready((loss, s.server.w, s.sbuf.state))
            history.append({"round": t, "test_loss": float(loss),
                            "test_acc": float(m["accuracy"]),
                            "participants": int(active.sum()),
                            "request_gen_s": req_s,
                            "round_s": time.perf_counter() - t_start})
            if save_every_k and (t + 1) % save_every_k == 0:
                writer.submit(
                    checkpoint_path(checkpoint_dir, t + 1),
                    {"engine": "stacked", "alg": alg,
                     "config": _run_shape(xc, eval_samples),
                     "next_round": t + 1,
                     "rng": checkpoint.generator_state(s.rng),
                     "server": s.server.state_dict(),
                     "buffer": s.sbuf.state_dict(),
                     "streams": (s.rstream.state_dict() if s.stacked_req
                                 else streams_state_dict(s.streams)),
                     "history": history},
                    metadata={"engine": "stacked", "alg": alg,
                              "round": t + 1})
        if writer is not None:
            writer.close()          # drain barrier: all snapshots committed
    finally:
        if writer is not None:
            writer.shutdown()
    return history


def _make_pod_step(pod_engine: str, s: SimpleNamespace, mesh):
    """The online pod local-train step for one engine flavor (all four
    sample their minibatches from the mesh-sharded buffer via
    ``make_pod_batch_fn``; ``core/pod.py`` online mode)."""
    batch_fn = make_pod_batch_fn()
    kw = dict(batch_fn=batch_fn, grad_fn=s.grad_fn, prox_mu=s.prox_mu)
    if pod_engine == "exact_tp":
        step = make_tp_train_step(None, s.fl, mesh, **kw)
    elif pod_engine == "recompute":
        step = make_recompute_train_step(None, s.fl, mesh, s.U, **kw)
    elif pod_engine == "stale":
        step = make_stale_score_train_step(None, s.fl, mesh, s.U, **kw)
    elif pod_engine == "fedavg":
        step = make_fedavg_train_step(None, s.fl, mesh, **kw)
    else:   # unreachable through the harness, which validates up front
        raise ValueError(pod_engine)
    return jax.jit(step)


def _run_pod(alg: str, xc: "ExperimentConfig", pod_engine: str,
             eval_samples: int, mesh, save_every_k, checkpoint_dir,
             resume_from, checkpoint_async, keep_last):
    """The mesh-sharded pod engine body (see the deprecated
    ``run_pod_online_experiment`` shim for the full semantics docstring)."""
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    rows = client_rows(mesh)
    if xc.num_clients % rows:
        raise ValueError(
            f"num_clients {xc.num_clients} is not divisible by the mesh's "
            f"{rows} client rows {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    if xc.cohort_size and xc.cohort_size % rows:
        raise ValueError(
            f"cohort_size {xc.cohort_size} is not divisible by the mesh's "
            f"{rows} client rows (the slot-indexed buffer shards over the "
            "client axes; each shard must own whole slots)")
    if xc.num_clusters > 1 and xc.num_clusters % rows:
        raise ExperimentConfigError(
            "hier-mesh",
            f"num_clusters {xc.num_clusters} is not a multiple of the "
            f"mesh's {rows} client rows: with K>1 each mesh shard must own "
            "whole cluster slot blocks (K=1 spans shards exactly like the "
            "flat buffer and is exempt)")
    s = _stacked_setup(alg, xc, eval_samples, mesh=mesh,
                       stale_scores=pod_engine == "stale")
    pod_step = _make_pod_step(pod_engine, s, mesh)
    mesh_shape = {"pod_engine": pod_engine,
                  "mesh_axes": list(mesh.axis_names),
                  "mesh_shape": [int(n) for n in mesh.devices.shape]}

    writer = _make_ckpt_writer(save_every_k, checkpoint_async, keep_last)
    history, start_round = [], 0
    if resume_from is not None:
        snap = checkpoint.load_run_state(resume_from)
        _check_snapshot(snap, "pod", alg, xc, eval_samples, extra=mesh_shape)
        history, start_round = _resume_stacked(s, snap)
    try:
        for t in range(start_round, xc.rounds):
            t_start = time.perf_counter()
            req_s, kappas, active, slots = _draw_round_inputs(s, xc, t)
            d, w = pod_step(s.server.params, s.sbuf.state.x, s.sbuf.state.y,
                            jnp.asarray(slots), jnp.asarray(kappas))
            upd = s.codec.flatten_stacked(w if s.weights_alg else d)
            _server_round(s, alg, upd, active, kappas)
            loss, m = small_loss(s.server.params, s.test_batch, s.model)
            # same fully-synced round_s convention as the vectorized harness
            jax.block_until_ready((loss, s.server.w, s.sbuf.state))
            history.append({"round": t, "test_loss": float(loss),
                            "test_acc": float(m["accuracy"]),
                            "participants": int(active.sum()),
                            "request_gen_s": req_s,
                            "round_s": time.perf_counter() - t_start})
            if save_every_k and (t + 1) % save_every_k == 0:
                writer.submit(
                    checkpoint_path(checkpoint_dir, t + 1),
                    {"engine": "pod", "alg": alg,
                     "config": dict(_run_shape(xc, eval_samples),
                                    **mesh_shape),
                     "next_round": t + 1,
                     "rng": checkpoint.generator_state(s.rng),
                     "server": s.server.state_dict(),
                     "buffer": s.sbuf.state_dict(),
                     "streams": (s.rstream.state_dict() if s.stacked_req
                                 else streams_state_dict(s.streams)),
                     "history": history},
                    metadata={"engine": "pod", "alg": alg, "round": t + 1,
                              "pod_engine": pod_engine})
        if writer is not None:
            writer.close()          # drain barrier: all snapshots committed
    finally:
        if writer is not None:
            writer.shutdown()
    return history


def _run_centralized(xc: "ExperimentConfig", eval_samples: int):
    """Genie baseline: all clients' current datasets pooled each round."""
    model = xc.model
    cat, streams = make_population(xc.seed, xc.num_clients, topk=xc.topk)
    rng = np.random.default_rng(xc.seed)
    feat_shape, dtype = dataset_layout(xc.dataset)
    bufs = []
    for s in streams:
        cap = int(rng.integers(*xc.capacity))
        buf = OnlineBuffer.create(cap, feat_shape, 100, dtype=dtype)
        x, y = _draw(s, cap, xc.dataset)
        buf.stage(x, y)
        buf.commit()
        bufs.append(buf)
    per = max(eval_samples // xc.num_clients, 20)
    tests = [_draw(s, per, xc.dataset) for s in streams]
    tx = np.concatenate([t[0] for t in tests])
    ty = np.concatenate([t[1] for t in tests])
    test_batch = {"x": jnp.asarray(tx), "y": jnp.asarray(ty)}
    params = init_small(jax.random.PRNGKey(xc.seed), model)
    grad_fn = jax.jit(jax.grad(lambda p, b: small_loss(p, b, model)[0]))
    history = []
    for t in range(xc.rounds):
        for c, s in enumerate(streams):
            n = binomial_arrivals(rng, xc.arrivals, s.user.p_ac)
            if n:
                x, y = _draw(s, n, xc.dataset)
                bufs[c].stage(x, y)
            bufs[c].commit()
        xs, ys = zip(*[b.dataset() for b in bufs])
        X, Y = np.concatenate(xs), np.concatenate(ys)
        for _ in range(5):                     # kappa=5 epochs-ish steps
            idx = rng.integers(0, len(Y), xc.batch * 4)
            g = grad_fn(params, {"x": jnp.asarray(X[idx]),
                                 "y": jnp.asarray(Y[idx])})
            params = jax.tree.map(lambda w, gg: w - xc.local_lr * gg,
                                  params, g)
        loss, m = small_loss(params, test_batch, model)
        history.append({"round": t, "test_loss": float(loss),
                        "test_acc": float(m["accuracy"])})
    return history


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------

def run(alg: str, xc: "ExperimentConfig", *, eval_samples: int = 400,
        mesh=None, save_every_k: int = None, checkpoint_dir=None,
        resume_from=None, checkpoint_async: bool = True,
        keep_last: int = None, pod_engine: str = None):
    """Run one FL experiment; returns per-round test metrics.

    The single entry point over every engine: ``xc.engine`` (or ``"auto"``)
    picks the harness, ``repro.harness.compat`` validates the whole knob
    combination up front (one uniform ``ExperimentConfigError``), and the
    resolved plan is logged on the ``repro.harness`` logger so CI lanes name
    the configuration they actually ran.

      * ``engine="loop"`` — the per-client oracle. Checkpoints are always
        synchronous v1 npz snapshots (the v1→v2 read-compat anchor);
        ``checkpoint_async`` is ignored.
      * ``engine="stacked"`` — the vectorized (U, N) engine;
        ``xc.round_backend="fused"`` runs single-dispatch segments,
        ``xc.cohort_size``/``participation`` the sparse slot pool,
        ``xc.num_clusters`` the hierarchical edge-cluster tier.
      * ``engine="pod"`` — the mesh-sharded online harness; ``mesh``
        defaults to all local devices on one ``('data', 'model'=1)`` mesh
        and ``xc.pod_engine`` (or the ``pod_engine`` kwarg) picks the
        local-train flavor.
      * ``engine="centralized"`` (or ``alg="centralized"``) — the pooled-
        data genie baseline; no checkpointing.
      * ``engine="auto"`` — pod when ``mesh`` is passed, else stacked.

    ``save_every_k``/``checkpoint_dir``/``resume_from``/``keep_last``/
    ``checkpoint_async`` are the RunState snapshot controls shared by every
    checkpointing engine (see the deprecation shims' docstrings for the
    engine-specific detail; semantics are unchanged by the facade)."""
    plan = resolve(alg, xc, mesh=mesh, pod_engine=pod_engine)
    _LOG.info("resolved experiment plan: %s", plan.describe())
    if plan.engine == "centralized":
        if (save_every_k or checkpoint_dir is not None
                or resume_from is not None or keep_last is not None):
            raise ValueError(
                "the centralized genie does not checkpoint (it is a "
                "baseline, not a trajectory to resume); drop the "
                "save_every_k/checkpoint_dir/resume_from/keep_last args")
        return _run_centralized(xc, eval_samples)
    _validate_ckpt_args(save_every_k, checkpoint_dir, keep_last)
    if plan.engine == "loop":
        return _run_loop(alg, xc, eval_samples, save_every_k,
                         checkpoint_dir, resume_from, keep_last)
    if plan.engine == "pod":
        return _run_pod(alg, xc, plan.pod_engine, eval_samples, mesh,
                        save_every_k, checkpoint_dir, resume_from,
                        checkpoint_async, keep_last)
    if plan.round_backend == "fused":
        return _run_fused(alg, xc, eval_samples, save_every_k,
                          checkpoint_dir, resume_from, checkpoint_async,
                          keep_last)
    return _run_stacked(alg, xc, eval_samples, save_every_k, checkpoint_dir,
                        resume_from, checkpoint_async, keep_last)


# ---------------------------------------------------------------------------
# deprecated entry points (thin shims over run())
# ---------------------------------------------------------------------------

def run_experiment(alg: str, xc: "ExperimentConfig", eval_samples: int = 400,
                   save_every_k: int = None, checkpoint_dir=None,
                   resume_from=None, keep_last: int = None):
    """Deprecated: use ``repro.harness.run(alg, xc)`` with
    ``xc.engine="loop"``.

    One FL training run on the paper-faithful per-client loop oracle;
    returns per-round test metrics. With ``save_every_k``/``checkpoint_dir``
    set, a full RunState snapshot (params, contribution buffers, FIFO
    buffers incl. staged arrivals, scores, staleness flags, every Generator
    stream) is written after every k-th round; ``resume_from`` restores one
    and continues the trajectory bit-identically
    (tests/test_checkpoint_resume.py). The loop oracle always writes
    synchronous v1 snapshots — it is the write-path anchor for v1→v2 read
    compat; ``keep_last`` prunes all but the newest N."""
    return run(alg, dataclasses.replace(xc, engine="loop"),
               eval_samples=eval_samples, save_every_k=save_every_k,
               checkpoint_dir=checkpoint_dir, resume_from=resume_from,
               keep_last=keep_last)


def run_vectorized_experiment(alg: str, xc: "ExperimentConfig",
                              eval_samples: int = 400,
                              save_every_k: int = None, checkpoint_dir=None,
                              resume_from=None, checkpoint_async: bool = True,
                              keep_last: int = None):
    """Deprecated: use ``repro.harness.run(alg, xc)`` with
    ``xc.engine="stacked"`` (or leave ``engine="auto"``).

    Stacked-engine counterpart of ``run_experiment``: the whole cohort
    trains under one ``jax.vmap``, the server round is one vectorized
    (U, N)-buffer update, and the paper's full *online* setting runs in
    stacked form too — per-client FIFO buffers with Binomial(E_u, p_ac)
    arrivals (``StackedOnlineBuffer``, committed at round boundaries as one
    jitted scatter) and the joint kappa/f/p resource optimizer
    (``resource_stacked``, all clients in one jitted f64 solve). So
    ``xc.num_clients`` can be hundreds to thousands with no loss of paper
    fidelity; only the request streams themselves stay per-client Python.

    ``save_every_k``/``checkpoint_dir``/``resume_from`` mirror
    ``run_experiment``: full RunState snapshots every k rounds, bit-identical
    mid-stream resume (``_stacked_setup`` re-derives everything
    deterministic from ``xc.seed`` — population, capacities, test set,
    system params — and the snapshot then overwrites all mutable state).
    Snapshots default to the streaming v2 writer (``checkpoint/streaming.py``:
    per-shard files written by a background thread, committed atomically;
    ``close()`` at harness exit is the drain barrier that keeps resume
    determinism); ``checkpoint_async=False`` falls back to the synchronous
    v1 npz save. ``keep_last`` prunes all but the newest N committed
    snapshots after each save (live-server claims are never pruned).

    ``xc.request_backend`` picks the request model: ``"python"`` draws from
    the per-user oracle streams (the last O(U) Python loop per round);
    ``"stacked"`` advances all U users at once with the jitted Gumbel-trick
    sampler (``data/video_caching_stacked.py``, distribution-equivalent —
    see DESIGN.md "Request model"). Both backends share the same population
    parameters, capacities, arrival process and system params per seed.

    ``xc.cohort_size``/``xc.participation`` switch on the sparse-cohort
    engine (``core/cohort.py``): only C slots of round state exist, the
    round-active users are sampled and seated via the slot pool each round,
    and per-round cost scales with C while ``num_clients`` counts registered
    users only. ``cohort_size=num_clients`` is bit-exact against the dense
    path (tests/test_cohort.py); DESIGN.md "Sparse cohorts" has the layout.

    ``xc.num_clusters`` adds the hierarchical edge-cluster tier
    (``core/hierarchy.py``): K per-cluster scored reductions + a PS combine
    over the K aggregates; ``num_clusters=1`` is bit-exact vs the flat PS
    (tests/test_hierarchy.py)."""
    return run(alg, dataclasses.replace(xc, engine="stacked"),
               eval_samples=eval_samples, save_every_k=save_every_k,
               checkpoint_dir=checkpoint_dir, resume_from=resume_from,
               checkpoint_async=checkpoint_async, keep_last=keep_last)


def run_pod_online_experiment(alg: str, xc: "ExperimentConfig",
                              eval_samples: int = 400, mesh=None,
                              pod_engine: str = "exact_tp",
                              save_every_k: int = None, checkpoint_dir=None,
                              resume_from=None, checkpoint_async: bool = True,
                              keep_last: int = None):
    """Deprecated: use ``repro.harness.run(alg, xc, mesh=...)`` with
    ``xc.engine="pod"`` and ``xc.pod_engine`` (or pass a mesh under
    ``engine="auto"``).

    The paper's online setting on the pod engines: the same round as the
    stacked engine — FIFO arrivals, batched resource optimizer, straggler
    masking, stacked server — but the cohort's FIFO datasets live **sharded
    over a device mesh** (``StackedOnlineBuffer`` mesh mode: U split over
    the ``('pod','data')`` client axes) and each mesh row samples its
    local-SGD minibatches from its own buffer shard inside the train step
    (``core/pod.py`` online mode). The server's dense ``(U, N)`` round ops
    consume the sharded update rows under auto-SPMD.

    ``pod_engine`` picks the local-train flavor (``POD_ENGINES``):
    ``exact_tp``/``fedavg`` run every shard's clients under one vmap inside
    a shard_map body; ``recompute`` scans clients sequentially (the
    FSDP-era memory-lean shape) under auto-SPMD; ``stale`` is ``exact_tp``
    plus the §Perf A5 one-round score lag (``FLConfig.stale_scores``,
    applied by the stacked OSAFL server). All four execute the identical
    per-client masked local-SGD math, so on a 1-device mesh this harness
    matches the stacked engine metric-for-metric (the parity anchor —
    tests/test_pod_online.py).

    ``mesh`` defaults to all local devices on one ``('data','model'=1)``
    mesh; fake a multi-device CPU mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (EXPERIMENTS.md
    "Pod online harness"). ``xc.num_clients`` must be a multiple of the
    mesh's client rows — and so must ``xc.cohort_size`` when the sparse
    slot-pool engine is on, and ``xc.num_clusters`` when K>1 (each shard
    must own whole cluster slot blocks; see ``core/hierarchy.py``).
    Checkpointing mirrors the stacked engine (engine tag ``"pod"``): by
    default the streaming v2 writer pulls the mesh-sharded buffer and
    cohort tables *per addressable shard* on a background thread — no host
    gather of the full ``(U, D, ...)`` storage ever happens — and resume
    re-shards the reassembled arrays onto the live mesh
    (``load_state_dict``). A snapshot additionally refuses to resume into a
    different ``pod_engine`` or mesh layout."""
    return run(alg, dataclasses.replace(xc, engine="pod"),
               eval_samples=eval_samples, mesh=mesh, pod_engine=pod_engine,
               save_every_k=save_every_k, checkpoint_dir=checkpoint_dir,
               resume_from=resume_from, checkpoint_async=checkpoint_async,
               keep_last=keep_last)


def run_centralized_sgd(xc: "ExperimentConfig", eval_samples: int = 400):
    """Deprecated: use ``repro.harness.run("centralized", xc)``.

    Genie baseline: all clients' current datasets pooled each round."""
    return run("centralized", dataclasses.replace(xc, engine="centralized"),
               eval_samples=eval_samples)
