"""The unified experiment harness: ``run(alg, xc)`` over every engine.

``repro.harness.run`` is the single entry point (see ``experiments.py``);
``repro.harness.compat`` holds the declarative config-compatibility matrix;
the historical ``run_*`` entry points survive as deprecation shims (also
re-exported from ``benchmarks.common``)."""
from repro.harness.compat import (ALL_ALGS, ENGINES, POD_ENGINES,
                                  ExperimentConfigError, ResolvedPlan,
                                  resolve)
from repro.harness.experiments import (MODEL_PARAMS, ExperimentConfig,
                                       build_fused_engine, checkpoint_path,
                                       resume_smoke_config, run,
                                       run_centralized_sgd, run_experiment,
                                       run_pod_online_experiment,
                                       run_vectorized_experiment)

__all__ = [
    "ALL_ALGS", "ENGINES", "POD_ENGINES", "MODEL_PARAMS",
    "ExperimentConfig", "ExperimentConfigError", "ResolvedPlan", "resolve",
    "run", "build_fused_engine", "checkpoint_path", "resume_smoke_config",
    "run_centralized_sgd", "run_experiment", "run_pod_online_experiment",
    "run_vectorized_experiment",
]
