"""Zamba2 2.7B [arXiv:2411.15242] — Mamba2 backbone + shared attention blocks."""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10_240,
    vocab_size=32_000,
    attention="gqa",
    ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2, chunk_size=64),
    hybrid=HybridConfig(shared_attn_every=6, shared_block_d_ff=10_240),
    source="arXiv:2411.15242",
)
