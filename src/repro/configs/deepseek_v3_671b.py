"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA, 1 shared + 256 routed top-8, MTP."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,                      # routed-expert FFN width
    vocab_size=129_280,
    head_dim=128,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, first_dense_layers=3,
                  d_ff_dense=18_432),
    mtp_depth=1,
    param_dtype="bfloat16",   # >100B: fp32 replicas cannot fit the mesh HBM
    source="arXiv:2412.19437",
)
