"""DeepSeek-Coder 33B [arXiv:2401.14196] — llama-architecture GQA decoder."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19_200,
    vocab_size=32_256,
    attention="gqa",
    source="arXiv:2401.14196",
)
