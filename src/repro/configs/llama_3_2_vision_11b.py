"""Llama-3.2-Vision 11B [hf:meta-llama/Llama-3.2-11B-Vision] —
cross-attn image layers every 5; ViT frontend stubbed (patch embeddings)."""
from repro.configs.base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    attention="gqa",
    vision=VisionConfig(cross_attn_every=5, n_patches=1601, d_vision=1280),
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
