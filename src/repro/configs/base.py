"""Config dataclasses for all supported architectures.

Every assigned architecture is expressed as a ``ModelConfig``; reduced smoke
variants are produced by ``ModelConfig.reduced()``. Configs are plain frozen
dataclasses so they can be hashed into jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 2048
    num_shared_experts: int = 0       # deepseek-v3: 1 shared expert
    dense_residual_d_ff: int = 0      # arctic: dense MLP in parallel with MoE
    first_dense_layers: int = 0       # deepseek-v3: first 3 layers are dense
    d_ff_dense: int = 0               # d_ff of those dense layers
    router_aux_coef: float = 0.001    # load-balance loss coefficient
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2/V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"              # "mamba2" | "xlstm"
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    n_groups: int = 1
    chunk_size: int = 256
    # xlstm-specific
    slstm_every: int = 0              # 0 => none; k => every k-th block is sLSTM
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3334


@dataclass(frozen=True)
class HybridConfig:
    """zamba2-style hybrid: SSM backbone + shared attention block."""
    shared_attn_every: int = 6        # insert shared attention block every k SSM layers
    shared_block_d_ff: int = 10240


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder for enc-dec models (whisper). Frontend (conv/mel) is a stub:
    input_specs provides precomputed frame embeddings of shape (B, n_frames, d)."""
    n_layers: int = 24
    n_frames: int = 1500
    max_decoder_len: int = 448


@dataclass(frozen=True)
class VisionConfig:
    """Cross-attention image layers for VLMs. The ViT is a stub: input_specs
    provides precomputed patch embeddings of shape (B, n_patches, d_vision)."""
    cross_attn_every: int = 5         # every 5th layer is a cross-attn layer
    n_patches: int = 1601
    d_vision: int = 1280


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 => d_model // n_heads
    attention: str = "gqa"            # gqa | mla | none
    qkv_bias: bool = False
    sliding_window: int = 0           # 0 => full attention
    mlp: str = "swiglu"               # swiglu | relu2 | gelu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mtp_depth: int = 0                # deepseek-v3 multi-token prediction heads
    remat: bool = False               # checkpoint each layer (train memory)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    source: str = ""                  # citation
    # numeric precision
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True if decode memory is bounded in context length (long_500k legal)."""
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0 and self.encoder is None

    @property
    def has_decode(self) -> bool:
        """Encoder-only archs have no decode step (none assigned here)."""
        return True

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts, small vocab."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        # keep GQA ratio where possible
        if self.n_kv_heads < self.n_heads:
            n_kv = max(1, n_heads // 2)
        kw: dict = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.head_dim else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            mtp_depth=min(self.mtp_depth, 1),
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 256),
                d_ff_dense=min(self.moe.d_ff_dense, 256) if self.moe.d_ff_dense else 0,
                dense_residual_d_ff=min(self.moe.dense_residual_d_ff, 256)
                if self.moe.dense_residual_d_ff else 0,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16,
                                  v_head_dim=32)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=min(self.ssm.d_state, 16), chunk_size=32)
        if self.hybrid is not None:
            kw["hybrid"] = dataclasses.replace(
                self.hybrid, shared_attn_every=1,
                shared_block_d_ff=min(self.hybrid.shared_block_d_ff, 256))
        if self.encoder is not None:
            kw["encoder"] = dataclasses.replace(
                self.encoder, n_layers=2, n_frames=16, max_decoder_len=64)
        if self.vision is not None:
            kw["vision"] = dataclasses.replace(
                self.vision, cross_attn_every=2, n_patches=16, d_vision=64)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)

INPUT_SHAPE_BY_NAME = {s.name: s for s in INPUT_SHAPES}


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning round configuration (paper Section II/III)."""
    num_clients: int = 16
    kappa_max: int = 5                # κ: max local SGD steps
    local_lr: float = 0.1             # η
    global_lr: float = 1.0            # η̃
    chi: float = 1.0                  # χ score shift control (eq. 21)
    algorithm: str = "osafl"          # osafl|fedavg|fedprox|fednova|afa_cd|feddisco
    fedprox_mu: float = 0.9
    fednova_slowdown: float = 0.1
    feddisco_a: float = 0.2
    feddisco_b: float = 0.1
    score_sketch_dim: int = 0         # 0 = exact scores (paper); >0 = sketched (§Perf)
    stale_scores: bool = False        # use round t-1 scores (§Perf A5 engine)
    engine: str = "loop"              # loop (paper-faithful pytree reference)
                                      # | stacked (vectorized (U, N) engine)
    score_backend: str = "kernel"     # stacked engine scoring: kernel (fused
                                      # Pallas scored_reduce) | reference
                                      # (pure-jnp kernels/ref.py oracle)
    request_backend: str = "python"   # request model: python (per-user
                                      # data/video_caching.py oracle streams)
                                      # | stacked (batched Gumbel-trick
                                      # data/video_caching_stacked.py,
                                      # stacked engine only). Applied at the
                                      # data layer by the cohort harness
                                      # (repro/harness/), recorded
                                      # here; servers never consult it.
    round_backend: str = "dispatch"   # online round execution: dispatch
                                      # (~7 device programs/round with host
                                      # draws between them) | fused (the
                                      # whole round — arrivals, FIFO commit,
                                      # local SGD, scored aggregation,
                                      # resource solve — as ONE jitted
                                      # program, core/round_fused.py; osafl
                                      # + stacked requests only). Applied by
                                      # the cohort harness, recorded here.
    cohort_size: int = 0              # C: active-slot pool capacity of the
                                      # sparse-cohort engine (core/cohort.py).
                                      # 0 = dense (slot index == user id,
                                      # every registered user materialized);
                                      # >0 = only C slots are live and
                                      # per-user score/staleness tables carry
                                      # the rest. cohort_size=num_clients is
                                      # the dense-parity anchor. Applied by
                                      # the cohort harness, recorded here.
    participation: float = 1.0        # per-round participation fraction of
                                      # the slot pool (Dinh et al. partial
                                      # participation; <1 requires
                                      # cohort_size>0). Harness-applied.
    num_clusters: int = 0             # K: hierarchical edge-cluster
                                      # aggregation (core/hierarchy.py).
                                      # 0 = flat PS (the historical path,
                                      # no hierarchy plumbing); 1 = one
                                      # cluster routed through the two-tier
                                      # round body (bit-exact vs flat — the
                                      # parity anchor); >1 = K edge clusters
                                      # score-reduce locally and the PS
                                      # combines the K aggregates with
                                      # cluster-level eq. 19-21 scores.
                                      # Stacked/pod engines only; K must
                                      # divide num_clients (and cohort_size
                                      # when the slot pool is on).
    scenario: str = ""                # composable wireless-world scenario
                                      # spec (src/repro/scenarios/): ""
                                      # = none (the historical code path),
                                      # "null" = empty scenario routed
                                      # through the hook plumbing (bit-exact
                                      # vs ""), else "+"-composed named
                                      # perturbations, e.g.
                                      # "churn(p_away=0.3)+flash_crowd()".
                                      # Applied at the harness hook points
                                      # (repro/harness/), recorded
                                      # here; servers never consult it.
    resource_backend: str = "x64"     # SCA resource solve numerics: x64
                                      # (scoped-f64 parity oracle) | f32
                                      # (log-domain SNR reformulation,
                                      # accelerator-native — see
                                      # core/resource_stacked.py)
    literal_init_buffer: bool = False # Algorithm 2's literal d[u]=w^t/eta for
                                      # never-participated clients (equivalent
                                      # to treating their model as 0; unstable
                                      # under stragglers — see EXPERIMENTS.md)
