"""Nemotron-4 15B [arXiv:2402.16819] — GQA, squared-ReLU MLP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=256_000,
    attention="gqa",
    mlp="relu2",
    source="arXiv:2402.16819",
)
