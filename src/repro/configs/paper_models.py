"""The paper's own four models (FCN/CNN/SqueezeNet1/LSTM), as pseudo-configs.

These are driven by repro.models.small; ModelConfig fields are nominal
(d_model == hidden width) so they can appear in the same registry.
"""
from repro.configs.base import ModelConfig

CONFIGS = {
    "paper-fcn": ModelConfig(name="paper-fcn", arch_type="small", n_layers=3,
                             d_model=1024, n_heads=1, n_kv_heads=1, d_ff=512,
                             vocab_size=100, source="OSAFL paper Fig. 7a"),
    "paper-cnn": ModelConfig(name="paper-cnn", arch_type="small", n_layers=4,
                             d_model=64, n_heads=1, n_kv_heads=1, d_ff=256,
                             vocab_size=100, source="OSAFL paper Fig. 7b"),
    "paper-squeezenet": ModelConfig(name="paper-squeezenet", arch_type="small",
                                    n_layers=5, d_model=128, n_heads=1,
                                    n_kv_heads=1, d_ff=256, vocab_size=100,
                                    source="OSAFL paper [40]"),
    "paper-lstm": ModelConfig(name="paper-lstm", arch_type="small", n_layers=3,
                              d_model=128, n_heads=1, n_kv_heads=1, d_ff=128,
                              vocab_size=100, source="OSAFL paper Fig. 8"),
}
