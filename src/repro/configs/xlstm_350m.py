"""xLSTM 350M [arXiv:2405.04517] — mLSTM blocks with sLSTM every 8th."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                         # mLSTM blocks carry their own up-proj
    vocab_size=50_304,
    attention="none",
    ssm=SSMConfig(kind="xlstm", slstm_every=8, mlstm_proj_factor=2.0,
                  chunk_size=256),
    source="arXiv:2405.04517",
)
