"""Whisper medium [arXiv:2212.04356] — enc-dec; conv/mel frontend stubbed
(input_specs provides precomputed frame embeddings)."""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    n_layers=24,                    # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    attention="gqa",
    mlp="gelu",
    encoder=EncoderConfig(n_layers=24, n_frames=1500, max_decoder_len=448),
    source="arXiv:2212.04356",
)
