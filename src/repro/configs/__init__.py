"""Architecture registry. ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import (FLConfig, InputShape, INPUT_SHAPES,
                                INPUT_SHAPE_BY_NAME, MLAConfig, ModelConfig,
                                MoEConfig, SSMConfig)

ARCH_IDS = (
    "deepseek-v3-671b",
    "arctic-480b",
    "h2o-danube-3-4b",
    "nemotron-4-15b",
    "zamba2-2.7b",
    "whisper-medium",
    "qwen1.5-4b",
    "llama-3.2-vision-11b",
    "xlstm-350m",
    "deepseek-coder-33b",
    # the paper's own models
    "paper-fcn", "paper-cnn", "paper-squeezenet", "paper-lstm",
)

_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "arctic-480b": "arctic_480b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "nemotron-4-15b": "nemotron_4_15b",
    "zamba2-2.7b": "zamba2_2_7b",
    "whisper-medium": "whisper_medium",
    "qwen1.5-4b": "qwen1_5_4b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "xlstm-350m": "xlstm_350m",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "paper-fcn": "paper_models",
    "paper-cnn": "paper_models",
    "paper-squeezenet": "paper_models",
    "paper-lstm": "paper_models",
}

TRANSFORMER_ARCHS = tuple(a for a in ARCH_IDS if not a.startswith("paper-"))


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    if name.startswith("paper-"):
        return mod.CONFIGS[name]
    return mod.CONFIG


__all__ = ["ARCH_IDS", "TRANSFORMER_ARCHS", "get_config", "ModelConfig",
           "MoEConfig", "MLAConfig", "SSMConfig", "FLConfig", "InputShape",
           "INPUT_SHAPES", "INPUT_SHAPE_BY_NAME"]
