"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] —
128 experts top-2 with a dense residual MLP in parallel."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    attention="gqa",
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual_d_ff=4864),
    param_dtype="bfloat16",   # >100B: fp32 replicas cannot fit the mesh HBM
    source="hf:Snowflake/snowflake-arctic-base",
)
