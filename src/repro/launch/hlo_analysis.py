"""HLO text analyzer: loop-aware FLOP / collective-byte / traffic accounting.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE, which makes
scan-over-layers models look ~n_layers times cheaper than they are. This
module parses ``compiled.as_text()`` into computations, builds the call graph
(while/fusion/call/conditional), reads ``known_trip_count`` from while
backend_configs, and accumulates:

  * flops            — 2*prod(result)*prod(contracted) for dots,
                       rough kernel-volume estimate for convolutions
  * collective_bytes — per collective kind (all-reduce, all-gather,
                       reduce-scatter, all-to-all, collective-permute),
                       *per-device* bytes (post-SPMD module shapes)
  * traffic_bytes    — sum of op result+operand bytes at fusion granularity
                       (HBM traffic proxy)

All numbers are per-device; multiply by chip count for mesh totals.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\{\s*$")
_CALLED_RE = re.compile(
    r"(?:body|to_apply|calls|branch_computations|condition)="
    r"\{?(%[\w.\-]+(?:,\s*%[\w.\-]+)*)\}?")
_TRIP_RE = re.compile(r'known_trip_count[\\\"{:n ]+([0-9]+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str                       # operands + attributes (raw tail)
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)   # op name -> type


def parse_module(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for line in txt.splitlines():
        line = comment_re.sub("", line)
        mc = _COMP_RE.match(line) if line and not line.startswith(" ") else None
        if mc:
            name = mc.group(1).lstrip("%")
            cur = Computation(name)
            comps[name] = cur
            continue
        m = _OP_RE.match(line)
        if m and cur is not None:
            name, type_str, opcode, rest = m.groups()
            operands = re.findall(r"%[\w.\-]+", rest.split("),")[0])
            op = Op(name.lstrip("%"), type_str.strip(), opcode, rest,
                    [o.lstrip("%") for o in operands])
            cur.ops.append(op)
            cur.symbols[op.name] = op.type_str
    return comps


def _dims_prod(type_str: str, dims: List[int]) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 1
    shape = [int(d) for d in m.group(2).split(",") if d]
    out = 1
    for d in dims:
        if d < len(shape):
            out *= shape[d]
    return out


def _dot_flops(op: Op, comp: Computation) -> float:
    result = shape_elems(op.type_str)
    lhs = op.operands[0] if op.operands else None
    lhs_type = comp.symbols.get(lhs, "")
    mcd = _CONTRACT_RE.search(op.rest)
    contracted = 1
    if mcd and lhs_type:
        dims = [int(d) for d in mcd.group(1).split(",") if d]
        contracted = _dims_prod(lhs_type, dims)
    return 2.0 * result * contracted


def _conv_flops(op: Op, comp: Computation) -> float:
    result = shape_elems(op.type_str)
    ker = op.operands[1] if len(op.operands) > 1 else None
    ker_type = comp.symbols.get(ker, "")
    ker_elems = shape_elems(ker_type)
    m = _SHAPE_RE.search(op.type_str)
    out_feat = 1
    if m:
        dims = [int(d) for d in m.group(2).split(",") if d]
        out_feat = dims[-1] if dims else 1
    return 2.0 * result * max(ker_elems // max(out_feat, 1), 1)


@dataclass
class Analysis:
    flops: float = 0.0
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    traffic_bytes: float = 0.0
    # HBM traffic of (seq x seq) score-shaped tensors: what a fused flash
    # attention kernel keeps in VMEM (see roofline flash projection)
    score_traffic_bytes: float = 0.0
    seq_len: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def as_dict(self) -> dict:
        return {"flops": self.flops,
                "collective_bytes": dict(self.collective_bytes),
                "collective_counts": dict(self.collective_counts),
                "total_collective_bytes": self.total_collective_bytes,
                "traffic_bytes": self.traffic_bytes}


_SKIP_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "copy", "after-all", "partition-id"}


def _called_computations(op: Op) -> List[str]:
    out = []
    for m in _CALLED_RE.finditer(op.rest):
        for nm in m.group(1).split(","):
            nm = nm.strip().lstrip("%")
            if nm:
                out.append(nm)
    return out


def _is_score_shaped(type_str: str, seq_len: int) -> bool:
    if seq_len < 2048:
        return False
    m = _SHAPE_RE.search(type_str)
    if not m:
        return False
    dims = [int(d) for d in m.group(2).split(",") if d]
    return sum(1 for d in dims if d == seq_len) >= 2


def analyze_computation(name: str, comps: Dict[str, Computation],
                        acc: Analysis, multiplier: float,
                        in_fusion: bool = False, _depth: int = 0) -> None:
    comp = comps.get(name)
    if comp is None or _depth > 64:
        return
    for op in comp.ops:
        oc = op.opcode
        base = oc.replace("-start", "")
        if base in COLLECTIVES and not oc.endswith("-done"):
            if base in ("reduce-scatter", "all-to-all"):
                # count the (larger) input side
                b = sum(shape_bytes(comp.symbols.get(o, ""))
                        for o in op.operands)
                b = max(b, shape_bytes(op.type_str))
            else:
                b = shape_bytes(op.type_str)
            acc.collective_bytes[base] += b * multiplier
            acc.collective_counts[base] += multiplier
        elif oc == "dot":
            acc.flops += _dot_flops(op, comp) * multiplier
        elif oc == "convolution":
            acc.flops += _conv_flops(op, comp) * multiplier
        # traffic at fusion granularity: don't descend into fusions for bytes
        if not in_fusion and oc not in _SKIP_TRAFFIC:
            rb = shape_bytes(op.type_str)
            ob = sum(shape_bytes(comp.symbols.get(o, "")) for o in op.operands)
            acc.traffic_bytes += (rb + ob) * multiplier
            if acc.seq_len and _is_score_shaped(op.type_str, acc.seq_len):
                acc.score_traffic_bytes += (rb + ob) * multiplier
        # recurse into called computations
        if oc == "while":
            trip = 1.0
            mt = _TRIP_RE.search(op.rest)
            if mt:
                trip = float(mt.group(1))
            called = _called_computations(op)
            # body only (condition is cheap)
            for c in called:
                if "region" in c or "body" in c or "while" in c:
                    analyze_computation(c, comps, acc, multiplier * trip,
                                        in_fusion, _depth + 1)
        elif oc in ("fusion",):
            for c in _called_computations(op):
                analyze_computation(c, comps, acc, multiplier, True,
                                    _depth + 1)
        elif oc in ("call", "conditional", "custom-call", "reduce", "sort",
                    "scatter", "select-and-scatter", "map", "reduce-window"):
            for c in _called_computations(op):
                analyze_computation(c, comps, acc, multiplier, in_fusion,
                                    _depth + 1)


def top_collectives(txt: str, n: int = 12) -> list:
    """The n largest collective ops (per-device bytes x trip count), with
    shapes and source metadata — the §Perf diagnosis tool."""
    comps = parse_module(txt)
    trip_of: Dict[str, float] = {}
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                mt = _TRIP_RE.search(op.rest)
                trip = float(mt.group(1)) if mt else 1.0
                for c in _called_computations(op):
                    trip_of[c] = max(trip_of.get(c, 1.0), trip)
    out = []
    for cname, comp in comps.items():
        mult = trip_of.get(cname, 1.0)
        for op in comp.ops:
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                b = shape_bytes(op.type_str)
                meta = ""
                mm = re.search(r'op_name="([^"]*)"', op.rest)
                if mm:
                    meta = mm.group(1)[:90]
                out.append({"kind": base, "bytes": b * mult, "trip": mult,
                            "shape": op.type_str[:80], "op": meta})
    out.sort(key=lambda r: -r["bytes"])
    return out[:n]


def top_traffic(txt: str, n: int = 12) -> list:
    """The n largest HBM-traffic ops (result+operand bytes x trip count)."""
    comps = parse_module(txt)
    trip_of: Dict[str, float] = {}
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                mt = _TRIP_RE.search(op.rest)
                trip = float(mt.group(1)) if mt else 1.0
                for c in _called_computations(op):
                    trip_of[c] = max(trip_of.get(c, 1.0), trip)
    out = []
    for cname, comp in comps.items():
        if "fused" in cname:
            continue
        mult = trip_of.get(cname, 1.0)
        for op in comp.ops:
            if op.opcode in _SKIP_TRAFFIC:
                continue
            rb = shape_bytes(op.type_str)
            ob = sum(shape_bytes(comp.symbols.get(o, "")) for o in op.operands)
            meta = ""
            mm = re.search(r'op_name="([^"]*)"', op.rest)
            if mm:
                meta = mm.group(1)[:90]
            out.append({"opcode": op.opcode, "bytes": (rb + ob) * mult,
                        "trip": mult, "shape": op.type_str[:60], "op": meta})
    out.sort(key=lambda r: -r["bytes"])
    return out[:n]


def while_trip_counts(txt: str) -> List[int]:
    """``known_trip_count`` of every while op in the module, descending.
    (XLA annotates whiles lowered from ``lax.scan``/unrolled loops with
    their static trip count in the backend config.)"""
    return sorted((int(t) for t in _TRIP_RE.findall(txt)), reverse=True)


def dispatch_report(txt: str, rounds_per_dispatch: int = None) -> dict:
    """Single-executable verification for the fused round engine.

    One compiled XLA module is one host->device dispatch per call, so the
    report counts the module's ENTRY computations (must be 1 — a multi-step
    host program would be several modules) and lists the while trip counts,
    which must include ``rounds_per_dispatch`` when given: the
    scan-over-rounds lowers to a while of exactly that trip, proving the k
    rounds really live inside the one executable. ``bench_online.py`` embeds
    this report in the bench-gate JSON artifact."""
    entries = sum(1 for line in txt.splitlines() if line.startswith("ENTRY"))
    modules = sum(1 for line in txt.splitlines()
                  if line.startswith("HloModule"))
    trips = while_trip_counts(txt)
    report = {"entry_computations": entries,
              "hlo_modules": modules,
              "computations": len(parse_module(txt)),
              "while_trip_counts": trips[:16],
              "single_dispatch": entries == 1 and modules == 1}
    if rounds_per_dispatch is not None:
        report["rounds_per_dispatch"] = int(rounds_per_dispatch)
        report["scan_carries_rounds"] = int(rounds_per_dispatch) in trips \
            or int(rounds_per_dispatch) == 1
        report["single_dispatch"] &= report["scan_carries_rounds"]
    return report


def analyze_hlo(txt: str, seq_len: int = 0) -> Analysis:
    comps = parse_module(txt)
    entry = None
    for line in txt.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1).lstrip("%")
            break
    if entry is None:
        # fall back: computation with most ops
        entry = max(comps, key=lambda c: len(comps[c].ops))
    acc = Analysis(seq_len=seq_len)
    analyze_computation(entry, comps, acc, 1.0)
    return acc
