import os
# Respect a caller-provided device count (the CI pod-smoke lane fakes an
# 8-device mesh); otherwise force the 512-chip production dry-run topology,
# preserving any unrelated XLA_FLAGS the caller set (e.g. --xla_dump_to).
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512"
                               ).strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on the
production meshes, record memory/cost/collective analysis for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-v3-671b \
      --shape train_4k [--multipod] [--engine exact_tp|recompute|fedavg] \
      [--sketch K] [--out experiments/dryrun]

No real arrays are allocated: parameters/batches/caches enter as
ShapeDtypeStructs via jax.eval_shape.

Online pod mode (EXPERIMENTS.md "Pod online harness"): ``--online`` instead
*executes* ``repro.harness.run`` on the pod engine — the paper's
FIFO-arrival setting on a mesh-sharded buffer — for every pod engine on a
small ('pod','data') CPU mesh, asserting finite losses and that the per-round
history schema matches the stacked engine's. This is the CI
``pod-smoke`` entrypoint:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.dryrun --online --pod 2 --data 4 --rounds 3
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPE_BY_NAME, TRANSFORMER_ARCHS, get_config
from repro.configs.base import FLConfig, InputShape, ModelConfig
from repro.core.pod import (make_fedavg_train_step, make_prefill_step,
                            make_recompute_train_step, make_serve_step,
                            make_stale_score_train_step, make_tp_train_step)
from repro.core.shmap import use_mesh
from repro.data.synthetic import train_batch_shapes
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_axes, batch_shardings,
                                   cache_shardings, param_shardings)
from repro.models.transformer import init_cache, init_model

# v5e roofline constants
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

# >100B MoE archs need FSDP (replicas can't fit TP-only) -> recompute engine
FSDP_ARCHS = {"deepseek-v3-671b", "arctic-480b"}


def default_engine(arch: str) -> str:
    return "recompute" if arch in FSDP_ARCHS else "exact_tp"


def cost_analysis(compiled) -> dict:
    """Version-compatible ``compiled.cost_analysis()``: jax 0.4.x returns a
    one-element list of per-partition dicts, newer jax the dict itself."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_model(k, cfg), key)


def input_specs(arch: str, shape_name: str, *, num_clients: int = 16):
    """ShapeDtypeStruct stand-ins for every model input of this combo."""
    cfg = get_config(arch)
    shp = INPUT_SHAPE_BY_NAME[shape_name]
    params = abstract_params(cfg)
    if shp.kind == "train":
        batch = train_batch_shapes(cfg, shp.global_batch, shp.seq_len)
        return cfg, shp, params, batch
    if shp.kind == "prefill":
        seq = shp.seq_len
        if cfg.encoder is not None:
            seq = min(seq, cfg.encoder.max_decoder_len)
        batch = train_batch_shapes(cfg, shp.global_batch, seq)
        batch.pop("labels")
        return cfg, shp, params, batch
    # decode
    L = shp.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, shp.global_batch, L))
    tokens = jax.ShapeDtypeStruct((shp.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    memory = None
    if cfg.encoder is not None:
        memory = jax.ShapeDtypeStruct(
            (shp.global_batch, cfg.encoder.n_frames, cfg.d_model),
            jnp.bfloat16)
    if cfg.vision is not None:
        memory = jax.ShapeDtypeStruct(
            (shp.global_batch, cfg.vision.n_patches, cfg.d_model),
            jnp.bfloat16)
    return cfg, shp, params, {"cache": cache, "tokens": tokens, "pos": pos,
                              "memory": memory}


def skip_reason(cfg: ModelConfig, shp: InputShape) -> str | None:
    if shp.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention architecture: 500k decode cache is unbounded; "
                "skipped per DESIGN.md long_500k applicability table")
    return None


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                engine: str | None = None, sketch: int = 0,
                remat: bool = False, kappa: int = 1,
                fl: FLConfig | None = None):
    """Build the jitted step for one combo and lower+compile it on the mesh.
    Returns (compiled, meta dict)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, shp, params, inputs = input_specs(arch, shape_name)
    if remat:
        cfg = dataclasses.replace(cfg, remat=True)
    reason = skip_reason(cfg, shp)
    if reason:
        return None, {"arch": arch, "shape": shape_name, "skipped": reason}
    engine = engine or default_engine(arch)
    fl = fl or FLConfig(kappa_max=kappa)
    # Weight placement per shape kind (§Perf B1/E3): FSDP for training
    # (grad/step sharding) and for batched decode (the per-layer gather
    # amortizes over the 128-request batch and beats TP-only weight reads);
    # weights-stationary TP for prefill, where FSDP-sharded weights made XLA
    # contract attention over a sharded head_dim and all-reduce full
    # (B,H,S,S) score tensors (the 702s -> 59s B1 win).
    fsdp = engine == "recompute" and shp.kind != "prefill"
    pshard = param_shardings(params, mesh, fsdp=fsdp)
    axes = batch_axes(mesh)

    with use_mesh(mesh):
        if shp.kind == "train":
            if engine == "exact_tp":
                step = make_tp_train_step(cfg, fl, mesh, sketch_dim=sketch)
            elif engine == "recompute":
                # per-client microbatch must divide the client-axis rows
                n_rows = 1
                for a in axes:
                    n_rows *= mesh.shape[a]
                U = min(fl.num_clients, max(1, shp.global_batch // n_rows))
                # reshape batch into (U, b, ...) client groups
                inputs = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(
                        (U, s.shape[0] // U) + s.shape[1:], s.dtype), inputs)
                gspecs = jax.tree.map(lambda s: s.spec, pshard)
                step = make_recompute_train_step(cfg, fl, mesh, U,
                                                 grad_specs=gspecs)
            elif engine == "stale":
                n_rows = 1
                for a in axes:
                    n_rows *= mesh.shape[a]
                U = min(fl.num_clients, max(1, shp.global_batch // n_rows))
                inputs = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(
                        (U, s.shape[0] // U) + s.shape[1:], s.dtype), inputs)
                gspecs = jax.tree.map(lambda s: s.spec, pshard)
                fsdp = True
                pshard = param_shardings(params, mesh, fsdp=True)
                gspecs = jax.tree.map(lambda s: s.spec, pshard)
                base = make_stale_score_train_step(cfg, fl, mesh, U,
                                                   grad_specs=gspecs)
            elif engine == "fedavg":
                step = make_fedavg_train_step(cfg, fl, mesh)
            else:
                raise ValueError(engine)
            grouped = engine in ("recompute", "stale")
            bshard = jax.tree.map(
                lambda s: NamedSharding(
                    mesh, P(*((None, axes) if grouped else (axes,)),
                            *([None] * (s.ndim - (2 if grouped else 1))))),
                inputs)
            if engine == "stale":
                lam = jax.ShapeDtypeStruct((U,), jnp.float32)
                lshard = NamedSharding(mesh, P())
                jf = jax.jit(base, in_shardings=(pshard, lshard, bshard),
                             out_shardings=(pshard, lshard, None))
                lowered = jf.lower(params, lam, inputs)
            else:
                jf = jax.jit(step, in_shardings=(pshard, bshard),
                             out_shardings=(pshard, None))
                lowered = jf.lower(params, inputs)
        elif shp.kind == "prefill":
            step = make_prefill_step(cfg)
            bshard = batch_shardings(inputs, mesh)
            jf = jax.jit(step, in_shardings=(pshard, bshard))
            lowered = jf.lower(params, inputs)
        else:  # decode
            step = make_serve_step(cfg)
            cshard = cache_shardings(inputs["cache"], mesh, shp.global_batch)
            tshard = NamedSharding(
                mesh, P(axes) if shp.global_batch > 1 else P())
            mshard = None
            if inputs["memory"] is not None:
                mshard = NamedSharding(
                    mesh, P(axes if shp.global_batch > 1 else None, None,
                            "model"))
            jf = jax.jit(step, in_shardings=(
                pshard, cshard, tshard, NamedSharding(mesh, P()), mshard),
                out_shardings=(tshard, cshard))
            lowered = jf.lower(params, inputs["cache"], inputs["tokens"],
                               inputs["pos"], inputs["memory"])
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    meta = {"arch": arch, "shape": shape_name, "engine": engine,
            "multi_pod": multi_pod, "sketch": sketch,
            "compile_s": compile_s, "mesh": dict(
                zip(mesh.axis_names, mesh.devices.shape))}
    return compiled, meta


def model_flops(cfg: ModelConfig, shp: InputShape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE), D = tokens/step."""
    n_active = active_params(cfg)
    if shp.kind == "train":
        d = shp.global_batch * shp.seq_len
        return 6.0 * n_active * d
    if shp.kind == "prefill":
        seq = shp.seq_len
        if cfg.encoder is not None:
            seq = min(seq, cfg.encoder.max_decoder_len)
        return 2.0 * n_active * shp.global_batch * seq
    return 2.0 * n_active * shp.global_batch          # decode: 1 token


def total_params(cfg: ModelConfig) -> int:
    import math
    params = abstract_params(cfg)
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


def active_params(cfg: ModelConfig) -> float:
    """Parameters touched per token (MoE: top_k of num_experts experts)."""
    params = abstract_params(cfg)
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        n = float(np.prod(leaf.shape))
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if cfg.moe and any(n2 in ("moe",) for n2 in names) and \
                names[-1] in ("w_gate", "w_up", "w_down"):
            n *= cfg.moe.top_k / cfg.moe.num_experts
        total += n
    return total


def roofline(compiled, meta, cfg: ModelConfig, shp: InputShape) -> dict:
    n_chips = 512 if meta["multi_pod"] else 256
    seq = shp.seq_len if shp.kind in ("train", "prefill") else 0
    analysis = analyze_hlo(compiled.as_text(), seq_len=seq)
    mem = compiled.memory_analysis()
    ca = cost_analysis(compiled)
    per_dev_flops = analysis.flops
    global_flops = per_dev_flops * n_chips
    per_dev_coll = analysis.total_collective_bytes
    per_dev_traffic = analysis.traffic_bytes
    compute_s = global_flops / (n_chips * PEAK_FLOPS)
    memory_s = per_dev_traffic / HBM_BW
    collective_s = per_dev_coll / ICI_BW
    # flash projection: the Pallas kernel (kernels/flash_attention.py,
    # validated in interpret mode) keeps (seq x seq) score tensors in VMEM;
    # kv re-reads at block_q=1024 add <= S/1024 * (K+V) bytes (small). The
    # projected memory term removes in-HBM score traffic. Reported alongside
    # the XLA-path baseline, never instead of it.
    memory_s_flash = (per_dev_traffic - analysis.score_traffic_bytes) / HBM_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shp)
    out = {
        **meta,
        "n_chips": n_chips,
        "per_device": {
            "flops": per_dev_flops,
            "traffic_bytes": per_dev_traffic,
            "collective_bytes": dict(analysis.collective_bytes),
            "collective_counts": dict(analysis.collective_counts),
            "xla_cost_flops_unscaled": float(ca.get("flops", -1)),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "peak_bytes": mem.argument_size_in_bytes
                + mem.output_size_in_bytes + mem.temp_size_in_bytes,
            },
        },
        "roofline": {**terms, "dominant": dominant,
                     "memory_s_flash_projected": memory_s_flash,
                     "score_traffic_bytes": analysis.score_traffic_bytes,
                     "step_time_lower_bound_s": max(terms.values())},
        "model_flops": mf,
        "useful_flops_ratio": mf / max(global_flops, 1.0),
        "total_params": total_params(cfg),
        "active_params": active_params(cfg),
    }
    return out


def run_one(arch, shape_name, *, multi_pod=False, engine=None, sketch=0,
            remat=False, kappa=1, out_dir="experiments/dryrun",
            save_hlo=False, verbose=True):
    compiled, meta = lower_combo(arch, shape_name, multi_pod=multi_pod,
                                 engine=engine, sketch=sketch, remat=remat,
                                 kappa=kappa)
    meta["remat"] = remat
    meta["kappa"] = kappa
    if compiled is None:
        rec = meta
    else:
        cfg = get_config(arch)
        shp = INPUT_SHAPE_BY_NAME[shape_name]
        rec = roofline(compiled, meta, cfg, shp)
        if verbose:
            print(compiled.memory_analysis())
            ca = cost_analysis(compiled)
            if ca:
                print({k: v for k, v in ca.items() if "flops" in k})
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    suffix = "multipod" if multi_pod else "pod"
    if engine:
        suffix += f"_{engine}"
    if sketch:
        suffix += f"_sketch{sketch}"
    if remat:
        suffix += "_remat"
    if kappa > 1:
        suffix += f"_kappa{kappa}"
    fn = out / f"{arch}__{shape_name}__{suffix}.json"
    fn.write_text(json.dumps(rec, indent=2, default=float))
    if verbose:
        rl = rec.get("roofline")
        if rl:
            print(f"{arch} x {shape_name} [{suffix}]: dominant={rl['dominant']}"
                  f" compute={rl['compute_s']:.4f}s memory={rl['memory_s']:.4f}s"
                  f" collective={rl['collective_s']:.4f}s")
        else:
            print(f"{arch} x {shape_name}: SKIPPED — {rec['skipped']}")
    return rec


def run_online(*, pod: int, data: int | None, rounds: int, clients: int,
               model: str, out_dir: str, engines=None) -> list:
    """Execute the online pod harness for every engine flavor on a small
    client mesh (see module docstring). Raises SystemExit(1) on any
    non-finite loss or history-schema mismatch; returns the per-engine
    records and writes them as one JSON into ``out_dir``."""
    from repro.harness import POD_ENGINES, ExperimentConfig, resolve, run

    data = data or max(jax.device_count() // pod, 1)
    mesh = jax.make_mesh((pod, data), ("pod", "data"))
    xc = ExperimentConfig(model=model, dataset=2, num_clients=clients,
                          rounds=rounds, capacity=(12, 24), arrivals=4,
                          batch=8, seed=5, request_backend="stacked")
    schema = set(run("osafl", dataclasses.replace(xc, rounds=1),
                     eval_samples=64)[0])
    records, failures = [], []
    for engine in (engines or POD_ENGINES):
        alg = "fedavg" if engine == "fedavg" else "osafl"
        print("plan:", resolve(alg, xc, mesh=mesh,
                               pod_engine=engine).describe())
        t0 = time.time()
        hist = run(alg, xc, eval_samples=64, mesh=mesh, pod_engine=engine)
        losses = [h["test_loss"] for h in hist]
        if not all(np.isfinite(losses)):
            failures.append(f"{engine}: non-finite losses {losses}")
        bad = [i for i, h in enumerate(hist) if set(h) != schema]
        if bad:
            failures.append(f"{engine}: history schema mismatch at rounds "
                            f"{bad} (want {sorted(schema)})")
        records.append({"engine": engine, "alg": alg, "history": hist,
                        "wall_s": time.time() - t0})
        print(f"online {engine:10s} [{alg}] losses "
              + " ".join(f"{l:.4f}" for l in losses)
              + f" ({records[-1]['wall_s']:.1f}s)")
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    fn = out / (f"online__{model}__U{clients}__"
                f"{pod}x{data}.json")
    fn.write_text(json.dumps({
        "mesh": {"pod": pod, "data": data}, "clients": clients,
        "rounds": rounds, "model": model, "records": records}, indent=2,
        default=float))
    if failures:
        for f in failures:
            print("FAIL", f)
        raise SystemExit(1)
    print(f"online pod dryrun OK: {len(records)} engines x {rounds} rounds "
          f"on a {pod}x{data} ('pod','data') mesh -> {fn}")
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--engine", default=None)
    ap.add_argument("--sketch", type=int, default=0)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--kappa", type=int, default=1)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--online", action="store_true",
                    help="run the online pod harness (real arrays, small "
                         "mesh) instead of the lower/compile sweep")
    ap.add_argument("--pod", type=int, default=2)
    ap.add_argument("--data", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--model", default="mlp")
    args = ap.parse_args()
    if args.online:
        run_online(pod=args.pod, data=args.data, rounds=args.rounds,
                   clients=args.clients, model=args.model, out_dir=args.out)
        return
    archs = TRANSFORMER_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPE_BY_NAME) if args.shape == "all" else [args.shape]
    for a in archs:
        for s in shapes:
            t0 = time.time()
            try:
                run_one(a, s, multi_pod=args.multipod, engine=args.engine,
                        sketch=args.sketch, remat=args.remat,
                        kappa=args.kappa, out_dir=args.out)
            except Exception as e:
                import traceback
                print(f"FAIL {a} x {s}: {type(e).__name__}: {e}")
                traceback.print_exc()
            print(f"  ({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()
