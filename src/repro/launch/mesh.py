"""Production meshes. Functions (not module constants) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over whatever devices exist — for CPU smoke tests."""
    n = jax.device_count()
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
