"""Parameter / batch / cache sharding rules for the production meshes.

Rules are name-based on the last path component and applied to the *trailing*
dimensions (layer-stacking axes get leading Nones automatically). Two regimes:

  tp      — tensor parallel over 'model', replicated over 'data' (+'pod').
            Used by the exact_tp OSAFL engine (clients = data rows need full
            replicas for client-local gradients).
  fsdp    — tp + the largest remaining dim sharded over 'data'
            (ZeRO-3 within a pod, replicated across pods so scored
            aggregation crosses the slow inter-pod links only once).
            Used by the exact_recompute engine for the >100B MoE archs.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# trailing-dims spec per parameter name, tp regime
_TP_RULES = {
    # embeddings / heads
    "table": (None, "model"),
    "lm_head": (None, "model"),
    "vision_proj": (None, "model"),
    # attention
    "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
    "wo": ("model", None),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    # MLA
    "wq_a": (None, None), "wq_b": (None, "model"),
    "wkv_a": (None, None), "wkv_b": (None, "model"),
    # MLP
    "w_up": (None, "model"), "w_gate": (None, "model"),
    "w_down": ("model", None),
    # MoE (expert-parallel over 'model'; router replicated)
    "router": (None, None),
    # mamba / xlstm
    "in_proj": (None, "model"), "out_proj": ("model", None),
    "up_proj": (None, "model"), "down_proj": ("model", None),
    "conv_w": (None, "model"), "conv_b": ("model",),
    "A_log": ("model",), "D": ("model",), "dt_bias": ("model",),
    "w_gates": (None, "model"),
    "wx": (None, "model"), "wh": (None, "model"),
    "w_in": (None, "model"), "r": ("model", None, None),
    # mtp
    "proj": (None, None),
}

# MoE expert tensors are stacked (E, d, f): expert axis over 'model'
_MOE_EXPERT = {"w_gate": ("model", None, None), "w_up": ("model", None, None),
               "w_down": ("model", None, None)}

# fsdp additions: shard this trailing dim index over 'data'
_FSDP_DIM = {
    "table": 0, "lm_head": 0, "wq": 0, "wk": 0, "wv": 0, "wo": 1,
    "w_up": 0, "w_gate": 0, "w_down": 1, "wq_b": 0, "wkv_b": 0,
    "in_proj": 0, "out_proj": 1, "up_proj": 0, "down_proj": 1,
}


def _path_names(path) -> list[str]:
    out = []
    for pp in path:
        if hasattr(pp, "key"):
            out.append(pp.key)
        elif hasattr(pp, "name"):
            out.append(pp.name)
    return out


def param_spec(path, leaf, *, fsdp: bool = False, mesh=None) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    in_moe = any(n in ("moe", "moe_layers") for n in names[:-1])
    if in_moe and name in _MOE_EXPERT and leaf.ndim >= 3:
        trailing = list(_MOE_EXPERT[name])
        if fsdp:
            # expert axis over BOTH mesh axes when it divides (1 expert/chip
            # at E=256): splitting d_model over 'data' instead made the
            # layer-scan cotangent replicate + all-gather 872GB/client
            # (§Perf A2). When E doesn't divide (arctic: 128 experts on 256
            # chips), fall back to experts-over-model + dim1-over-data —
            # the naive 2D spec silently degrades to full replication via
            # the divisibility check (§Perf E2 regression).
            E = leaf.shape[leaf.ndim - 3]
            nm = mesh.shape["model"] if mesh is not None else 1
            nd = mesh.shape["data"] if mesh is not None else 1
            if mesh is not None and E % (nm * nd) == 0:
                trailing[0] = ("model", "data")
            else:
                trailing[1] = "data"
    else:
        trailing = list(_TP_RULES.get(name, ()))
        if not trailing or leaf.ndim < len(trailing):
            return P()
        if fsdp and name in _FSDP_DIM:
            i = _FSDP_DIM[name]
            if trailing[i] is None:
                trailing[i] = "data"
    lead = [None] * (leaf.ndim - len(trailing))
    spec = lead + trailing
    if mesh is not None:
        # drop axes that don't evenly divide the dimension
        shape = leaf.shape
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            n = mesh.shape[ax] if not isinstance(ax, tuple) else \
                int(np.prod([mesh.shape[a] for a in ax]))
            if shape[i] % n != 0:
                spec[i] = None
    return P(*spec)


def param_shardings(params, mesh, *, fsdp: bool = False):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, fsdp=fsdp, mesh=mesh)),
        params)


def batch_axes(mesh) -> tuple:
    """Client/data axes present in the mesh ('pod' first if multi-pod)."""
    names = mesh.axis_names
    return tuple(n for n in ("pod", "data") if n in names)


def batch_shardings(batch, mesh, *, shard_batch_dim: bool = True):
    axes = batch_axes(mesh)
    spec_fn = lambda leaf: P(axes if shard_batch_dim and leaf.ndim else None,
                             *([None] * (leaf.ndim - 1)))
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, spec_fn(leaf)), batch)


def cache_shardings(cache, mesh, batch_size: int):
    """KV/SSM caches: batch dim over data axes where divisible (heads etc. are
    left to auto-SPMD through the model-sharded params)."""
    axes = batch_axes(mesh)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]

    def spec(leaf):
        # caches are stacked (layers..., batch, ...): find the batch dim
        for i, s in enumerate(leaf.shape):
            if s == batch_size and batch_size % n_dev == 0 and n_dev > 1:
                return P(*([None] * i), axes, *([None] * (leaf.ndim - i - 1)))
        return P()
    return jax.tree.map(lambda leaf: NamedSharding(mesh, spec(leaf)), cache)
