"""Config-driven trainer: runs the pod-scale OSAFL engines for real (on the
host mesh; the production mesh is exercised by dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --reduced \
      --steps 50 --engine exact_tp [--sketch 64] [--ckpt out.npz]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import get_config
from repro.configs.base import FLConfig
from repro.core.pod import (make_fedavg_train_step, make_recompute_train_step,
                            make_stale_score_train_step, make_tp_train_step)
from repro.core.shmap import use_mesh
from repro.data.synthetic import learnable_sequence_batch, make_train_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import param_shardings
from repro.models.transformer import init_model, param_count


def run(arch: str, *, reduced=True, steps=20, engine="exact_tp", sketch=0,
        batch=8, seq=64, lr=0.1, global_lr=1.0, num_clients=None,
        learnable=True, ckpt=None, log_every=5, seed=0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    fl = FLConfig(kappa_max=1, local_lr=lr, global_lr=global_lr,
                  num_clients=num_clients or mesh.shape["data"],
                  score_sketch_dim=sketch)
    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg)
    print(f"{cfg.name}: {param_count(params) / 1e6:.1f}M params, "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"engine={engine}")

    with use_mesh(mesh):
        if engine == "exact_tp":
            step = make_tp_train_step(cfg, fl, mesh, sketch_dim=sketch)
        elif engine == "recompute":
            step = make_recompute_train_step(cfg, fl, mesh, fl.num_clients)
        elif engine == "stale":
            step = make_stale_score_train_step(cfg, fl, mesh, fl.num_clients)
        elif engine == "fedavg":
            step = make_fedavg_train_step(cfg, fl, mesh)
        else:
            raise ValueError(engine)
        jstep = jax.jit(step)
        lam = jnp.ones((fl.num_clients,), jnp.float32)
        history = []
        for t in range(steps):
            key, bk = jax.random.split(key)
            if learnable:
                b = learnable_sequence_batch(bk, cfg, batch, seq)
            else:
                b = make_train_batch(bk, cfg, batch, seq)
            if engine in ("recompute", "stale"):
                b = jax.tree.map(
                    lambda x: x.reshape((fl.num_clients, -1) + x.shape[1:]),
                    b)
            t0 = time.time()
            if engine == "stale":
                params, lam, metrics = jstep(params, lam, b)
            else:
                params, metrics = jstep(params, b)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_s"] = time.time() - t0
            history.append(metrics)
            if t % log_every == 0 or t == steps - 1:
                lam_m = metrics.get("lambda_mean")
                print(f"step {t:4d} loss={metrics['loss']:.4f}"
                      + (f" lambda={lam_m:.4f}" if lam_m is not None else "")
                      + f" ({metrics['step_s']:.2f}s)")
    if ckpt:
        checkpoint.save(ckpt, params, step=steps)
        print(f"saved checkpoint -> {ckpt}")
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--engine", default="exact_tp",
                    choices=["exact_tp", "recompute", "stale", "fedavg"])
    ap.add_argument("--sketch", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    run(args.arch, reduced=not args.full, steps=args.steps,
        engine=args.engine, sketch=args.sketch, batch=args.batch,
        seq=args.seq, lr=args.lr, ckpt=args.ckpt)


if __name__ == "__main__":
    main()
