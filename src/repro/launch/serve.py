"""Train-while-serve: hot-reload the latest committed global FL model.

The paper's deployment is a live wireless video-caching system: clients keep
training online while the *current* global predictor serves cache-decision
requests. This module closes that loop against the streaming checkpoint
layer (``checkpoint/streaming.py``):

  * ``ModelServer`` polls a checkpoint directory, maps the newest
    **committed** snapshot (uncommitted / torn writes are invisible —
    ``latest_checkpoint`` requires the commit marker), and swaps the global
    model in without interrupting in-flight request scoring: ``pin()``
    returns a handle holding the mapped params by reference, so a reload
    between two ``score`` calls of one request batch cannot change that
    batch's outputs (jax arrays are immutable; the swap is a pure rebind).
  * Staleness is first-class: ``rounds_behind`` (newest committed round
    minus mapped round) updates on every poll, and each reload logs how far
    behind the server was the moment it swapped (``stats()["reloads"]``).
  * The prune-vs-reload race is closed by claim files: before loading, the
    server publishes ``SERVING-<token>.json`` naming the snapshot it has
    mapped *and* the one it is about to read; ``prune_checkpoints`` skips
    claimed names. A prune that raced the claim is caught by re-checking
    the commit marker after claiming and by the loader's crc/commit
    validation — the server then just retries on the next poll.

``serve_loop`` drives a synthetic request stream against the server while a
trainer (another thread or process) writes snapshots — the shape
``tools/serve_smoke.py`` runs in CI and ``benchmarks/bench_serve.py``
measures. The transformer decode-path example that previously lived here
moved to ``examples/serve_decode.py``.

    PYTHONPATH=src python -m repro.launch.serve --checkpoint-dir \\
        experiments/run1/ckpt --until-round 20
"""
from __future__ import annotations

import argparse
import os
import time
from functools import partial
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.checkpoint import CheckpointError
from repro.core.flatten import make_codec
from repro.data.online import dataset_layout
from repro.models.small import NUM_CLASSES, REGISTRY, init_small, \
    small_forward


def extract_global_model(snap: dict):
    """(model_name, params pytree, next_round) from a loaded RunState
    snapshot, across every engine's layout: the loop servers store a
    ``params`` pytree, the stacked/pod servers a flat ``w`` vector
    (unflattened through the model's codec), and the sparse-cohort server
    nests its inner width-C server under ``server.inner``."""
    try:
        sv = snap["server"]
        model = str(snap["config"]["model"])
        rnd = int(snap["next_round"])
    except (KeyError, TypeError) as e:
        raise CheckpointError(
            f"snapshot is not a harness RunState (missing {e})") from e
    if isinstance(sv, dict) and "inner" in sv:
        sv = sv["inner"]
    if model not in REGISTRY:
        raise CheckpointError(f"snapshot names unknown model {model!r}")
    if isinstance(sv, dict) and "params" in sv:
        params = jax.tree.map(jnp.asarray, sv["params"])
    elif isinstance(sv, dict) and "w" in sv:
        codec = make_codec(init_small(jax.random.PRNGKey(0), model))
        params = codec.unflatten(jnp.asarray(sv["w"]))
    else:
        raise CheckpointError(
            "snapshot server state has neither 'params' nor 'w'")
    return model, params, rnd


class ScoringHandle:
    """An immutable view of one mapped model: ``score`` always runs the
    params this handle was pinned with, even if the owning ``ModelServer``
    hot-reloads mid-batch. Pin one per request batch."""

    def __init__(self, fwd, params, round_: int):
        self._fwd = fwd
        self._params = params
        self.round = round_

    def score(self, x) -> np.ndarray:
        """(B, ...) request features -> (B, NUM_CLASSES) logits."""
        return np.asarray(self._fwd(self._params, jnp.asarray(x)))


class ModelServer:
    """Hot-reloading model server over a checkpoint directory.

    ``poll()`` is the single state transition: scan for the newest committed
    snapshot, claim it, load it, swap. Everything else (``pin``/``score``)
    reads the currently mapped model. Load failures caused by races (the
    snapshot pruned between scan and read) are counted and retried on the
    next poll, never fatal; they cannot map a partial model because the
    loader validates commit marker, manifest sha and per-shard crc before
    returning anything."""

    def __init__(self, checkpoint_dir, claim: bool = True):
        self.dir = Path(checkpoint_dir)
        self._claim = bool(claim)
        self._token = (f"{os.getpid()}-"
                       f"{np.random.SeedSequence().entropy % 16**8:08x}")
        self._fwd = None
        self._params = None
        self.model: Optional[str] = None
        self.mapped: Optional[str] = None     # snapshot name currently mapped
        self.mapped_round = -1
        self.rounds_behind = 0
        self.reloads = 0
        self.failed_loads = 0
        self.last_error: Optional[str] = None
        self._reload_log = []

    # -- polling / hot reload ------------------------------------------------
    def poll(self) -> bool:
        """Map the newest committed snapshot if it is newer than the mapped
        one. Returns True iff a reload happened."""
        latest = checkpoint.latest_checkpoint(self.dir)
        if latest is None:
            return False
        latest_round = checkpoint.snapshot_round(latest)
        if latest_round is None:
            latest_round = self.mapped_round
        if self.mapped is not None:
            self.rounds_behind = max(latest_round - self.mapped_round, 0)
        if latest.name == self.mapped:
            return False
        # claim-before-load: name both the mapped snapshot (still serving
        # in-flight batches) and the target, then re-verify the target is
        # still committed — a prune that raced the scan loses here
        if self._claim:
            checkpoint.write_claim(self.dir, self._token,
                                   [self.mapped, latest.name])
        if not checkpoint.is_committed(latest):
            self._unclaim_target()
            return False
        t0 = time.perf_counter()
        try:
            snap = checkpoint.load_run_state(latest)
            model, params, rnd = extract_global_model(snap)
        except (CheckpointError, FileNotFoundError) as e:
            # raced a prune or hit a bad artifact: stay on the mapped model
            self.failed_loads += 1
            self.last_error = str(e)
            self._unclaim_target()
            return False
        if self._fwd is None or model != self.model:
            self._fwd = jax.jit(partial(small_forward, name=model))
        behind = rnd - self.mapped_round if self.mapped is not None else 0
        # the swap: pure rebind — existing ScoringHandles keep the old params
        self._params = params
        self.model = model
        self.mapped = latest.name
        self.mapped_round = rnd
        self.rounds_behind = 0
        self.reloads += 1
        self._reload_log.append({"round": rnd, "behind": int(behind),
                                 "reload_s": time.perf_counter() - t0})
        if self._claim:
            checkpoint.write_claim(self.dir, self._token, [self.mapped])
        return True

    def _unclaim_target(self) -> None:
        if self._claim:
            if self.mapped is not None:
                checkpoint.write_claim(self.dir, self._token, [self.mapped])
            else:
                checkpoint.clear_claim(self.dir, self._token)

    # -- scoring -------------------------------------------------------------
    def pin(self) -> ScoringHandle:
        """Pin the currently mapped model for one request batch."""
        if self._params is None:
            raise RuntimeError(
                "no model mapped yet — poll() until a committed snapshot "
                f"appears under {self.dir}")
        return ScoringHandle(self._fwd, self._params, self.mapped_round)

    def score(self, x) -> np.ndarray:
        """One-shot scoring on the currently mapped model."""
        return self.pin().score(x)

    # -- bookkeeping ---------------------------------------------------------
    def stats(self) -> dict:
        return {"mapped": self.mapped, "mapped_round": self.mapped_round,
                "rounds_behind": self.rounds_behind,
                "reloads": list(self._reload_log),
                "failed_loads": self.failed_loads,
                "last_error": self.last_error}

    def close(self) -> None:
        checkpoint.clear_claim(self.dir, self._token)

    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        self.close()
        return False


def make_request_batch(rng: np.random.Generator, batch: int, dataset: int
                       ) -> np.ndarray:
    """Synthetic request features matching the dataset's layout (dataset 1:
    normalized feature rows; dataset 2: content-id sequences)."""
    feat_shape, dtype = dataset_layout(dataset)
    if np.issubdtype(np.dtype(dtype), np.integer):
        return rng.integers(0, NUM_CLASSES,
                            (batch,) + feat_shape).astype(dtype)
    return rng.standard_normal((batch,) + feat_shape).astype(dtype)


def serve_loop(checkpoint_dir, *, until_round: int = None,
               duration_s: float = None, poll_s: float = 0.1,
               batch: int = 32, dataset: int = 2, seed: int = 0,
               timeout_s: float = 120.0, verbose: bool = False) -> dict:
    """Score synthetic request batches against the hot-reloading server
    until the mapped model reaches ``until_round`` (or ``duration_s``
    elapses). Each batch is scored on a pinned handle; the server polls
    between batches. Returns the serving stats plus traffic counters."""
    rng = np.random.default_rng(seed)
    deadline = time.monotonic() + (duration_s if duration_s is not None
                                   else timeout_s)
    batches = scored = 0
    mapped_rounds = []
    with ModelServer(checkpoint_dir) as server:
        while True:
            reloaded = server.poll()
            if reloaded:
                mapped_rounds.append(server.mapped_round)
                if verbose:
                    print(f"serve: mapped round {server.mapped_round} "
                          f"({server.rounds_behind} behind at swap)")
            if server.mapped is not None:
                handle = server.pin()
                out = handle.score(make_request_batch(rng, batch, dataset))
                batches += 1
                scored += out.shape[0]
            if until_round is not None and \
                    server.mapped_round >= until_round:
                break
            if time.monotonic() >= deadline:
                if until_round is not None:
                    raise TimeoutError(
                        f"serve_loop: model never reached round "
                        f"{until_round} (mapped {server.mapped_round}) "
                        f"within {timeout_s}s")
                break
            time.sleep(poll_s)
        stats = server.stats()
    stats.update(batches=batches, requests_scored=scored,
                 mapped_rounds=mapped_rounds)
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Serve the latest committed FL model from a checkpoint "
        "directory, hot-reloading as training publishes new rounds.")
    ap.add_argument("--checkpoint-dir", required=True)
    ap.add_argument("--until-round", type=int, default=None,
                    help="exit once this round is mapped")
    ap.add_argument("--duration-s", type=float, default=None,
                    help="serve for a fixed wall-clock window instead")
    ap.add_argument("--poll-s", type=float, default=0.1)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--dataset", type=int, default=2, choices=(1, 2))
    ap.add_argument("--timeout-s", type=float, default=120.0)
    args = ap.parse_args(argv)
    stats = serve_loop(args.checkpoint_dir, until_round=args.until_round,
                       duration_s=args.duration_s, poll_s=args.poll_s,
                       batch=args.batch, dataset=args.dataset,
                       timeout_s=args.timeout_s, verbose=True)
    print(f"served {stats['requests_scored']} requests over "
          f"{stats['batches']} batches; {len(stats['reloads'])} reloads, "
          f"final round {stats['mapped_round']}")
    return stats


if __name__ == "__main__":
    main()
