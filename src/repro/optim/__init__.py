"""Minimal functional optimizers (the paper's algorithms use plain SGD; Adam
is provided for the centralized baselines / examples)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: callable
    update: callable          # (grads, state, params) -> (updates, state)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return {"mu": jax.tree.map(jnp.zeros_like, params)}
        return {}

    def update(grads, state, params=None):
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g,
                              state["mu"], grads)
            return jax.tree.map(lambda m: -lr * m, mu), {"mu": mu}
        return jax.tree.map(lambda g: -lr * g, grads), state
    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state["v"], grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)
        upd = jax.tree.map(lambda m_, v_: -lr * m_ / (jnp.sqrt(v_) + eps),
                           mh, vh)
        return upd, {"m": m, "v": v, "t": t}
    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
