"""Model assembly: builds every assigned architecture from a ModelConfig.

Families:
  dense decoders        (h2o-danube-3, nemotron-4, qwen1.5, deepseek-coder)
  MoE decoders          (deepseek-v3 w/ MLA+MTP, arctic w/ dense residual)
  hybrid SSM            (zamba2: mamba2 backbone + shared attention block)
  xLSTM                 (mLSTM/sLSTM groups)
  encoder-decoder audio (whisper-medium; conv/mel frontend stubbed)
  VLM decoder           (llama-3.2-vision: interleaved cross-attn layers)

All parameter stacks are scanned (lax.scan over stacked layer params) so the
largest configs lower/compile quickly. Public API:

  init_model(key, cfg)                         -> params
  forward(params, batch, cfg)                  -> (logits, aux_loss)
  loss_fn(params, batch, cfg)                  -> (loss, metrics)
  init_cache(cfg, batch, length)               -> cache
  decode_step(params, cache, tokens, pos, cfg) -> (logits, new_cache)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_lib
from repro.models.attention import (cross_attn_fwd, gqa_fwd, init_cross_attn,
                                    init_gqa, init_gqa_cache, init_mla,
                                    init_mla_cache, mla_fwd)
from repro.models.layers import (dense_init, embed, init_embedding, init_mlp,
                                 init_rmsnorm, mlp_fwd, rmsnorm, unembed)
from repro.models.moe import init_moe, moe_fwd


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def stacked_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# Generic transformer block (self-attn [+moe|mlp]); attention kind from cfg
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, *, use_moe: bool, d_ff: int = 0,
               causal: bool = True, dtype=None):
    dtype = dtype or _pdtype(cfg)
    k1, k2 = jax.random.split(key)
    if cfg.attention == "mla":
        attn = init_mla(k1, cfg, dtype=dtype)
    else:
        attn = init_gqa(k1, cfg, dtype=dtype)
    p = {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn,
        "ln2": init_rmsnorm(cfg.d_model, dtype),
    }
    if use_moe:
        p["moe"] = init_moe(k2, cfg, dtype=dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg, d_ff=d_ff or cfg.d_ff, dtype=dtype)
    return p


def block_fwd(p, x, cfg: ModelConfig, positions, *, use_moe: bool,
              cache=None, cache_pos=None, causal: bool = True,
              rope: bool = True):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attention == "mla":
        h, new_cache = mla_fwd(p["attn"], h, cfg, positions,
                               cache=cache, cache_pos=cache_pos)
    else:
        h, new_cache = gqa_fwd(p["attn"], h, cfg, positions, cache=cache,
                               cache_pos=cache_pos, causal=causal, rope=rope)
    x = x + h
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        h, aux = moe_fwd(p["moe"], h, cfg)
    else:
        h, aux = mlp_fwd(p["mlp"], h, cfg.mlp), jnp.float32(0.0)
    return x + h, new_cache, aux


import os


def _maybe_remat(fn, cfg):
    """Per-layer activation checkpointing (§Perf A1/C1): recompute the layer
    in backward instead of storing its internals. REPRO_REMAT_POLICY=dots
    saves matmul outputs (no recomputed TP collectives, more memory)."""
    if not cfg.remat:
        return fn
    if os.environ.get("REPRO_REMAT_POLICY", "") == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def _scan_blocks(stack, x, cfg, positions, *, use_moe, caches=None,
                 cache_pos=None, causal=True, rope=True):
    """Scan a stacked block over the layer axis; threads caches if given."""
    if caches is None:
        def body(carry, layer_p):
            h, aux = carry
            h, _, a = block_fwd(layer_p, h, cfg, positions, use_moe=use_moe,
                                causal=causal, rope=rope)
            return (h, aux + a), None
        (x, aux), _ = jax.lax.scan(_maybe_remat(body, cfg),
                                   (x, jnp.float32(0.0)), stack)
        return x, aux, None

    def body(h, inp):
        layer_p, layer_c = inp
        h, new_c, _ = block_fwd(layer_p, h, cfg, positions, use_moe=use_moe,
                                cache=layer_c, cache_pos=cache_pos,
                                causal=causal, rope=rope)
        return h, new_c
    x, new_caches = jax.lax.scan(body, x, (stack, caches))
    return x, jnp.float32(0.0), new_caches


def _block_cache(cfg: ModelConfig, batch: int, length: int):
    if cfg.attention == "mla":
        return init_mla_cache(cfg, batch, length)
    return init_gqa_cache(cfg, batch, length)


def _stack_tree(tree, lead: tuple):
    """Stack a cache pytree along new leading axes, PRESERVING initial values
    (e.g. the -1e9 running-max stabilizers in m/sLSTM caches)."""
    return jax.tree.map(
        lambda c: jnp.broadcast_to(c, tuple(lead) + c.shape).copy(), tree)


def _stacked_cache(cfg, n, batch, length):
    return _stack_tree(_block_cache(cfg, batch, length), (n,))


# ===========================================================================
# Family: dense / MoE decoders (incl. deepseek-v3, arctic)
# ===========================================================================

def _init_decoder(key, cfg: ModelConfig):
    pd = _pdtype(cfg)
    ks = jax.random.split(key, 8)
    moe_cfg = cfg.moe
    n_dense = moe_cfg.first_dense_layers if moe_cfg else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if moe_cfg else 0
    params: Dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, pd),
        "final_norm": init_rmsnorm(cfg.d_model, pd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                       dtype=pd)
    d_ff_dense = (moe_cfg.d_ff_dense or cfg.d_ff) if moe_cfg else cfg.d_ff
    if n_dense:
        params["dense_layers"] = stacked_init(
            lambda k: init_block(k, cfg, use_moe=False, d_ff=d_ff_dense),
            ks[2], n_dense)
    if n_moe:
        params["moe_layers"] = stacked_init(
            lambda k: init_block(k, cfg, use_moe=True), ks[3], n_moe)
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": dense_init(ks[4], (2 * cfg.d_model, cfg.d_model), dtype=pd),
            "ln_h": init_rmsnorm(cfg.d_model, pd),
            "ln_e": init_rmsnorm(cfg.d_model, pd),
            "block": init_block(ks[5], cfg, use_moe=False, d_ff=d_ff_dense),
        }
    return params


def _decoder_trunk(params, x, cfg, positions, caches=None, cache_pos=None):
    moe_cfg = cfg.moe
    n_dense = moe_cfg.first_dense_layers if moe_cfg else cfg.n_layers
    aux = jnp.float32(0.0)
    new_caches = {}
    if n_dense:
        x, a, nc = _scan_blocks(params["dense_layers"], x, cfg, positions,
                                use_moe=False,
                                caches=caches.get("dense") if caches else None,
                                cache_pos=cache_pos)
        aux += a
        new_caches["dense"] = nc
    if moe_cfg and cfg.n_layers - n_dense:
        x, a, nc = _scan_blocks(params["moe_layers"], x, cfg, positions,
                                use_moe=True,
                                caches=caches.get("moe") if caches else None,
                                cache_pos=cache_pos)
        aux += a
        new_caches["moe"] = nc
    return x, aux, new_caches


def _logits(params, x, cfg):
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return unembed(params["embed"], x)
    return x @ params["lm_head"].astype(x.dtype)


def _decoder_forward(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens, _cdtype(cfg))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, aux, _ = _decoder_trunk(params, x, cfg, positions)
    logits = _logits(params, x, cfg)
    if cfg.mtp_depth and "labels" in batch:
        aux = aux + _mtp_loss(params, x, batch, cfg, positions)
    return logits, aux


def _mtp_loss(params, h, batch, cfg, positions, weight: float = 0.1):
    """DeepSeek-V3 multi-token prediction: predict token t+2 from
    (h_t, emb(token_{t+1})) through one extra block."""
    p = params["mtp"]
    tokens, labels = batch["tokens"], batch["labels"]
    nxt = jnp.roll(tokens, -1, axis=1)
    e = embed(params["embed"], nxt, h.dtype)
    z = jnp.concatenate([rmsnorm(p["ln_h"], h, cfg.norm_eps),
                         rmsnorm(p["ln_e"], e, cfg.norm_eps)], axis=-1)
    z = z @ p["proj"].astype(h.dtype)
    z, _, _ = block_fwd(p["block"], z, cfg, positions, use_moe=False)
    logits = _logits(params, z, cfg)
    tgt = jnp.roll(labels, -1, axis=1)
    S = tokens.shape[1]
    mask = (jnp.arange(S) < S - 2)[None, :]
    return weight * _ce(logits, tgt, mask)


# ===========================================================================
# Family: hybrid (zamba2) — mamba2 backbone + shared attention block
# ===========================================================================

def _init_zamba(key, cfg: ModelConfig):
    pd = _pdtype(cfg)
    ks = jax.random.split(key, 5)
    every = cfg.hybrid.shared_attn_every
    n_groups = cfg.n_layers // every
    params = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, pd),
        "final_norm": init_rmsnorm(cfg.d_model, pd),
        # (n_groups, every, ...) stacked mamba layers
        "mamba_layers": jax.vmap(lambda kk: stacked_init(
            lambda k: {"ln": init_rmsnorm(cfg.d_model, pd),
                       "m": ssm_lib.init_mamba(k, cfg, pd)}, kk, every))(
            jax.random.split(ks[1], n_groups)),
        "shared_block": init_block(
            ks[2], cfg, use_moe=False, d_ff=cfg.hybrid.shared_block_d_ff),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[3], (cfg.d_model, cfg.vocab_size),
                                       dtype=pd)
    return params


def _zamba_trunk(params, x, cfg, positions, caches=None, cache_pos=None):
    every = cfg.hybrid.shared_attn_every
    decode = caches is not None

    def mamba_layer(h, lp, lc):
        hn = rmsnorm(lp["ln"], h, cfg.norm_eps)
        if decode:
            y, nc = ssm_lib.mamba_decode_step(lp["m"], hn, lc, cfg)
        else:
            y, nc = ssm_lib.mamba_fwd(lp["m"], hn, cfg), None
        return h + y, nc

    def group(h, inp):
        group_p, group_c, attn_c = inp

        def inner(hh, li):
            lp, lc = li
            return mamba_layer(hh, lp, lc)
        h, new_mc = jax.lax.scan(inner, h, (group_p, group_c))
        h, new_ac, _ = block_fwd(params["shared_block"], h, cfg, positions,
                                 use_moe=False, cache=attn_c,
                                 cache_pos=cache_pos)
        return h, (new_mc, new_ac)

    if not decode:
        def group_nc(h, gp):
            def inner(hh, lp):
                hh, _ = mamba_layer(hh, lp, None)
                return hh, None
            h, _ = jax.lax.scan(_maybe_remat(inner, cfg), h, gp)
            h, _, _ = block_fwd(params["shared_block"], h, cfg, positions,
                                use_moe=False)
            return h, None
        x, _ = jax.lax.scan(_maybe_remat(group_nc, cfg), x,
                            params["mamba_layers"])
        return x, jnp.float32(0.0), None
    x, (new_mc, new_ac) = jax.lax.scan(
        group, x, (params["mamba_layers"], caches["mamba"], caches["attn"]))
    return x, jnp.float32(0.0), {"mamba": new_mc, "attn": new_ac}


# ===========================================================================
# Family: xLSTM
# ===========================================================================

def _init_xlstm(key, cfg: ModelConfig):
    pd = _pdtype(cfg)
    ks = jax.random.split(key, 5)
    every = cfg.ssm.slstm_every or cfg.n_layers + 1
    n_groups = max(1, cfg.n_layers // every) if cfg.ssm.slstm_every else 1
    n_m = (every - 1) if cfg.ssm.slstm_every else cfg.n_layers
    params = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, pd),
        "final_norm": init_rmsnorm(cfg.d_model, pd),
        "mlstm_layers": jax.vmap(lambda kk: stacked_init(
            lambda k: {"ln": init_rmsnorm(cfg.d_model, pd),
                       "m": ssm_lib.init_mlstm(k, cfg, pd)}, kk, n_m))(
            jax.random.split(ks[1], n_groups)),
    }
    if cfg.ssm.slstm_every:
        params["slstm_layers"] = stacked_init(
            lambda k: {"ln": init_rmsnorm(cfg.d_model, pd),
                       "s": ssm_lib.init_slstm(k, cfg, pd)}, ks[2], n_groups)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[3], (cfg.d_model, cfg.vocab_size),
                                       dtype=pd)
    return params


def _xlstm_trunk(params, x, cfg, positions, caches=None, cache_pos=None):
    decode = caches is not None
    has_s = "slstm_layers" in params

    def m_layer(h, lp, lc):
        hn = rmsnorm(lp["ln"], h, cfg.norm_eps)
        if decode:
            y, nc = ssm_lib.mlstm_decode_step(lp["m"], hn, lc, cfg)
        else:
            y, nc = ssm_lib.mlstm_fwd(lp["m"], hn, cfg), None
        return h + y, nc

    def s_layer(h, lp, lc):
        hn = rmsnorm(lp["ln"], h, cfg.norm_eps)
        if decode:
            y, nc = ssm_lib.slstm_decode_step(lp["s"], hn, lc, cfg)
        else:
            y, _ = ssm_lib.slstm_fwd(lp["s"], hn, cfg)
            nc = None
        return h + y, nc

    def group(h, inp):
        gp_m, gc_m, gp_s, gc_s = inp

        def inner(hh, li):
            lp, lc = li
            return m_layer(hh, lp, lc)
        if decode:
            h, new_mc = jax.lax.scan(inner, h, (gp_m, gc_m))
        else:
            def inner_nc(hh, lp):
                hh, _ = m_layer(hh, lp, None)
                return hh, None
            h, _ = jax.lax.scan(inner_nc, h, gp_m)
            new_mc = None
        new_sc = None
        if has_s:
            h, new_sc = s_layer(h, gp_s, gc_s)
        return h, (new_mc, new_sc)

    n_groups = params["mlstm_layers"]["ln"]["scale"].shape[0]
    gc_m = caches["mlstm"] if decode else None
    gc_s = caches.get("slstm") if decode and has_s else None
    sp = params.get("slstm_layers")
    if not decode:
        def group_nc(h, inp):
            gp_m, gp_s = inp
            def inner_nc(hh, lp):
                hh, _ = m_layer(hh, lp, None)
                return hh, None
            h, _ = jax.lax.scan(_maybe_remat(inner_nc, cfg), h, gp_m)
            if has_s:
                h, _ = s_layer(h, gp_s, None)
            return h, None
        xs = (params["mlstm_layers"], sp if has_s else jnp.zeros((n_groups,)))
        x, _ = jax.lax.scan(_maybe_remat(group_nc, cfg), x, xs)
        return x, jnp.float32(0.0), None
    xs = (params["mlstm_layers"], gc_m,
          sp if has_s else jnp.zeros((n_groups,)),
          gc_s if has_s else jnp.zeros((n_groups,)))
    x, (new_mc, new_sc) = jax.lax.scan(group, x, xs)
    nc = {"mlstm": new_mc}
    if has_s:
        nc["slstm"] = new_sc
    return x, jnp.float32(0.0), nc


# ===========================================================================
# Family: VLM (llama-3.2-vision): interleaved gated cross-attn layers
# ===========================================================================

def _init_vlm(key, cfg: ModelConfig):
    pd = _pdtype(cfg)
    ks = jax.random.split(key, 6)
    every = cfg.vision.cross_attn_every
    n_groups = cfg.n_layers // every
    n_self = every - 1
    params = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, pd),
        "final_norm": init_rmsnorm(cfg.d_model, pd),
        "vision_proj": dense_init(ks[1], (cfg.vision.d_vision, cfg.d_model),
                                  dtype=pd),
        "self_layers": jax.vmap(lambda kk: stacked_init(
            lambda k: init_block(k, cfg, use_moe=False), kk, n_self))(
            jax.random.split(ks[2], n_groups)),
        "cross_layers": stacked_init(
            lambda k: _init_cross_block(k, cfg, pd), ks[3], n_groups),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[4], (cfg.d_model, cfg.vocab_size),
                                       dtype=pd)
    return params


def _init_cross_block(key, cfg, pd):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, pd),
        "xattn": init_cross_attn(k1, cfg, cfg.d_model, pd),
        "gate_attn": jnp.zeros((), pd),
        "ln2": init_rmsnorm(cfg.d_model, pd),
        "mlp": init_mlp(k2, cfg, dtype=pd),
        "gate_mlp": jnp.zeros((), pd),
    }


def _cross_block_fwd(p, x, memory, cfg):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    h = cross_attn_fwd(p["xattn"], h, memory, cfg)
    x = x + jnp.tanh(p["gate_attn"].astype(h.dtype)) * h
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    h = mlp_fwd(p["mlp"], h, cfg.mlp)
    return x + jnp.tanh(p["gate_mlp"].astype(h.dtype)) * h


def _vlm_trunk(params, x, cfg, positions, memory, caches=None, cache_pos=None):
    decode = caches is not None

    def group(h, inp):
        gp_self, gc_self, gp_cross = inp
        if decode:
            def inner(hh, li):
                lp, lc = li
                hh, nc, _ = block_fwd(lp, hh, cfg, positions, use_moe=False,
                                      cache=lc, cache_pos=cache_pos)
                return hh, nc
            h, new_sc = jax.lax.scan(inner, h, (gp_self, gc_self))
        else:
            def inner_nc(hh, lp):
                hh, _, _ = block_fwd(lp, hh, cfg, positions, use_moe=False)
                return hh, None
            h, _ = jax.lax.scan(inner_nc, h, gp_self)
            new_sc = None
        h = _cross_block_fwd(gp_cross, h, memory, cfg)
        return h, new_sc

    if decode:
        x, new_sc = jax.lax.scan(
            group, x, (params["self_layers"], caches["self"],
                       params["cross_layers"]))
        return x, jnp.float32(0.0), {"self": new_sc}
    n_groups = params["cross_layers"]["gate_attn"].shape[0]
    x, _ = jax.lax.scan(
        _maybe_remat(group, cfg), x,
        (params["self_layers"], jnp.zeros((n_groups,)),
         params["cross_layers"]))
    return x, jnp.float32(0.0), None


# ===========================================================================
# Family: encoder-decoder audio (whisper)
# ===========================================================================

def _init_whisper(key, cfg: ModelConfig):
    pd = _pdtype(cfg)
    ks = jax.random.split(key, 6)
    params = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, pd),
        "final_norm": init_rmsnorm(cfg.d_model, pd),
        "enc_layers": stacked_init(
            lambda k: init_block(k, cfg, use_moe=False), ks[1],
            cfg.encoder.n_layers),
        "enc_norm": init_rmsnorm(cfg.d_model, pd),
        "dec_layers": stacked_init(
            lambda k: _init_decdec_block(k, cfg, pd), ks[2], cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[3], (cfg.d_model, cfg.vocab_size),
                                       dtype=pd)
    return params


def _init_decdec_block(key, cfg, pd):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model, pd),
        "attn": init_gqa(k1, cfg, pd),
        "ln_x": init_rmsnorm(cfg.d_model, pd),
        "xattn": init_cross_attn(k2, cfg, cfg.d_model, pd),
        "ln2": init_rmsnorm(cfg.d_model, pd),
        "mlp": init_mlp(k3, cfg, dtype=pd),
    }


def _decdec_block_fwd(p, x, memory, cfg, positions, cache=None, cache_pos=None):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    h, nc = gqa_fwd(p["attn"], h, cfg, positions, cache=cache,
                    cache_pos=cache_pos, causal=True)
    x = x + h
    h = rmsnorm(p["ln_x"], x, cfg.norm_eps)
    x = x + cross_attn_fwd(p["xattn"], h, memory, cfg)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp_fwd(p["mlp"], h, cfg.mlp), nc


def _sinusoid(n: int, d: int, dtype):
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10_000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def whisper_encode(params, frames, cfg: ModelConfig):
    """frames: (B, F, d_model) precomputed conv/mel embeddings (stub)."""
    B, F, _ = frames.shape
    x = frames.astype(_cdtype(cfg)) + _sinusoid(F, cfg.d_model, _cdtype(cfg))
    positions = jnp.broadcast_to(jnp.arange(F), (B, F))
    x, _, _ = _scan_blocks(params["enc_layers"], x, cfg, positions,
                           use_moe=False, causal=False, rope=False)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _whisper_trunk(params, x, cfg, positions, memory, caches=None,
                   cache_pos=None):
    if caches is None:
        def body(carry, lp):
            h = carry
            h, _ = _decdec_block_fwd(lp, h, memory, cfg, positions)
            return h, None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["dec_layers"])
        return x, jnp.float32(0.0), None

    def body(h, inp):
        lp, lc = inp
        h, nc = _decdec_block_fwd(lp, h, memory, cfg, positions, cache=lc,
                                  cache_pos=cache_pos)
        return h, nc
    x, new_c = jax.lax.scan(body, x, (params["dec_layers"], caches["self"]))
    return x, jnp.float32(0.0), {"self": new_c}


# ===========================================================================
# Public API
# ===========================================================================

def init_model(key, cfg: ModelConfig):
    if cfg.encoder is not None:
        return _init_whisper(key, cfg)
    if cfg.hybrid is not None:
        return _init_zamba(key, cfg)
    if cfg.ssm is not None and cfg.ssm.kind == "xlstm":
        return _init_xlstm(key, cfg)
    if cfg.vision is not None:
        return _init_vlm(key, cfg)
    return _init_decoder(key, cfg)


def forward(params, batch, cfg: ModelConfig):
    """Training / prefill forward. batch: tokens (B,S) [+frames|patches]."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    cd = _cdtype(cfg)
    x = embed(params["embed"], tokens, cd)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.encoder is not None:
        memory = whisper_encode(params, batch["frames"], cfg)
        x, aux, _ = _whisper_trunk(params, x, cfg, positions, memory)
    elif cfg.hybrid is not None:
        x, aux, _ = _zamba_trunk(params, x, cfg, positions)
    elif cfg.ssm is not None and cfg.ssm.kind == "xlstm":
        x, aux, _ = _xlstm_trunk(params, x, cfg, positions)
    elif cfg.vision is not None:
        memory = (batch["patches"].astype(cd) @
                  params["vision_proj"].astype(cd))
        x, aux, _ = _vlm_trunk(params, x, cfg, positions, memory)
    else:
        x, aux, _ = _decoder_trunk(params, x, cfg, positions)
        if cfg.mtp_depth and "labels" in batch:
            aux = aux + _mtp_loss(params, x, batch, cfg, positions)
        return _logits(params, x, cfg), aux
    return _logits(params, x, cfg), aux


def _ce(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


def loss_fn(params, batch, cfg: ModelConfig):
    logits, aux = forward(params, batch, cfg)
    loss = _ce(logits, batch["labels"]) + aux
    acc = jnp.mean(jnp.argmax(logits, -1) == batch["labels"])
    return loss, {"loss": loss, "aux": aux, "accuracy": acc}


# --- decode -----------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, length: int):
    if cfg.encoder is not None:
        L = min(length, cfg.encoder.max_decoder_len)
        return {"self": _stacked_cache(cfg, cfg.n_layers, batch, L)}
    if cfg.hybrid is not None:
        every = cfg.hybrid.shared_attn_every
        n_groups = cfg.n_layers // every
        mc = _stack_tree(ssm_lib.init_mamba_cache(cfg, batch),
                         (n_groups, every))
        L = min(length, cfg.sliding_window) if cfg.sliding_window else length
        ac = _stacked_cache(cfg, n_groups, batch, L)
        return {"mamba": mc, "attn": ac}
    if cfg.ssm is not None and cfg.ssm.kind == "xlstm":
        every = cfg.ssm.slstm_every or 0
        n_groups = max(1, cfg.n_layers // every) if every else 1
        n_m = (every - 1) if every else cfg.n_layers
        mc = _stack_tree(ssm_lib.init_mlstm_cache(cfg, batch),
                         (n_groups, n_m))
        out = {"mlstm": mc}
        if every:
            out["slstm"] = _stack_tree(ssm_lib.init_slstm_cache(cfg, batch),
                                       (n_groups,))
        return out
    if cfg.vision is not None:
        every = cfg.vision.cross_attn_every
        n_groups = cfg.n_layers // every
        sc = _stack_tree(_block_cache(cfg, batch, length),
                         (n_groups, every - 1))
        return {"self": sc}
    moe_cfg = cfg.moe
    n_dense = moe_cfg.first_dense_layers if moe_cfg else cfg.n_layers
    out = {}
    if n_dense:
        out["dense"] = _stacked_cache(cfg, n_dense, batch, length)
    if moe_cfg and cfg.n_layers - n_dense:
        out["moe"] = _stacked_cache(cfg, cfg.n_layers - n_dense, batch, length)
    return out


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, memory=None):
    """tokens: (B, 1); pos: scalar int32 — current write index.
    Returns (logits (B,1,V), new_cache)."""
    B = tokens.shape[0]
    cd = _cdtype(cfg)
    x = embed(params["embed"], tokens, cd)
    positions = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos
    if cfg.encoder is not None:
        pos_c = jnp.minimum(pos, cfg.encoder.max_decoder_len - 1)
        x, _, nc = _whisper_trunk(params, x, cfg, positions, memory,
                                  caches=cache, cache_pos=pos_c)
    elif cfg.hybrid is not None:
        x, _, nc = _zamba_trunk(params, x, cfg, positions, caches=cache,
                                cache_pos=pos)
    elif cfg.ssm is not None and cfg.ssm.kind == "xlstm":
        x, _, nc = _xlstm_trunk(params, x, cfg, positions, caches=cache,
                                cache_pos=pos)
    elif cfg.vision is not None:
        x, _, nc = _vlm_trunk(params, x, cfg, positions, memory, caches=cache,
                              cache_pos=pos)
    else:
        x, _, nc = _decoder_trunk(params, x, cfg, positions, caches=cache,
                                  cache_pos=pos)
    return _logits(params, x, cfg), nc


def param_count(params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))
