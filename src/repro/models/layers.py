"""Shared neural-net building blocks (pure functional, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dense_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def zeros_init(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype=dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype),
            "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., seq, n_heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                        # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs    # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                          # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None, dtype=jnp.float32):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w_up": dense_init(k1, (d, f), dtype=dtype),
        "w_down": dense_init(k2, (f, d), dtype=dtype),
    }
    if cfg.mlp == "swiglu":
        params["w_gate"] = dense_init(k3, (d, f), dtype=dtype)
    return params


def mlp_fwd(params, x, kind: str):
    dtype = x.dtype
    up = x @ params["w_up"].astype(dtype)
    if kind == "swiglu":
        gate = x @ params["w_gate"].astype(dtype)
        h = jax.nn.silu(gate) * up
    elif kind == "relu2":                     # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(up))
    elif kind == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    return h @ params["w_down"].astype(dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": dense_init(key, (vocab, d), dtype=dtype)}


def embed(params, tokens, compute_dtype):
    return params["table"].astype(compute_dtype)[tokens]


def unembed(params, x):
    return x @ params["table"].astype(x.dtype).T
