"""The paper's four evaluation models, in JAX.

Dataset-1 samples are 3168-dim feature vectors (Appendix D): a flattened
3x32x32 content feature (3072) + genre preferences (5) + cosine similarities
to the 20 files of the genre (20) + genre feature (70) + exploitation prob (1)
= 3168. Labels: F=100 content classes.

Dataset-2 samples are L=10 past content IDs -> next content ID (100 classes).

Models (paper Fig. 7-8): FCN, CNN, SqueezeNet1-style, LSTM.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

NUM_CLASSES = 100
D1_FEATURES = 3168
IMG = (32, 32, 3)
SIDE = D1_FEATURES - 3072
SEQ_LEN = 10


def _linear(key, din, dout):
    kw, = jax.random.split(key, 1)
    return {"w": dense_init(kw, (din, dout), scale=(2.0 / din) ** 0.5),
            "b": jnp.zeros((dout,))}


def _apply_linear(p, x):
    return x @ p["w"] + p["b"]


def _conv(key, k, cin, cout):
    return {"w": dense_init(key, (k, k, cin, cout),
                            scale=(2.0 / (k * k * cin)) ** 0.5),
            "b": jnp.zeros((cout,))}


def _apply_conv(p, x, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool(x, k=2, s=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, s, s, 1), "VALID")


# --- FCN -------------------------------------------------------------------

def init_fcn(key):
    ks = jax.random.split(key, 3)
    return {"l1": _linear(ks[0], D1_FEATURES, 1024),
            "l2": _linear(ks[1], 1024, 512),
            "l3": _linear(ks[2], 512, NUM_CLASSES)}


def fcn_forward(params, x):
    h = jax.nn.relu(_apply_linear(params["l1"], x))
    h = jax.nn.relu(_apply_linear(params["l2"], h))
    return _apply_linear(params["l3"], h)


# --- CNN -------------------------------------------------------------------

def init_cnn(key):
    ks = jax.random.split(key, 5)
    return {"c1": _conv(ks[0], 3, 3, 32), "c2": _conv(ks[1], 3, 32, 64),
            "f1": _linear(ks[2], 8 * 8 * 64 + SIDE, 256),
            "f2": _linear(ks[3], 256, NUM_CLASSES)}


def cnn_forward(params, x):
    B = x.shape[0]
    img = x[:, :3072].reshape(B, *IMG)
    side = x[:, 3072:]
    h = _maxpool(jax.nn.relu(_apply_conv(params["c1"], img)))
    h = _maxpool(jax.nn.relu(_apply_conv(params["c2"], h)))
    h = jnp.concatenate([h.reshape(B, -1), side], axis=-1)
    h = jax.nn.relu(_apply_linear(params["f1"], h))
    return _apply_linear(params["f2"], h)


# --- SqueezeNet1-style -------------------------------------------------------

def _fire(key, cin, squeeze, expand):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"s": _conv(k1, 1, cin, squeeze),
            "e1": _conv(k2, 1, squeeze, expand),
            "e3": _conv(k3, 3, squeeze, expand)}


def _apply_fire(p, x):
    s = jax.nn.relu(_apply_conv(p["s"], x))
    return jnp.concatenate([jax.nn.relu(_apply_conv(p["e1"], s)),
                            jax.nn.relu(_apply_conv(p["e3"], s))], axis=-1)


def init_squeezenet(key):
    ks = jax.random.split(key, 6)
    return {"c1": _conv(ks[0], 3, 3, 64),
            "fire1": _fire(ks[1], 64, 16, 64),
            "fire2": _fire(ks[2], 128, 16, 64),
            "fire3": _fire(ks[3], 128, 32, 128),
            "head": _conv(ks[4], 1, 256, NUM_CLASSES),
            "side": _linear(ks[5], SIDE, NUM_CLASSES)}


def squeezenet_forward(params, x):
    B = x.shape[0]
    img = x[:, :3072].reshape(B, *IMG)
    side = x[:, 3072:]
    h = _maxpool(jax.nn.relu(_apply_conv(params["c1"], img)))      # 16x16x64
    h = _apply_fire(params["fire1"], h)
    h = _maxpool(_apply_fire(params["fire2"], h))                  # 8x8x128
    h = _apply_fire(params["fire3"], h)                            # 8x8x256
    h = _apply_conv(params["head"], h)                             # 8x8xC
    logits = jnp.mean(h, axis=(1, 2))
    return logits + _apply_linear(params["side"], side)


# --- LSTM (Dataset-2) --------------------------------------------------------

def _lstm_layer(key, din, dh):
    k1, k2 = jax.random.split(key)
    return {"wx": dense_init(k1, (din, 4 * dh), scale=(1.0 / din) ** 0.5),
            "wh": dense_init(k2, (dh, 4 * dh), scale=(1.0 / dh) ** 0.5),
            "b": jnp.zeros((4 * dh,))}


def _apply_lstm(p, xs):
    """xs: (B, L, din) -> (B, L, dh)."""
    B = xs.shape[0]
    dh = p["wh"].shape[0]

    def step(carry, xt):
        h, c = carry
        gates = xt @ p["wx"] + h @ p["wh"] + p["b"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((B, dh)), jnp.zeros((B, dh)))
    _, hs = jax.lax.scan(step, init, jnp.moveaxis(xs, 1, 0))
    return jnp.moveaxis(hs, 0, 1)


def init_lstm(key):
    ks = jax.random.split(key, 5)
    return {"embed": dense_init(ks[0], (NUM_CLASSES, 64)),
            "l1": _lstm_layer(ks[1], 64, 128),
            "l2": _lstm_layer(ks[2], 128, 128),
            "l3": _lstm_layer(ks[3], 128, 128),
            "head": _linear(ks[4], 128, NUM_CLASSES)}


def lstm_forward(params, x):
    """x: (B, L) int32 content ids."""
    h = params["embed"][x.astype(jnp.int32)]
    h = _apply_lstm(params["l1"], h)
    h = _apply_lstm(params["l2"], h)
    h = _apply_lstm(params["l3"], h)
    return _apply_linear(params["head"], h[:, -1])


# --- MLP (Dataset-2; beyond-paper) ------------------------------------------
# Tiny embedding MLP used by the stacked-engine scale tests/benchmarks: same
# task as the LSTM (last L content ids -> next id) at ~2% of the FLOPs, so
# thousand-client vectorized cohorts stay CPU-cheap. Not a paper model.

def init_mlp(key):
    ks = jax.random.split(key, 3)
    return {"embed": dense_init(ks[0], (NUM_CLASSES, 16)),
            "l1": _linear(ks[1], SEQ_LEN * 16, 64),
            "head": _linear(ks[2], 64, NUM_CLASSES)}


def mlp_forward(params, x):
    """x: (B, L) int32 content ids."""
    h = params["embed"][x.astype(jnp.int32)]
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(_apply_linear(params["l1"], h))
    return _apply_linear(params["head"], h)


REGISTRY = {
    "fcn": (init_fcn, fcn_forward),
    "cnn": (init_cnn, cnn_forward),
    "squeezenet": (init_squeezenet, squeezenet_forward),
    "lstm": (init_lstm, lstm_forward),
    "mlp": (init_mlp, mlp_forward),
}


def init_small(key, name: str):
    return REGISTRY[name][0](key)


def small_forward(params, x, name: str):
    return REGISTRY[name][1](params, x)


def small_loss(params, batch, name: str):
    logits = small_forward(params, batch["x"], name)
    labels = batch["y"]
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, {"loss": loss, "accuracy": acc}
