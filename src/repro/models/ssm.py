"""State-space / recurrent blocks: Mamba2 (chunked SSD) and xLSTM (mLSTM/sLSTM).

Mamba2 uses the chunked SSD formulation: intra-chunk attention-like matmuls
(MXU friendly, (B,H,Q,Q) with small Q) + an inter-chunk state scan, which is
the TPU adaptation of the paper-family GPU kernels. Decode carries
(conv_state, ssm_state) and is O(1) in context length — this is why the
ssm/hybrid architectures run the long_500k shape.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm

HEAD_P = 64  # mamba2 head dim


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------

def mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = max(1, d_inner // HEAD_P)
    d_inner = n_heads * HEAD_P
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32):
    s = cfg.ssm
    d_inner, H, conv_dim = mamba_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_inner + 2 * s.n_groups * s.d_state + H),
                              dtype=dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_dim), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "norm": init_rmsnorm(d_inner, dtype),
        "out_proj": dense_init(ks[2], (d_inner, d), dtype=dtype),
    }


def _causal_conv(x, w, b):
    """x: (B,L,C); w: (K,C) depthwise causal conv."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _split_proj(cfg, proj):
    s = cfg.ssm
    d_inner, H, _ = mamba_dims(cfg)
    gn = s.n_groups * s.d_state
    z, xi, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn], axis=-1)
    return z, xi, Bm, Cm, dt


def mamba_fwd(params, x, cfg: ModelConfig):
    """Chunked SSD. x: (B, L, d) -> (B, L, d). L must be divisible by chunk."""
    s = cfg.ssm
    d_inner, H, conv_dim = mamba_dims(cfg)
    N, G, Q = s.d_state, s.n_groups, s.chunk_size
    B_, L, _ = x.shape
    dt_ = x.dtype
    proj = x @ params["in_proj"].astype(dt_)
    z, xi, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"].astype(dt_),
                                        params["conv_b"].astype(dt_)))
    xi, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + G * N], axis=-1)
    xh = xi.reshape(B_, L, H, HEAD_P)
    Bm = Bm.reshape(B_, L, G, N).mean(2)            # (B,L,N)  (G=1 typical)
    Cm = Cm.reshape(B_, L, G, N).mean(2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))        # (B,L,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))                  # (H,)
    la = dt * A                                                        # log decay

    nc = L // Q
    assert nc * Q == L, f"seq {L} not divisible by chunk {Q}"
    xc = xh.reshape(B_, nc, Q, H, HEAD_P)
    Bc = Bm.reshape(B_, nc, Q, N)
    Cc = Cm.reshape(B_, nc, Q, N)
    lac = la.reshape(B_, nc, Q, H)
    dtc = dt.reshape(B_, nc, Q, H)

    seg = jnp.cumsum(lac, axis=2)                                      # (B,nc,Q,H)

    def chunk_step(h0, inp):
        xq, Bq, Cq, segq, laq, dtq = inp
        # h0: (B,H,P,N). All within a single chunk.
        # intra-chunk: scores[t,s] = (C_t.B_s) exp(seg_t - seg_s) dt_s, s<=t
        cb = jnp.einsum("btn,bsn->bts", Cq, Bq)                        # (B,Q,Q)
        dec = jnp.exp(segq[:, :, None, :] - segq[:, None, :, :])       # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        w = jnp.where(tri, cb[..., None] * dec * dtq[:, None, :, :], 0.0)
        y_intra = jnp.einsum("btsh,bshp->bthp", w.astype(xq.dtype), xq)
        # contribution of incoming state
        y_state = jnp.einsum("btn,bhpn,bth->bthp", Cq, h0.astype(xq.dtype),
                             jnp.exp(segq).astype(xq.dtype))
        # state update: h' = exp(seg_Q) h0 + sum_s exp(seg_Q - seg_s) dt_s B_s x_s
        decay_out = jnp.exp(segq[:, -1:, :] - segq)                    # (B,Q,H)
        h_in = jnp.einsum("bsh,bsn,bshp->bhpn",
                          (decay_out * dtq).astype(xq.dtype), Bq, xq)
        h1 = (jnp.exp(segq[:, -1, :])[:, :, None, None].astype(jnp.float32)
              * h0 + h_in.astype(jnp.float32))
        return h1, y_intra + y_state

    h0 = jnp.zeros((B_, H, HEAD_P, N), jnp.float32)
    inputs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(Bc, 1, 0),
              jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(seg, 1, 0),
              jnp.moveaxis(lac, 1, 0), jnp.moveaxis(dtc, 1, 0))
    _, ys = jax.lax.scan(chunk_step, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, L, H, HEAD_P)
    y = y + params["D"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(B_, L, d_inner)
    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["out_proj"].astype(dt_)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_inner, H, conv_dim = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "h": jnp.zeros((batch, H, HEAD_P, s.d_state), jnp.float32),
    }


def mamba_decode_step(params, x, cache, cfg: ModelConfig):
    """x: (B, 1, d). O(1) decode. Returns (y, new_cache)."""
    s = cfg.ssm
    d_inner, H, conv_dim = mamba_dims(cfg)
    N, G = s.d_state, s.n_groups
    B_, _, d = x.shape
    dt_ = x.dtype
    proj = x @ params["in_proj"].astype(dt_)
    z, xi, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)                  # (B,1,C)
    window = jnp.concatenate([cache["conv"].astype(dt_), conv_in], axis=1)
    w = params["conv_w"].astype(dt_)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"].astype(dt_)
    conv_out = jax.nn.silu(conv_out)[:, None, :]
    xi, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + G * N], axis=-1)
    xh = xi.reshape(B_, H, HEAD_P)
    Bv = Bm.reshape(B_, G, N).mean(1)
    Cv = Cm.reshape(B_, G, N).mean(1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))       # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                               # (B,H)
    h = (a[:, :, None, None] * cache["h"] +
         jnp.einsum("bh,bn,bhp->bhpn", dt, Bv.astype(jnp.float32),
                    xh.astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", Cv.astype(jnp.float32), h).astype(dt_)
    y = y + params["D"].astype(dt_)[None, :, None] * xh
    y = y.reshape(B_, 1, d_inner)
    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    y = y @ params["out_proj"].astype(dt_)
    new_cache = {"conv": window[:, 1:, :].astype(cache["conv"].dtype), "h": h}
    return y, new_cache


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory)
# ---------------------------------------------------------------------------

def xlstm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = int(cfg.d_model * s.mlstm_proj_factor)
    H = cfg.n_heads
    P = d_inner // H
    return d_inner, H, P


def init_mlstm(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, H, P = xlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], (d, 2 * d_inner), dtype=dtype),
        "conv_w": dense_init(ks[1], (4, d_inner), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "wq": dense_init(ks[2], (d_inner, d_inner), dtype=dtype),
        "wk": dense_init(ks[3], (d_inner, d_inner), dtype=dtype),
        "wv": dense_init(ks[4], (d_inner, d_inner), dtype=dtype),
        "w_gates": dense_init(ks[5], (d_inner, 2 * H), dtype=dtype),
        "gate_bias": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]
                                     ).astype(dtype),
        "norm": init_rmsnorm(d_inner, dtype),
        "down_proj": dense_init(ks[6], (d_inner, d), dtype=dtype),
    }


def mlstm_fwd(params, x, cfg: ModelConfig):
    """mLSTM forward. Dispatches to the chunkwise form for long sequences
    (linear memory in S); quadratic parallel form otherwise. x: (B,L,d)."""
    Q = min(cfg.ssm.chunk_size, 256)
    if x.shape[1] >= 2 * Q and x.shape[1] % Q == 0:
        return mlstm_fwd_chunked(params, x, cfg)
    return _mlstm_fwd_quadratic(params, x, cfg)


def _mlstm_fwd_quadratic(params, x, cfg: ModelConfig):
    """Parallel (quadratic) mLSTM forward. x: (B,L,d)."""
    d_inner, H, P = xlstm_dims(cfg)
    B_, L, _ = x.shape
    dt_ = x.dtype
    up = x @ params["up_proj"].astype(dt_)
    xi, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xi, params["conv_w"].astype(dt_),
                                  params["conv_b"].astype(dt_)))
    q = (xc @ params["wq"].astype(dt_)).reshape(B_, L, H, P)
    k = (xc @ params["wk"].astype(dt_)).reshape(B_, L, H, P) / (P ** 0.5)
    v = (xi @ params["wv"].astype(dt_)).reshape(B_, L, H, P)
    gates = (xi @ params["w_gates"].astype(dt_)).astype(jnp.float32) \
        + params["gate_bias"].astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)                              # (B,L,H)
    logf = jax.nn.log_sigmoid(fg)
    cumf = jnp.cumsum(logf, axis=1)
    # D[t,s] = cumf_t - cumf_s + i_s  (s <= t)
    Dm = cumf[:, :, None, :] - cumf[:, None, :, :] + ig[:, None, :, :]  # (B,T,S,H)
    tri = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
    Dm = jnp.where(tri, Dm, -jnp.inf)
    m = jnp.max(Dm, axis=2, keepdims=True)                             # (B,T,1,H)
    w = jnp.exp(Dm - m)                                                # (B,T,S,H)
    scores = jnp.einsum("bthp,bshp->btsh", q, k).astype(jnp.float32) * w
    norm = jnp.maximum(jnp.abs(jnp.sum(scores, axis=2, keepdims=True)),
                       jnp.exp(-m))                                    # (B,T,1,H)
    scores = (scores / norm).astype(dt_)
    h = jnp.einsum("btsh,bshp->bthp", scores, v).reshape(B_, L, d_inner)
    h = rmsnorm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    return h @ params["down_proj"].astype(dt_)


def mlstm_fwd_chunked(params, x, cfg: ModelConfig):
    """Chunkwise-stabilized mLSTM (§Perf: the quadratic parallel form
    materializes (B,H,S,S) — 4.3e9 elements at 32k — while this form carries
    the matrix memory (C, n, m) across chunks of length Q and only builds
    (B,H,Q,Q) blocks, making prefill memory linear in S).

    Math: with per-chunk local cumsum F_tau = sum_{r<=tau} logf_r and
    D[tau,s] = F_tau - F_s + i_s (s<=tau), position tau combines
      inter: exp(F_tau + m_state - M) * (C q) with running max
      M = max(F_tau + m_state, max_s D[tau,s]); intra as usual; and the
    chunk-end state update mirrors the decode recurrence exactly.
    """
    d_inner, H, P = xlstm_dims(cfg)
    Q = min(cfg.ssm.chunk_size, 256)
    B_, L, _ = x.shape
    dt_ = x.dtype
    up = x @ params["up_proj"].astype(dt_)
    xi, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xi, params["conv_w"].astype(dt_),
                                  params["conv_b"].astype(dt_)))
    q = (xc @ params["wq"].astype(dt_)).reshape(B_, L, H, P)
    k = (xc @ params["wk"].astype(dt_)).reshape(B_, L, H, P) / (P ** 0.5)
    v = (xi @ params["wv"].astype(dt_)).reshape(B_, L, H, P)
    gates = (xi @ params["w_gates"].astype(dt_)).astype(jnp.float32) \
        + params["gate_bias"].astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)                              # (B,L,H)
    logf = jax.nn.log_sigmoid(fg)

    nc = L // Q
    assert nc * Q == L, (L, Q)
    qc = jnp.moveaxis(q.reshape(B_, nc, Q, H, P), 1, 0).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(B_, nc, Q, H, P), 1, 0).astype(jnp.float32)
    vc = jnp.moveaxis(v.reshape(B_, nc, Q, H, P), 1, 0).astype(jnp.float32)
    ic = jnp.moveaxis(ig.reshape(B_, nc, Q, H), 1, 0)
    fc = jnp.moveaxis(logf.reshape(B_, nc, Q, H), 1, 0)

    def chunk(carry, inp):
        C, n, m = carry                       # (B,H,P,P), (B,H,P), (B,H)
        qq, kk, vv, ii, ff = inp
        F = jnp.cumsum(ff, axis=1)            # (B,Q,H) local cumsum
        # D[tau,s] = F_tau - F_s + i_s, s <= tau
        D = F[:, :, None, :] - F[:, None, :, :] + ii[:, None, :, :]
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        D = jnp.where(tri, D, -jnp.inf)
        m_intra = jnp.max(D, axis=2)          # (B,Q,H)
        m_inter = F + m[:, None, :]           # (B,Q,H)
        M = jnp.maximum(m_intra, m_inter)
        w = jnp.exp(D - M[:, :, None, :])     # (B,Q,S,H)
        scores = jnp.einsum("bthp,bshp->btsh", qq, kk) * w
        inter_scale = jnp.exp(m_inter - M)    # (B,Q,H)
        num_inter = jnp.einsum("bhpq,bthq->bthp", C, qq) \
            * inter_scale[..., None]
        num = jnp.einsum("btsh,bshp->bthp", scores, vv) + num_inter
        den = (jnp.sum(scores, axis=2)
               + jnp.einsum("bhp,bthp->bth", n, qq) * inter_scale)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-M))
        h = num / den[..., None]              # (B,Q,H,P)
        # state update (mirror of the decode recurrence over the chunk)
        FQ = F[:, -1, :]                      # (B,H)
        m_endc = jnp.max(FQ[:, None, :] - F + ii, axis=1)   # (B,H)
        m_new = jnp.maximum(FQ + m, m_endc)
        decay = jnp.exp(FQ[:, None, :] - F + ii - m_new[:, None, :])
        C_new = jnp.exp(FQ + m - m_new)[:, :, None, None] * C + \
            jnp.einsum("bsh,bshp,bshq->bhpq", decay, vv, kk)
        n_new = jnp.exp(FQ + m - m_new)[:, :, None] * n + \
            jnp.einsum("bsh,bshp->bhp", decay, kk)
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((B_, H, P, P), jnp.float32)
    n0 = jnp.zeros((B_, H, P), jnp.float32)
    m0 = jnp.full((B_, H), -1e9, jnp.float32)
    _, hs = jax.lax.scan(chunk, (C0, n0, m0), (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B_, L, d_inner).astype(dt_)
    h = rmsnorm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    return h @ params["down_proj"].astype(dt_)


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    d_inner, H, P = xlstm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, 3, d_inner), jnp.float32),
        "C": jnp.zeros((batch, H, P, P), jnp.float32),
        "n": jnp.zeros((batch, H, P), jnp.float32),
        "m": jnp.full((batch, H), -1e9, jnp.float32),
    }


def mlstm_decode_step(params, x, cache, cfg: ModelConfig):
    d_inner, H, P = xlstm_dims(cfg)
    B_, _, d = x.shape
    dt_ = x.dtype
    up = x @ params["up_proj"].astype(dt_)
    xi, z = jnp.split(up, 2, axis=-1)                                  # (B,1,di)
    window = jnp.concatenate([cache["conv"], xi.astype(jnp.float32)], axis=1)
    xc = jnp.einsum("bkc,kc->bc", window.astype(dt_),
                    params["conv_w"].astype(dt_)) + params["conv_b"].astype(dt_)
    xc = jax.nn.silu(xc)[:, None, :]
    q = (xc @ params["wq"].astype(dt_)).reshape(B_, H, P).astype(jnp.float32)
    k = ((xc @ params["wk"].astype(dt_)).reshape(B_, H, P) / (P ** 0.5)
         ).astype(jnp.float32)
    v = (xi @ params["wv"].astype(dt_)).reshape(B_, H, P).astype(jnp.float32)
    gates = (xi @ params["w_gates"].astype(dt_)).astype(jnp.float32)[:, 0] \
        + params["gate_bias"].astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)                              # (B,H)
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + cache["m"], ig)
    fs = jnp.exp(logf + cache["m"] - m_new)[:, :, None]
    is_ = jnp.exp(ig - m_new)[:, :, None]
    C = fs[..., None] * cache["C"] + is_[..., None] * jnp.einsum(
        "bhp,bhq->bhpq", v, k)
    n = fs * cache["n"] + is_ * k
    num = jnp.einsum("bhpq,bhq->bhp", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q)),
                      jnp.exp(-m_new))[:, :, None]
    h = (num / den).reshape(B_, 1, d_inner).astype(dt_)
    h = rmsnorm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    y = h @ params["down_proj"].astype(dt_)
    cache = {"conv": window[:, 1:, :], "C": C, "n": n, "m": m_new}
    return y, cache


def init_slstm(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.n_heads
    P = d // H
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), dtype=dtype),          # i,f,z,o
        "r": dense_init(ks[1], (H, P, 4 * P), dtype=dtype),          # block-diag rec
        "bias": jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                                 jnp.zeros((2 * d,))]).astype(dtype),
        "norm": init_rmsnorm(d, dtype),
        "out_proj": dense_init(ks[2], (d, d), dtype=dtype),
    }


def _slstm_cell(params, carry, xt, H, P):
    """One sLSTM step. carry: (c,n,m,h) each (B,H,P) / m (B,H,P)."""
    c, n, m, h = carry
    pre = xt + jnp.einsum("bhp,hpq->bhq", h, params["r"].astype(xt.dtype)
                          ).reshape(xt.shape)                          # (B,4d)
    B_ = xt.shape[0]
    pre = pre.reshape(B_, 4, H, P)
    i_raw, f_raw, z_raw, o_raw = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    i_raw = i_raw.astype(jnp.float32)
    f_raw = f_raw.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    i_s = jnp.exp(i_raw - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z_raw.astype(jnp.float32))
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o_raw.astype(jnp.float32)) * c_new / \
        jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_fwd(params, x, cfg: ModelConfig, carry=None):
    """Recurrent sLSTM over the sequence. x: (B,L,d)."""
    H = cfg.n_heads
    B_, L, d = x.shape
    P = d // H
    dt_ = x.dtype
    pre = x @ params["w_in"].astype(dt_) + params["bias"].astype(dt_)  # (B,L,4d)
    if carry is None:
        zero = jnp.zeros((B_, H, P), jnp.float32)
        carry = (zero, zero, jnp.full((B_, H, P), -1e9, jnp.float32), zero)

    def step(carry, xt):
        return _slstm_cell(params, carry, xt, H, P)

    carry, hs = jax.lax.scan(step, carry, jnp.moveaxis(pre, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B_, L, d).astype(dt_)
    h = rmsnorm(params["norm"], h, cfg.norm_eps)
    return h @ params["out_proj"].astype(dt_), carry


def init_slstm_cache(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    P = cfg.d_model // H
    zero = jnp.zeros((batch, H, P), jnp.float32)
    return {"c": zero, "n": zero, "m": jnp.full((batch, H, P), -1e9, jnp.float32),
            "h": zero}


def slstm_decode_step(params, x, cache, cfg: ModelConfig):
    carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    y, carry = slstm_fwd(params, x, cfg, carry=carry)
    c, n, m, h = carry
    return y, {"c": c, "n": n, "m": m, "h": h}
