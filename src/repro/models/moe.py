"""Mixture-of-Experts layer with capacity-based sort dispatch.

Design (TPU-native, expert-parallel friendly):
  1. router: softmax logits, top-k selection, renormalized gates
  2. dispatch: sort token-expert assignments by expert id, drop beyond a fixed
     per-expert capacity C = ceil(T*k/E * capacity_factor) -> gather (E, C, d)
  3. batched expert matmuls (E, C, d) x (E, d, f) — expert axis shardable
  4. combine: scatter-add gated expert outputs back to tokens

Supports DeepSeek-V3 shared experts (always-on dense experts) and Arctic's
dense residual MLP in parallel with the MoE branch. Returns the Switch-style
load-balance auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, init_mlp, mlp_fwd


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, E), dtype=dtype),
        "w_gate": dense_init(ks[1], (E, d, f), dtype=dtype),
        "w_up": dense_init(ks[2], (E, d, f), dtype=dtype),
        "w_down": dense_init(ks[3], (E, f, d), dtype=dtype),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=f * m.num_shared_experts,
                               dtype=dtype)
    if m.dense_residual_d_ff:
        p["dense_residual"] = init_mlp(ks[5], cfg, d_ff=m.dense_residual_d_ff,
                                       dtype=dtype)
    return p


def _capacity(T: int, k: int, E: int, factor: float) -> int:
    c = int((T * k / E) * factor) + 1
    return min(max(8, c), T)  # floor for tiny smokes, never exceed all tokens


def moe_fwd(params, x, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    T = B * S
    dt = x.dtype
    xt = x.reshape(T, d)

    # --- router ---------------------------------------------------------
    logits = (xt @ params["router"].astype(dt)).astype(jnp.float32)   # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)                   # (T,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e mean_frac_e * mean_prob_e
    frac = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (T * k)
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.router_aux_coef * E * jnp.sum(frac * mean_prob)

    # --- dispatch (sort by expert, capacity drop) -------------------------
    C = _capacity(T, k, E, m.capacity_factor)
    flat_expert = expert_ids.reshape(-1)                              # (T*k,)
    flat_token = jnp.repeat(jnp.arange(T), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    group_start = jnp.searchsorted(se, jnp.arange(E), side="left")    # (E,)
    pos = jnp.arange(T * k) - group_start[se]                         # slot in expert
    keep = pos < C
    # token table (E, C): index of the token in each expert slot; T = "empty"
    token_table = jnp.full((E, C), T, dtype=jnp.int32)
    token_table = token_table.at[se, jnp.where(keep, pos, 0)].set(
        jnp.where(keep, st, T).astype(jnp.int32), mode="drop")
    gate_table = jnp.zeros((E, C), jnp.float32).at[
        se, jnp.where(keep, pos, 0)].set(jnp.where(keep, sg, 0.0), mode="drop")

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), dt)], axis=0)     # row T = zeros
    xe = xt_pad[token_table]                                          # (E, C, d)

    # --- expert computation (batched over E; shardable on expert axis) ----
    gate = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))   # (E, C, d)

    # --- combine ----------------------------------------------------------
    yt = jnp.zeros((T + 1, d), dt).at[token_table].add(
        ye * gate_table[..., None].astype(dt))
    y = yt[:T].reshape(B, S, d)

    if m.num_shared_experts:
        y = y + mlp_fwd(params["shared"], x, "swiglu")
    if m.dense_residual_d_ff:
        y = y + mlp_fwd(params["dense_residual"], x, "swiglu")
    return y, aux
