"""Attention variants: GQA (opt. bias / sliding window), MLA, cross-attention.

All functions are pure; KV caches are explicit pytrees threaded through.
Cache layout (full attention): {"k": (B, L, n_kv, hd), "v": (B, L, n_kv, hd)}
with the current write position passed separately (static-shape friendly).
Sliding-window caches are ring buffers of length ``window``.
MLA decode caches the *compressed latent* (B, L, kv_lora_rank) + shared rope key,
using the absorbed-matmul formulation (DeepSeek-V2 §2.1) so cache bytes are
independent of the number of heads.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, init_rmsnorm, rmsnorm


def _sdpa(q, k, v, mask, scale):
    """q: (B,S,H,D) k/v: (B,L,Hkv,D[v]) mask: broadcastable (B,1,S,L) or None."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        if S > 1:
            # constrain AFTER the repeat: sharding Hkv(<TP degree) heads
            # directly forces uneven/padded layouts + involuntary remat
            # (§Perf B3). Decode (S==1) must NOT constrain here — it would
            # materialize the repeated KV cache (§Perf E1 regression).
            k = shard_heads(k)
            v = shard_heads(v)
    logits = jnp.einsum("bshd,bthd->bhst", q, k) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def causal_mask(s_q: int, s_k: int, q_offset=0, window: int = 0):
    """(1,1,S,L) boolean mask; window>0 limits lookback (sliding window)."""
    qi = jnp.arange(s_q)[:, None] + q_offset
    kj = jnp.arange(s_k)[None, :]
    m = kj <= qi
    if window > 0:
        m = m & (qi - kj < window)
    return m[None, None]


def use_flash() -> bool:
    return os.environ.get("REPRO_USE_FLASH", "0") == "1"


def shard_heads(x, axis: int = 2):
    """Constrain the heads axis of (B, S, H, D) to the 'model' mesh axis.

    §Perf iteration B2: without this, architectures whose head count does not
    divide the model axis (arctic: 56 heads on 16-way TP) let the partitioner
    shard the *head_dim* (contracting) axis instead, which turns every
    attention score matmul into a full (B,H,S,S) all-reduce. Forcing (padded)
    head sharding trades <=14% head padding for that all-reduce. No-op when
    no mesh with a 'model' axis is ambient. Set REPRO_ACT_SHARDING=0 to
    reproduce the unconstrained baseline."""
    if os.environ.get("REPRO_ACT_SHARDING", "1") != "1":
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
            return x
        spec = [None] * x.ndim
        spec[axis] = "model"
        from jax.sharding import PartitionSpec
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))
    except Exception:
        return x


def _attention(q, k, v, mask, scale, *, causal_full: bool):
    """Dispatch: Pallas flash kernel (interpret on CPU) or XLA reference."""
    if use_flash() and causal_full and q.shape[1] == k.shape[1]:
        from repro.kernels import ops  # lazy: kernels are optional at import time
        return ops.flash_attention(q, k, v, causal=True, scale=scale)
    return _sdpa(q, k, v, mask, scale)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig, dtype=jnp.float32):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, Hkv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, Hkv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype=dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype=dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype=dtype)
    return p


def gqa_fwd(params, x, cfg: ModelConfig, positions, *, cache=None,
            cache_pos=None, causal: bool = True, rope: bool = True):
    """x: (B,S,d). Training/prefill when cache is None; else single-step decode
    (S==1) writing into the cache at ``cache_pos``.

    Returns (y, new_cache)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if S > 1:
        # decode (S==1) is excluded: constraining single-token q/kv reshards
        # the KV cache instead of helping (§Perf E1)
        q = shard_heads(q)
        if Hkv == H:
            k = shard_heads(k)
            v = shard_heads(v)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    scale = hd ** -0.5
    window = cfg.sliding_window

    if cache is None:
        mask = causal_mask(S, S, window=window) if causal else None
        o = _attention(q, k, v, mask, scale,
                       causal_full=causal and window == 0)
        new_cache = None
    else:
        # decode: S == 1
        L = cache["k"].shape[1]
        if window > 0:
            slot = cache_pos % L                      # ring buffer (L == window)
        else:
            slot = cache_pos
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, slot, 0, 0))
        idx = jnp.arange(L)
        if window > 0:
            # ring buffer: absolute position of slot j
            abs_pos = cache_pos - ((slot - idx) % L)
            valid = (abs_pos >= 0) & (abs_pos <= cache_pos)
        else:
            valid = idx <= cache_pos
        mask = valid[None, None, None, :]
        o = _sdpa(q, ck.astype(dt), cv.astype(dt), mask, scale)
        new_cache = {"k": ck, "v": cv}
    y = o.reshape(B, S, H * hd) @ params["wo"].astype(dt)
    return y, new_cache


def init_gqa_cache(cfg: ModelConfig, batch: int, length: int, dtype=jnp.bfloat16):
    L = min(length, cfg.sliding_window) if cfg.sliding_window else length
    shape = (batch, L, cfg.n_kv_heads, cfg.resolved_head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# Cross-attention (VLM image layers, whisper decoder)
# ---------------------------------------------------------------------------

def init_cross_attn(key, cfg: ModelConfig, d_memory: int, dtype=jnp.float32):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d_memory, Hkv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d_memory, Hkv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype=dtype),
    }


def cross_attn_fwd(params, x, memory, cfg: ModelConfig):
    """x: (B,S,d); memory: (B,M,d_mem). Full (non-causal) attention over memory."""
    B, S, _ = x.shape
    M = memory.shape[1]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (memory @ params["wk"].astype(dt)).reshape(B, M, Hkv, hd)
    v = (memory @ params["wv"].astype(dt)).reshape(B, M, Hkv, hd)
    o = _sdpa(q, k, v, None, hd ** -0.5)
    return o.reshape(B, S, H * hd) @ params["wo"].astype(dt)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype=dtype),
        "q_norm": init_rmsnorm(m.q_lora_rank, dtype=dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, H * qk), dtype=dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            dtype=dtype),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dtype=dtype),
        "wkv_b": dense_init(ks[3], (m.kv_lora_rank,
                                    H * (m.qk_nope_head_dim + m.v_head_dim)),
                            dtype=dtype),
        "wo": dense_init(ks[4], (H * m.v_head_dim, d), dtype=dtype),
    }


def _mla_qkv(params, x, cfg: ModelConfig, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    dt = x.dtype
    q = rmsnorm(params["q_norm"], x @ params["wq_a"].astype(dt), cfg.norm_eps)
    q = (q @ params["wq_b"].astype(dt)).reshape(
        B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = x @ params["wkv_a"].astype(dt)
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)       # (B,S,rank)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,r)
    return q_nope, q_rope, c_kv, k_rope


def mla_fwd(params, x, cfg: ModelConfig, positions, *, cache=None, cache_pos=None):
    """MLA attention. Prefill/train: naive expansion. Decode: absorbed form over
    the latent cache {"c": (B,L,rank), "k_rope": (B,L,r)}."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    dt = x.dtype
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, cfg, positions)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    wkv_b = params["wkv_b"].astype(dt).reshape(
        m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    w_k = wkv_b[:, :, :m.qk_nope_head_dim]                       # (rank,H,dk)
    w_v = wkv_b[:, :, m.qk_nope_head_dim:]                       # (rank,H,dv)

    if cache is None:
        k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, w_k)
        v = shard_heads(jnp.einsum("bsr,rhd->bshd", c_kv, w_v))
        k = shard_heads(jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))],
            axis=-1))
        q = shard_heads(jnp.concatenate([q_nope, q_rope], axis=-1))
        mask = causal_mask(S, S)
        o = _attention(q, k, v, mask, scale, causal_full=True)
        new_cache = None
    else:
        # absorbed decode: scores = (q_nope W_k^T) c^T + q_rope k_rope^T
        L = cache["c"].shape[1]
        c_new = jax.lax.dynamic_update_slice(
            cache["c"], c_kv.astype(cache["c"].dtype), (0, cache_pos, 0))
        r_new = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0, :].astype(cache["k_rope"].dtype),
            (0, cache_pos, 0))
        q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, w_k)        # (B,1,H,rank)
        logits = (jnp.einsum("bshr,btr->bhst", q_abs, c_new.astype(dt)) +
                  jnp.einsum("bshd,btd->bhst", q_rope, r_new.astype(dt))) * scale
        valid = (jnp.arange(L) <= cache_pos)[None, None, None, :]
        logits = jnp.where(valid, logits, jnp.finfo(logits.dtype).min)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(dt)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, c_new.astype(dt))
        o = jnp.einsum("bshr,rhd->bshd", o_lat, w_v)             # (B,1,H,dv)
        new_cache = {"c": c_new, "k_rope": r_new}
    y = o.reshape(B, S, H * o.shape[-1]) @ params["wo"].astype(dt)
    return y, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, length: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {"c": jnp.zeros((batch, length, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, length, m.qk_rope_head_dim), dtype)}
