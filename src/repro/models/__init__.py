from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_model, loss_fn, param_count)
from repro.models.small import init_small, small_forward, small_loss

__all__ = ["decode_step", "forward", "init_cache", "init_model", "loss_fn",
           "param_count", "init_small", "small_forward", "small_loss"]
