"""Checkpointing: params pytrees and full online-run state.

Two layers, one on-disk convention (``<path>[.npz]`` + ``<path>.meta.json``):

  * params-only helpers (``save`` / ``restore`` / ``load_metadata``) — npz
    with path-flattened keys, used by ``launch/train.py`` and the examples.
    Host-gathered; adequate for the CPU engines; a real deployment would
    swap in per-shard array serialization.
  * run-state snapshots (``save_run_state`` / ``load_run_state`` in
    ``run_state.py``) — versioned nested-tree snapshots covering everything
    a long online FL run accumulates (FIFO buffers, staged arrivals, server
    contribution buffers, scores, staleness, Generator streams). The
    harness wiring lives in ``repro/harness/experiments.py`` (``save_every_k`` /
    ``resume_from``); resume determinism is proven bit-exactly by
    ``tests/test_checkpoint_resume.py``.

Structure or version mismatches raise ``CheckpointError`` with the offending
keys/dtypes named — never a bare ``assert`` or a silent cast.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint.run_state import (FORMAT_VERSION, V1_FORMAT,
                                        CheckpointError, _npz_path,
                                        atomic_write, check_version,
                                        diff_snapshots, find_sidecar,
                                        generator_state, load_run_state,
                                        meta_path, parse_sidecar,
                                        read_sidecar, save_run_state,
                                        set_generator_state,
                                        validate_cohort_shapes)
from repro.checkpoint.streaming import (AsyncCheckpointWriter,
                                        BlockingCheckpointWriter, clear_claim,
                                        committed_snapshots, delete_snapshot,
                                        is_committed, latest_checkpoint,
                                        load_run_state_v2, prune_checkpoints,
                                        save_run_state_v2, snapshot_round,
                                        write_claim)

__all__ = [
    "AsyncCheckpointWriter", "BlockingCheckpointWriter", "CheckpointError",
    "FORMAT_VERSION", "V1_FORMAT", "clear_claim", "committed_snapshots",
    "delete_snapshot", "diff_snapshots", "generator_state", "is_committed",
    "latest_checkpoint", "load_metadata", "load_run_state",
    "load_run_state_v2", "prune_checkpoints", "restore", "save",
    "save_run_state", "save_run_state_v2", "set_generator_state",
    "snapshot_round", "validate_cohort_shapes", "write_claim",
]


def _key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _flatten(params) -> dict:
    return {_key(path): np.asarray(leaf)
            for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]}


def save(path, params, step: int = 0, metadata: dict = None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(params)
    atomic_write(_npz_path(path), lambda tmp: np.savez(tmp, **flat))
    atomic_write(meta_path(path), lambda tmp: tmp.write_text(
        json.dumps({"format_version": V1_FORMAT, "kind": "params",
                    "step": step, **(metadata or {})})))


def restore(path, like):
    """Restore into the structure of ``like`` (a params pytree). Raises
    ``CheckpointError`` naming missing/extra keys or dtype mismatches, and
    refuses future snapshot-format versions (legacy sidecar-less / unversioned
    checkpoints still load)."""
    sidecar = find_sidecar(path)
    if sidecar is not None:
        check_version(parse_sidecar(sidecar), path)
    npz = _npz_path(path)
    if not npz.exists():
        raise CheckpointError(f"checkpoint array file {npz} not found")
    data = np.load(npz)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    want = {_key(pp): leaf for pp, leaf in flat}
    missing = sorted(set(want) - set(data.files))
    extra = sorted(set(data.files) - set(want))
    if missing or extra:
        raise CheckpointError(
            f"checkpoint {path} does not match the target structure: "
            f"missing keys {missing or '[]'}, extra keys {extra or '[]'}")
    bad_dtype = [f"{k}: checkpoint {data[k].dtype} != target {v.dtype}"
                 for k, v in want.items() if data[k].dtype != v.dtype]
    if bad_dtype:
        raise CheckpointError(
            f"checkpoint {path} dtype mismatch: " + "; ".join(bad_dtype))
    return jax.tree_util.tree_unflatten(
        treedef, [data[_key(pp)] for pp, _ in flat])


def load_metadata(path) -> dict:
    """The checkpoint's sidecar metadata; ``CheckpointError`` (naming the
    path) when the sidecar is absent, instead of a deep ``FileNotFoundError``."""
    meta = read_sidecar(path)
    check_version(meta, path)
    return meta
