"""npz checkpointing with path-flattened keys (host-gathered; adequate for the
CPU engine; a real deployment would swap in per-shard array serialization)."""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np


def _key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _flatten(params) -> dict:
    return {_key(path): np.asarray(leaf)
            for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]}


def save(path, params, step: int = 0, metadata: dict = None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(path, **_flatten(params))
    Path(str(path) + ".meta.json").write_text(
        json.dumps({"step": step, **(metadata or {})}))


def restore(path, like):
    """Restore into the structure of ``like`` (a params pytree)."""
    p = str(path)
    data = np.load(p if p.endswith(".npz") else p + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    assert set(data.files) == {_key(pp) for pp, _ in flat}, \
        "checkpoint structure mismatch"
    new_leaves = [data[_key(pp)].astype(leaf.dtype) for pp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def load_metadata(path) -> dict:
    return json.loads(Path(str(path) + ".meta.json").read_text())
