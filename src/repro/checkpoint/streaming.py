"""Streaming per-shard run-state snapshots (``run_state/v2``) + retention.

The v1 layout (``run_state.py``) host-gathers every array into one blocking
``.npz`` on the round loop — already the wrong shape at one host for a
mesh-sharded pod buffer, fatal multi-host. v2 replaces the archive with a
per-snapshot *directory*:

    round_00006/
      a00000.s00.npy ... a00042.s07.npy   per-shard array files
      manifest.json                       tree skeleton + shard table
      COMMIT.json                         commit marker, written last

  * Each array leaf is written as one ``.npy`` file *per addressable shard*
    (``jax.Array.addressable_shards``): a ``NamedSharding``-split pod buffer
    or cohort table never materializes host-side as a whole. Replicated and
    host-numpy leaves write a single full shard.
  * ``manifest.json`` carries the JSON tree skeleton (same ``__array__``
    codec as v1), and per leaf the dtype/shape plus every shard's file name,
    index extents, byte length and crc32.
  * ``COMMIT.json`` (save id + manifest sha256) is atomically written
    **last**: a snapshot is either complete or invisible. Readers refuse a
    missing/garbled marker, a manifest that does not hash to the committed
    sha, and any shard whose length or crc mismatches — naming the bad
    artifact (tests/test_checkpoint_crash.py SIGKILLs a writer at random
    offsets to enforce this).

``AsyncCheckpointWriter`` feeds a background thread through a bounded queue:
``submit`` only walks the state tree (host numpy leaves are defensively
copied — the round loop mutates them in place; jax arrays are immutable
references), the device→host shard pulls and disk writes happen off the
round loop, and ``close()`` is the drain barrier the harnesses call at exit
so resume determinism is preserved. ``BlockingCheckpointWriter`` is the
uniform-interface v1 fallback (``checkpoint_async=False``) and the oracle
the async path is benchmarked against (benchmarks/bench_serve.py).

Retention: ``prune_checkpoints(dir, keep_last)`` deletes all but the newest
``keep_last`` *committed* snapshots, but never one named by a live server's
``SERVING-<token>.json`` claim file (``write_claim``) — the prune-vs-reload
race is closed by claim-before-load on the server side
(``launch/serve.py``).
"""
from __future__ import annotations

import hashlib
import io
import json
import re
import shutil
import threading
import queue
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.run_state import (CheckpointError, _decode, _encode,
                                        _npz_path, atomic_write,
                                        check_version, find_sidecar,
                                        save_run_state)

V2_FORMAT = 2
MANIFEST_NAME = "manifest.json"
COMMIT_NAME = "COMMIT.json"
CLAIM_PREFIX = "SERVING-"

# test seam: called after each shard file hits disk (the crash suite widens
# the SIGKILL window with it); never set in production code
_POST_SHARD_HOOK = None


def _stem(path) -> Path:
    """Snapshot paths are given as stems (``.../round_00006``); tolerate the
    v1 ``.npz``-suffixed form so both layouts share call sites."""
    return Path(str(path).removesuffix(".npz"))


# ---------------------------------------------------------------------------
# v2 write
# ---------------------------------------------------------------------------

def _norm_index(index, shape) -> Tuple[Tuple[int, int], ...]:
    """A shard's ``.index`` (tuple of slices) -> concrete (start, stop)
    extents; replicated axes carry ``slice(None)`` which normalizes to the
    full extent, so replicated shards of one array dedupe to one entry."""
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = int(dim) if sl.stop is None else int(sl.stop)
        out.append((start, stop))
    return tuple(out)


def _leaf_shards(ref) -> List[Tuple[Tuple[Tuple[int, int], ...], np.ndarray]]:
    """[(index extents, host shard)] covering ``ref``. Mesh-sharded jax
    arrays are pulled shard-by-shard (no host gather of the full array);
    replicated/single-device/numpy leaves yield one full shard. Falls back
    to the full array when the addressable shards do not cover it (a
    multi-host topology — per-host manifests are the documented follow-up)."""
    import jax

    shape = tuple(int(n) for n in np.shape(ref))
    if isinstance(ref, jax.Array) and shape:
        try:
            addressable = list(ref.addressable_shards)
        except Exception:
            addressable = []
        shards: Dict[Tuple, Any] = {}
        for sh in addressable:
            shards.setdefault(_norm_index(sh.index, shape), sh.data)
        total = sum(int(np.prod([b - a for a, b in idx], initial=1))
                    for idx in shards)
        if shards and total == int(np.prod(shape, initial=1)):
            return [(idx, np.asarray(data))
                    for idx, data in sorted(shards.items())]
    return [(tuple((0, n) for n in shape), np.asarray(ref))]


def _write_v2(path, tree, arrays: Dict[str, Any], metadata: dict) -> None:
    """Write one committed v2 snapshot directory. ``arrays`` holds *array
    references* from ``_encode`` (device arrays still on device). Overwriting
    an existing snapshot unlinks its commit marker first, so a crash mid-
    rewrite can never leave a stale marker next to fresh shard files."""
    d = _stem(path)
    d.mkdir(parents=True, exist_ok=True)
    (d / COMMIT_NAME).unlink(missing_ok=True)
    (d / MANIFEST_NAME).unlink(missing_ok=True)
    for old in d.glob("*.npy"):
        old.unlink()
    save_id = f"{np.random.SeedSequence().entropy:032x}"
    entries = {}
    for i, (key, ref) in enumerate(arrays.items()):
        shards = []
        dtype = None
        for j, (idx, data) in enumerate(_leaf_shards(ref)):
            fname = f"a{i:05d}.s{j:02d}.npy"
            buf = io.BytesIO()
            # NB: np.ascontiguousarray promotes 0-d to 1-d; guard on ndim
            np.save(buf, np.ascontiguousarray(data) if data.ndim else data,
                    allow_pickle=False)
            payload = buf.getvalue()
            (d / fname).write_bytes(payload)
            if _POST_SHARD_HOOK is not None:
                _POST_SHARD_HOOK()
            dtype = str(data.dtype)
            shards.append({"file": fname,
                           "index": [[int(a), int(b)] for a, b in idx],
                           "crc32": zlib.crc32(payload) & 0xFFFFFFFF,
                           "nbytes": len(payload)})
        entries[key] = {"dtype": dtype,
                        "shape": [int(n) for n in np.shape(ref)],
                        "shards": shards}
    manifest = {"format_version": V2_FORMAT, "kind": "run_state",
                "save_id": save_id, "tree": tree, "metadata": metadata,
                "arrays": entries}
    mbytes = json.dumps(manifest).encode()
    atomic_write(d / MANIFEST_NAME, lambda t: t.write_bytes(mbytes))
    atomic_write(d / COMMIT_NAME, lambda t: t.write_text(json.dumps(
        {"format_version": V2_FORMAT, "save_id": save_id,
         "manifest_sha256": hashlib.sha256(mbytes).hexdigest()})))


def save_run_state_v2(path, state, metadata: dict = None) -> None:
    """Synchronous v2 save (the async writer inlined): same tree contract as
    ``save_run_state``, per-shard directory layout on disk."""
    arrays: Dict[str, Any] = {}
    tree = _encode(state, arrays, "s")
    _write_v2(path, tree, arrays, dict(metadata or {}))


# ---------------------------------------------------------------------------
# v2 read
# ---------------------------------------------------------------------------

def read_manifest(path) -> dict:
    """The committed manifest of a v2 snapshot directory: requires the
    commit marker, verifies the manifest hashes to the committed sha and
    that both sides name the same save. Raises ``CheckpointError`` naming
    the bad artifact."""
    d = _stem(path)
    commit_p = d / COMMIT_NAME
    if not commit_p.exists():
        raise CheckpointError(
            f"snapshot {d} has no commit marker {COMMIT_NAME} — the write "
            "never completed (crashed writer?); refusing a partial restore")
    try:
        commit = json.loads(commit_p.read_text())
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointError(
            f"corrupt commit marker {commit_p}: {e}") from e
    man_p = d / MANIFEST_NAME
    if not man_p.exists():
        raise CheckpointError(f"snapshot manifest {man_p} not found")
    mbytes = man_p.read_bytes()
    sha = hashlib.sha256(mbytes).hexdigest()
    if sha != commit.get("manifest_sha256"):
        raise CheckpointError(
            f"snapshot manifest {man_p} does not hash to the committed "
            f"sha256 (torn overwrite or corruption)")
    manifest = json.loads(mbytes)
    check_version(manifest, d, expect_kind="run_state")
    if manifest.get("save_id") != commit.get("save_id"):
        raise CheckpointError(
            f"snapshot {d} is torn: manifest and commit marker come from "
            "different saves")
    return manifest


def _read_leaf(d: Path, key: str, ent: dict) -> np.ndarray:
    dtype = np.dtype(ent["dtype"])
    shape = tuple(int(n) for n in ent["shape"])
    full = np.empty(shape, dtype)
    count = 0
    for shard in ent["shards"]:
        f = d / shard["file"]
        if not f.exists():
            raise CheckpointError(
                f"snapshot {d} array {key!r}: shard file {f.name} is "
                "missing")
        payload = f.read_bytes()
        if len(payload) != int(shard["nbytes"]):
            raise CheckpointError(
                f"snapshot {d} array {key!r}: shard file {f.name} is "
                f"truncated ({len(payload)} of {shard['nbytes']} bytes)")
        if (zlib.crc32(payload) & 0xFFFFFFFF) != int(shard["crc32"]):
            raise CheckpointError(
                f"snapshot {d} array {key!r}: shard file {f.name} fails "
                "its crc32 check (corrupt or from a different save)")
        try:
            arr = np.load(io.BytesIO(payload), allow_pickle=False)
        except Exception as e:
            raise CheckpointError(
                f"snapshot {d} array {key!r}: shard file {f.name} is not "
                f"a readable npy: {e}") from e
        idx = tuple((int(a), int(b)) for a, b in shard["index"])
        want = tuple(b - a for a, b in idx)
        if arr.shape != want or arr.dtype != dtype:
            raise CheckpointError(
                f"snapshot {d} array {key!r}: shard file {f.name} holds "
                f"{arr.dtype}{arr.shape}, manifest says {dtype}{want}")
        full[tuple(slice(a, b) for a, b in idx)] = arr
        count += int(arr.size) if shape else 1
    if count != (int(full.size) if shape else 1):
        raise CheckpointError(
            f"snapshot {d} array {key!r}: shards cover {count} of "
            f"{full.size} elements (incomplete manifest)")
    return full


def load_run_state_v2(path):
    """Reassemble a committed v2 snapshot into nested plain structures.
    Every shard is length- and crc-verified; the reassembled arrays are
    whole host arrays, so a resuming run re-shards them onto *its* mesh
    (``load_state_dict`` does the ``device_put``) — a snapshot written on a
    2x4 mesh restores onto 1x8, 8x1 or a single device unchanged."""
    d = _stem(path)
    manifest = read_manifest(d)
    data = {key: _read_leaf(d, key, ent)
            for key, ent in manifest["arrays"].items()}
    return _decode(manifest["tree"], data)


# ---------------------------------------------------------------------------
# snapshot directory scanning / retention
# ---------------------------------------------------------------------------

_ROUND_RE = re.compile(r"round_(\d+)$")


def snapshot_round(path) -> Optional[int]:
    """Round number encoded in a harness snapshot name, else None."""
    m = _ROUND_RE.search(_stem(path).name)
    return int(m.group(1)) if m else None


def is_committed(path) -> bool:
    """Cheap commit probe: a v2 directory with marker + manifest, or a v1
    npz + sidecar pair. (Deep validation happens at load.)"""
    stem = _stem(path)
    if stem.is_dir():
        return (stem / COMMIT_NAME).exists() and \
            (stem / MANIFEST_NAME).exists()
    return _npz_path(stem).exists() and find_sidecar(stem) is not None


def _snapshot_stems(checkpoint_dir) -> List[Tuple[Path, int]]:
    """All ``round_*`` snapshot stems in a checkpoint dir (committed or
    not), sorted by round."""
    seen: Dict[Path, int] = {}
    for p in Path(checkpoint_dir).glob("round_*"):
        stem = Path(str(p).removesuffix(".meta.json").removesuffix(".npz"))
        r = snapshot_round(stem)
        if r is not None:
            seen[stem] = r
    return sorted(seen.items(), key=lambda kv: (kv[1], kv[0].name))


def committed_snapshots(checkpoint_dir) -> List[Path]:
    """Stems of all committed snapshots in a dir, oldest round first."""
    return [s for s, _ in _snapshot_stems(checkpoint_dir)
            if is_committed(s)]


def latest_checkpoint(checkpoint_dir) -> Optional[Path]:
    """Stem of the newest *committed* snapshot, or None. Uncommitted v2
    directories (in-flight or crashed writes) are invisible here — this is
    what the serving path polls."""
    snaps = committed_snapshots(checkpoint_dir)
    return snaps[-1] if snaps else None


def delete_snapshot(path) -> None:
    """Remove one snapshot. v2: the commit marker goes first (the snapshot
    turns invisible atomically), then the directory; v1: npz before
    sidecar, so a concurrent reader fails loudly instead of decoding a
    half-deleted pair."""
    stem = _stem(path)
    if stem.is_dir():
        (stem / COMMIT_NAME).unlink(missing_ok=True)
        shutil.rmtree(stem, ignore_errors=True)
    else:
        _npz_path(stem).unlink(missing_ok=True)
        mp = find_sidecar(stem)
        if mp is not None:
            mp.unlink(missing_ok=True)


def write_claim(checkpoint_dir, token: str, snapshots) -> Path:
    """Publish a server's claim file naming the snapshots it is using (the
    one currently mapped + the one it is about to load): ``prune_checkpoints``
    never deletes a claimed snapshot. Claim before load, re-verify the
    commit marker after claiming (a prune that raced the claim is detected
    and retried by the server)."""
    d = Path(checkpoint_dir)
    d.mkdir(parents=True, exist_ok=True)
    names = sorted({_stem(s).name for s in snapshots if s is not None})
    p = d / f"{CLAIM_PREFIX}{token}.json"
    atomic_write(p, lambda t: t.write_text(json.dumps(
        {"token": token, "snapshots": names})))
    return p


def clear_claim(checkpoint_dir, token: str) -> None:
    (Path(checkpoint_dir) / f"{CLAIM_PREFIX}{token}.json").unlink(
        missing_ok=True)


def claimed_names(checkpoint_dir) -> set:
    """Snapshot names named by any live claim file (unparsable claim files
    are skipped: a torn claim must not wedge retention forever)."""
    out = set()
    for p in Path(checkpoint_dir).glob(f"{CLAIM_PREFIX}*.json"):
        try:
            doc = json.loads(p.read_text())
        except (json.JSONDecodeError, OSError):
            continue
        out.update(str(n) for n in doc.get("snapshots", []))
    return out


def prune_checkpoints(checkpoint_dir, keep_last: int,
                      protect=()) -> List[Path]:
    """Delete all but the newest ``keep_last`` committed snapshots; returns
    the deleted stems. Never deletes (a) the newest committed snapshot,
    (b) anything named by a ``SERVING-*`` claim file or ``protect``, or
    (c) an uncommitted snapshot at/after the newest committed round (that
    is the writer's in-flight directory). Older uncommitted leftovers
    (crashed writes) are swept."""
    if not isinstance(keep_last, int) or keep_last < 1:
        raise ValueError(f"keep_last must be a positive int, got "
                         f"{keep_last!r}")
    d = Path(checkpoint_dir)
    if not d.is_dir():
        return []
    stems = _snapshot_stems(d)
    committed = [(s, r) for s, r in stems if is_committed(s)]
    if not committed:
        return []
    newest_round = committed[-1][1]
    keep = {s.name for s, _ in committed[-keep_last:]}
    keep |= claimed_names(d)
    keep |= {_stem(p).name for p in protect}
    removed = []
    for s, r in stems:
        if s.name in keep:
            continue
        if not is_committed(s) and r >= newest_round:
            continue
        delete_snapshot(s)
        removed.append(s)
    return removed


# ---------------------------------------------------------------------------
# writers
# ---------------------------------------------------------------------------

class BlockingCheckpointWriter:
    """Uniform writer interface over the synchronous v1 save: the
    ``checkpoint_async=False`` harness path, the perf baseline
    ``bench_serve.py`` measures the async writer against, and the reason
    the v1 *write* path stays exercised end-to-end (v1→v2 read-compat)."""

    def __init__(self, keep_last: int = None):
        self.keep_last = keep_last

    def submit(self, path, state, metadata: dict = None) -> None:
        save_run_state(path, state, metadata=metadata)
        if self.keep_last:
            prune_checkpoints(_stem(path).parent, self.keep_last)

    def drain(self) -> None:
        pass

    def close(self) -> None:
        pass

    def shutdown(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        self.close() if et is None else self.shutdown()
        return False


class AsyncCheckpointWriter:
    """Background v2 snapshot writer fed through a bounded queue.

    ``submit`` runs on the round loop and only walks the state tree:
    device arrays are enqueued as references (immutable), host numpy leaves
    are copied (the harness mutates them in place between rounds). The
    worker thread pulls per-shard device→host transfers, writes the
    snapshot directory, commits, and prunes — the round loop never blocks
    on disk unless the writer falls ``queue_size`` snapshots behind
    (backpressure beats unbounded memory growth).

    A failed write is re-raised on the *next* ``submit``/``drain``/
    ``close`` — ``close()`` is the harness's drain barrier at exit, so an
    experiment cannot return having silently dropped its snapshots.
    ``shutdown()`` is the ``finally``-safe variant (never raises, never
    masks the in-flight exception that got there)."""

    def __init__(self, keep_last: int = None, queue_size: int = 2):
        self.keep_last = keep_last
        self._q: "queue.Queue" = queue.Queue(maxsize=queue_size)
        self._err: Optional[BaseException] = None
        self._closed = False
        self._thread = threading.Thread(
            target=self._worker, name="ckpt-writer", daemon=True)
        self._thread.start()

    # -- round-loop side -----------------------------------------------------
    def submit(self, path, state, metadata: dict = None) -> None:
        self._raise_pending()
        if self._closed:
            raise CheckpointError("submit() on a closed checkpoint writer")
        arrays: Dict[str, Any] = {}
        tree = _encode(state, arrays, "s", copy_host=True)
        self._q.put((_stem(path), tree, arrays, dict(metadata or {})))

    def drain(self) -> None:
        """Block until every submitted snapshot is committed (or failed)."""
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        """Drain barrier: waits for all pending writes, stops the worker,
        re-raises the first write failure."""
        if not self._closed:
            self._closed = True
            self._q.put(None)
            self._thread.join()
        self._raise_pending()

    def shutdown(self) -> None:
        """``finally``-safe close: same drain, swallows write errors so it
        never masks an exception already unwinding the harness."""
        if not self._closed:
            self._closed = True
            self._q.put(None)
            self._thread.join()

    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        self.close() if et is None else self.shutdown()
        return False

    # -- worker side ---------------------------------------------------------
    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                path, tree, arrays, metadata = item
                _write_v2(path, tree, arrays, metadata)
                if self.keep_last:
                    prune_checkpoints(path.parent, self.keep_last)
            except BaseException as e:           # surfaced at the barrier
                if self._err is None:
                    self._err = e
            finally:
                self._q.task_done()

    def _raise_pending(self) -> None:
        if self._err is not None:
            err, self._err = self._err, None
            if isinstance(err, CheckpointError):
                raise err
            raise CheckpointError(
                f"async checkpoint write failed: {err}") from err
