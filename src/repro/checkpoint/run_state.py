"""Versioned on-disk snapshots of arbitrary nested run state.

``repro/checkpoint``'s original npz helper covers a params pytree only; long
online runs also carry FIFO buffer contents, staged arrivals, server
contribution buffers, scores, staleness flags and several NumPy Generator
streams. This module is the serialization layer under the full ``RunState``
snapshot (see DESIGN.md "Checkpoint/restore of online-run state"):

  * ``save_run_state(path, state)`` / ``load_run_state(path)`` round-trip a
    nested tree of dicts / lists / scalars / None / numpy-or-jax arrays.
    Array leaves go into one ``.npz`` archive under their tree path; the
    non-array skeleton (including arbitrary-precision ints such as PCG64
    Generator words) goes into the ``.meta.json`` sidecar with
    ``{"__array__": <npz key>}`` markers where arrays were.
  * Every sidecar carries ``format_version`` + ``kind``; loading a snapshot
    written by a future (or unknown) format fails with ``CheckpointError``
    instead of silently reinterpreting arrays.
  * ``generator_state`` / ``set_generator_state`` snapshot and restore
    ``np.random.Generator`` streams mid-sequence (the bit_generator state
    dict is plain JSON-able ints), so arrivals, channel shadowing and batch
    sampling resume on the exact draw they would have seen uninterrupted.

Two on-disk layouts share this tree codec:

  * v1 (``save_run_state`` here): one host-gathered ``.npz`` + sidecar pair —
    adequate for the CPU engines and kept as the read-compatible oracle
    format.
  * v2 (``checkpoint/streaming.py``): a per-snapshot *directory* of per-shard
    ``.npy`` files + manifest + commit marker, written without host-gathering
    mesh-sharded leaves; ``load_run_state`` dispatches on the path form and
    reads both.
"""
from __future__ import annotations

import copy
import json
import os
import zipfile
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

# newest readable snapshot format; v1 saves stamp V1_FORMAT so snapshots they
# write stay readable by pre-v2 builds
FORMAT_VERSION = 2
V1_FORMAT = 1
_ARRAY_KEY = "__array__"


class CheckpointError(RuntimeError):
    """A checkpoint could not be read/written against the live structures."""


def validate_cohort_shapes(sd: dict, num_users: int, capacity: int) -> None:
    """Validate a slot-pool snapshot against a live run's U and C
    *independently*.

    The dense engines had slot index == user id, so one fused shape check on
    the (U, N) buffer covered both dimensions; the sparse-cohort engine
    (``core/cohort.py``) decouples them — ``user_slot`` is per registered
    user (length U) while ``slot_user``/the slot-resident state are per pool
    slot (length C) — and a snapshot from U'=C'=8 must not slip into a U=8,
    C=4 run (or vice versa) through a single combined check. Each mismatch
    raises ``CheckpointError`` naming the offending dimension."""
    missing = sorted(k for k in ("user_slot", "slot_user") if k not in sd)
    if missing:
        raise CheckpointError(
            "cohort snapshot is missing the slot-map keys: "
            + ", ".join(missing))
    u = int(np.asarray(sd["user_slot"]).shape[0])
    c = int(np.asarray(sd["slot_user"]).shape[0])
    if u != int(num_users):
        raise CheckpointError(
            f"cohort snapshot covers U={u} registered users; the live run "
            f"has U={num_users} (per-user tables cannot be re-indexed)")
    if c != int(capacity):
        raise CheckpointError(
            f"cohort snapshot has slot-pool capacity C={c}; the live run "
            f"has C={capacity} (slot-resident state cannot be re-packed)")


# ---------------------------------------------------------------------------
# np.random.Generator streams
# ---------------------------------------------------------------------------

def generator_state(rng: np.random.Generator) -> dict:
    """JSON-able snapshot of a Generator's exact stream position."""
    return copy.deepcopy(rng.bit_generator.state)


def set_generator_state(rng: np.random.Generator, state: dict) -> None:
    """Restore a stream snapshot taken by ``generator_state``."""
    rng.bit_generator.state = copy.deepcopy(state)


# ---------------------------------------------------------------------------
# nested-tree codec
# ---------------------------------------------------------------------------

def _encode(obj, arrays: Dict[str, Any], path: str,
            copy_host: bool = False):
    """Nested state -> JSON skeleton, array leaves moved into ``arrays``.

    Array leaves are stored as *references* (device arrays stay on device —
    the writer decides whether to gather whole or pull per shard). With
    ``copy_host`` host numpy leaves are defensively copied at encode time:
    the async writer snapshots state the round loop keeps mutating in place
    (``SlotPool`` clocks, the baseline servers' ``sizes``/``kappas`` arrays),
    while jax arrays are immutable and safe to hold by reference."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    if hasattr(obj, "__array__") and hasattr(obj, "dtype"):
        if copy_host and isinstance(obj, np.ndarray):
            obj = obj.copy()
        arrays[path] = obj
        return {_ARRAY_KEY: path}
    if isinstance(obj, dict):
        out = {}
        for k, v in obj.items():
            if not isinstance(k, str) or k == _ARRAY_KEY:
                raise CheckpointError(
                    f"state dict key {k!r} at {path!r} is not serializable "
                    f"(keys must be strings, {_ARRAY_KEY!r} is reserved)")
            out[k] = _encode(v, arrays, f"{path}/{k}", copy_host)
        return out
    if isinstance(obj, (list, tuple)):
        return [_encode(v, arrays, f"{path}/{i}", copy_host)
                for i, v in enumerate(obj)]
    raise CheckpointError(
        f"cannot serialize {type(obj).__name__} at {path!r}")


def _decode(node, data):
    if isinstance(node, dict):
        if set(node) == {_ARRAY_KEY}:
            key = node[_ARRAY_KEY]
            if key not in data:
                raise CheckpointError(
                    f"sidecar references array {key!r} which is missing "
                    "from the npz archive (torn or mismatched save?)")
            return data[key]
        return {k: _decode(v, data) for k, v in node.items()}
    if isinstance(node, list):
        return [_decode(v, data) for v in node]
    return node


# ---------------------------------------------------------------------------
# on-disk format
# ---------------------------------------------------------------------------

def _npz_path(path) -> Path:
    p = str(path)
    return Path(p if p.endswith(".npz") else p + ".npz")


def meta_path(path) -> Path:
    """Canonical sidecar location (written by save/save_run_state); ``ckpt``
    and ``ckpt.npz`` resolve to the same file so the version check cannot be
    dodged by the suffixed path form."""
    p = str(path)
    if p.endswith(".npz"):
        p = p[:-4]
    return Path(p + ".meta.json")


def find_sidecar(path) -> Optional[Path]:
    """The existing sidecar for ``path``, or None. Probes the canonical
    stem-based location first, then the legacy ``<file>.npz.meta.json`` spot
    (pre-RunState checkpoints appended '.meta.json' to the caller's path
    verbatim, so '.npz'-suffixed saves put it after the extension)."""
    legacy = Path(str(_npz_path(path)) + ".meta.json")
    for mp in (meta_path(path), legacy):
        if mp.exists():
            return mp
    return None


def parse_sidecar(mp: Path) -> dict:
    """Parse an already-located sidecar file."""
    try:
        return json.loads(mp.read_text())
    except json.JSONDecodeError as e:
        raise CheckpointError(f"corrupt checkpoint sidecar {mp}: {e}") from e


def read_sidecar(path) -> dict:
    """The ``.meta.json`` sidecar dict, or CheckpointError if absent/corrupt."""
    mp = find_sidecar(path)
    if mp is None:
        raise CheckpointError(
            f"checkpoint sidecar {meta_path(path)} not found — was this "
            "checkpoint written by repro.checkpoint.save/save_run_state?")
    return parse_sidecar(mp)


def atomic_write(target: Path, writer) -> None:
    """Write via a temp file + ``os.replace`` so an interrupted save never
    tears ``target`` (the previous version stays intact until the new one is
    fully on disk). ``writer`` receives the temp path; for npz targets the
    temp name keeps the '.npz' suffix so ``np.savez`` doesn't append one."""
    tmp = target.with_name(".tmp." + target.name)
    try:
        writer(tmp)
        os.replace(tmp, target)
    finally:
        tmp.unlink(missing_ok=True)


def check_version(meta: dict, path, expect_kind: str = None) -> None:
    """Reject future/unknown snapshot formats instead of reinterpreting."""
    ver = meta.get("format_version", 0)
    if not isinstance(ver, int) or ver > FORMAT_VERSION:
        raise CheckpointError(
            f"checkpoint {path} has format_version {ver!r}; this build "
            f"reads versions <= {FORMAT_VERSION} — refusing to reinterpret "
            "a future snapshot format")
    kind = meta.get("kind", "params")
    if expect_kind is not None and kind != expect_kind:
        raise CheckpointError(
            f"checkpoint {path} holds a {kind!r} snapshot, expected "
            f"{expect_kind!r}")
    if expect_kind == "run_state" and ver < 1:
        raise CheckpointError(
            f"checkpoint {path} predates the run_state format "
            f"(format_version {ver!r})")


_SAVE_ID_KEY = "__save_id__"


def save_run_state(path, state, metadata: dict = None) -> None:
    """Write a nested run-state tree as ``path[.npz]`` + ``.meta.json``.

    Each file is written atomically, and the *pair* carries a shared random
    save id (an npz entry + a sidecar field): overwriting an existing
    snapshot cannot silently publish a new array file next to a stale
    sidecar (or vice versa) if the process dies between the two replaces —
    consecutive snapshots of one run share identical tree paths, so without
    the id such a torn pair would decode without error."""
    arrays: Dict[str, Any] = {}
    tree = _encode(state, arrays, "s")
    save_id = f"{np.random.SeedSequence().entropy:032x}"
    arrays[_SAVE_ID_KEY] = save_id
    npz = _npz_path(path)
    npz.parent.mkdir(parents=True, exist_ok=True)
    atomic_write(npz, lambda tmp: np.savez(
        tmp, **{k: np.asarray(v) for k, v in arrays.items()}))
    atomic_write(meta_path(path), lambda tmp: tmp.write_text(json.dumps(
        {"format_version": V1_FORMAT, "kind": "run_state",
         "save_id": save_id, "tree": tree, "metadata": metadata or {}})))


def load_run_state(path):
    """Read a run-state snapshot back into nested plain structures (dicts /
    lists / scalars / np arrays). Dispatches on the path form: a snapshot
    *directory* is the v2 per-shard layout (``checkpoint/streaming.py``), a
    ``.npz`` + sidecar pair is v1. Version-checked; a mismatched pair
    (interrupted overwrite), a truncated archive or a corrupt shard raises
    ``CheckpointError`` naming the bad artifact — never a silent partial
    restore."""
    if Path(str(path).removesuffix(".npz")).is_dir():
        from repro.checkpoint import streaming
        return streaming.load_run_state_v2(Path(str(path)
                                                .removesuffix(".npz")))
    meta = read_sidecar(path)
    check_version(meta, path, expect_kind="run_state")
    npz = _npz_path(path)
    if not npz.exists():
        raise CheckpointError(f"checkpoint array file {npz} not found")
    try:
        with np.load(npz) as data:
            data = dict(data.items())
    except (zipfile.BadZipFile, ValueError, OSError, EOFError) as e:
        raise CheckpointError(
            f"checkpoint array file {npz} is corrupt or truncated: "
            f"{e}") from e
    sid = meta.get("save_id")
    got = data.pop(_SAVE_ID_KEY, None)
    # a pre-save_id snapshot has the id on neither side; any single-sided or
    # mismatched id means the pair mixes two saves
    if (sid is None) != (got is None) or (sid is not None
                                          and str(got) != sid):
        raise CheckpointError(
            f"checkpoint {path} is torn: the array file and the sidecar "
            "come from different saves (interrupted overwrite?)")
    return _decode(meta["tree"], data)


def diff_snapshots(a, b, path: str = "s",
                   skip: Tuple[str, ...] = ("round_s", "request_gen_s"),
                   ) -> List[str]:
    """Bit-exact recursive comparison of two loaded snapshot trees; returns
    difference descriptions (empty list == identical). ``skip`` names dict
    keys excluded everywhere — by default the wall-clock timings (whole-round
    and request-generation), the only legitimately divergent leaves between
    an uninterrupted run and a save/resume run. Shared by
    tests/test_checkpoint_resume.py and the CI smoke tools/resume_smoke.py."""
    out: List[str] = []
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b)):
            if k in skip:
                continue
            if k not in a or k not in b:
                out.append(f"{path}/{k}: present on one side only")
            else:
                out += diff_snapshots(a[k], b[k], f"{path}/{k}", skip)
    elif isinstance(a, list) and isinstance(b, list):
        if len(a) != len(b):
            out.append(f"{path}: length {len(a)} != {len(b)}")
        for i, (x, y) in enumerate(zip(a, b)):
            out += diff_snapshots(x, y, f"{path}/{i}", skip)
    elif hasattr(a, "dtype") or hasattr(b, "dtype"):
        if not (hasattr(a, "dtype") and hasattr(b, "dtype")):
            out.append(f"{path}: type {type(a).__name__} != "
                       f"{type(b).__name__}")
        else:
            aa, bb = np.asarray(a), np.asarray(b)
            if aa.dtype != bb.dtype:
                out.append(f"{path}: dtype {aa.dtype} != {bb.dtype}")
            elif aa.shape != bb.shape:
                out.append(f"{path}: shape {aa.shape} != {bb.shape}")
            elif not np.array_equal(aa, bb, equal_nan=True):
                out.append(f"{path}: array values differ")
    elif type(a) is not type(b):
        out.append(f"{path}: type {type(a).__name__} != {type(b).__name__}")
    elif a != b:
        out.append(f"{path}: {a!r} != {b!r}")
    return out
