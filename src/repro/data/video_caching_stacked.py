"""Batched Gumbel-trick request model: all U users advance per slot as one
jitted JAX program (Algorithm 5 at cohort scale).

``data/video_caching.py`` is the per-user oracle: every decision in
Algorithm 5 is an ``rng.choice(p=pmf)`` over a small categorical — genre by
Dirichlet preference, Zipf-Mandelbrot rank, top-K exploit softmax, explore
re-normalization. Each of those is replaced here by the Gumbel-max trick:
``argmax(log p_i + G_i)`` with ``G_i`` iid Gumbel(0,1) is exactly
``Cat(p)``, and masking an entry's logit to -inf is exactly dropping it and
re-normalizing the rest. That turns the whole per-request branch structure
into a handful of masked ``(U, .)`` argmaxes with no host synchronization:

  * **first request** (``genre < 0``): genre = argmax over ``log pref_u``;
  * **exploit** (``u <= eps_u``): candidate logits are the raw within-genre
    cosine sims with the current file masked out (softmax is a monotone
    reparametrization — ``argmax(sims + G)`` already samples the softmax),
    restricted to the top-K sims via ``lax.top_k``;
  * **explore**: genre = argmax over ``log pref_u`` with the current genre
    masked to -inf (the oracle's re-normalization over the other genres);
  * **Zipf rank** (first/explore): argmax over the cached
    ``log zipf_mandelbrot_pmf`` mapped through the genre's popularity order.

One ``StackedRequestStream.draw_dataset{1,2}(counts, width)`` call runs a
fixed-length ``lax.scan`` of ``width + warmup`` such steps — warmup is the
cohort's worst-case unfilled-window deficit read off the current state (up
to 1 slot for the Dataset-1 sliding window, SEQ_LEN for the Dataset-2
history ring, the same extra requests the oracle's while-loop consumes; 0
once the cohort is warm) — with a per-user ``emitted < counts`` mask so
users that reached their arrival count stop consuming requests, exactly
like the oracle. All randomness is drawn in
four bulk threefry calls before the scan, and the scan itself carries only
the O(U) Markov state: it emits (slot, request-id) pairs, from which the
padded ``(U, width, 3168)`` / ``(U, width, SEQ_LEN)`` blocks are assembled
in one vectorized pass afterwards (the Dataset-1 feature is a deterministic
function of the *previous* request id, so features never enter the scan).
The result is exactly the layout ``data/online.py::pad_arrival_batch``
produces, so it feeds ``core/buffer_stacked.py::StackedOnlineBuffer.stage``
directly.

The streams are **distribution-equivalent**, not bit-equivalent, to the
oracle (the RNG is a JAX counter-based PRNG, not NumPy PCG64):
per-decision-branch pmf parity is enforced by chi-squared tests in
``tests/test_request_stacked.py``. Checkpointing is ``state_dict`` /
``load_state_dict`` over the device-array state (PRNG key, Markov state,
sliding-window carries), round-tripped through the RunState codec.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.video_caching import (Catalog, D1_DIM, F_FILES,
                                      FILES_PER_GENRE, G_GENRES,
                                      GENRE_FEAT_DIM, RequestStream, SEQ_LEN,
                                      zipf_mandelbrot_pmf)


class StreamConsts(NamedTuple):
    """Immutable per-population device arrays (catalog + user parameters)."""
    feat50: jnp.ndarray     # (F, 3072) catalog features / 50 (sample layout)
    own_sims: jnp.ndarray   # (F, 20) cosine sims of each file vs its genre
    popularity: jnp.ndarray  # (G, 20) int32 Zipf rank -> in-genre file index
    pref: jnp.ndarray       # (U, G) Dirichlet genre preferences
    log_pref: jnp.ndarray   # (U, G) cached log preferences (genre logits)
    eps: jnp.ndarray        # (U,) exploitation probabilities
    log_zipf: jnp.ndarray   # (20,) cached log Zipf-Mandelbrot pmf


class StreamState(NamedTuple):
    """Mutable cohort state — everything a draw advances (a pytree).

    There is no stored Dataset-1 feature carry: the oracle invariant
    ``_last_feat == dataset1_sample(cat, user, _file)`` (re-established on
    every Dataset-1 request) means the carried feature is always
    reconstructible from ``file``, so only the flag survives here."""
    key: jnp.ndarray        # JAX PRNG key (the whole cohort's stream)
    genre: jnp.ndarray      # (U,) int32 Markov genre, -1 before first request
    file: jnp.ndarray       # (U,) int32 Markov global file id, -1 initially
    has_last: jnp.ndarray   # (U,) bool — a Dataset-1 window carry exists
    hist: jnp.ndarray       # (U, SEQ_LEN) int32 Dataset-2 ring (newest last)
    hist_len: jnp.ndarray   # (U,) int32 valid suffix of hist


def _features_for(consts: StreamConsts, fids: jnp.ndarray) -> jnp.ndarray:
    """Vectorized ``dataset1_sample``: (U, W) request ids -> (U, W, 3168)
    feature rows (content feature/50, genre prefs, within-genre sims, genre
    feature/G, eps)."""
    U, W = fids.shape
    g = (fids // FILES_PER_GENRE).astype(jnp.float32)
    return jnp.concatenate([
        consts.feat50[fids],
        jnp.broadcast_to(consts.pref[:, None, :], (U, W, G_GENRES)),
        consts.own_sims[fids],
        jnp.broadcast_to(g[..., None] / G_GENRES, (U, W, GENRE_FEAT_DIM)),
        jnp.broadcast_to(consts.eps[:, None, None], (U, W, 1)),
    ], axis=-1)


@partial(jax.jit, static_argnames=("width", "warmup", "dataset", "topk"))
def _draw_block(consts: StreamConsts, state: StreamState, counts,
                width: int, warmup: int, dataset: int, topk: int):
    """Advance the cohort until every user u has emitted counts[u] samples
    (counts[u] <= width), returning padded (U, width, ...) blocks."""
    U = counts.shape[0]
    G, P = G_GENRES, FILES_PER_GENRE
    uu = jnp.arange(U, dtype=jnp.int32)
    g_ids = jnp.arange(G, dtype=jnp.int32)[None, :]
    p_ids = jnp.arange(P, dtype=jnp.int32)[None, :]

    # fixed scan length: width emissions + the warmup requests the oracle's
    # while-loop would consume to fill cold windows (0 once the cohort is
    # warm — the caller reads the deficit off the current state)
    L = width + warmup
    # all randomness for the block in 4 bulk draws (per-step threefry calls
    # dominate CPU wall-clock); the cohort key advances once per block
    key, k_br, k_genre, k_rank, k_top = jax.random.split(state.key, 5)
    # dtype pinned: under a scoped-x64 trace (the fused round's "x64"
    # resource backend) the defaults would switch to f64 and draw different
    # random bits than the f32 program
    rnd = (jax.random.uniform(k_br, (L, U), jnp.float32),
           jax.random.gumbel(k_genre, (L, U, G), jnp.float32),
           jax.random.gumbel(k_rank, (L, U, P), jnp.float32),
           jax.random.gumbel(k_top, (L, U, topk), jnp.float32))
    state = state._replace(key=key)

    def step(carry, rnd):
        st, emitted = carry
        u_br, gum_genre, gum_rank, gum_top = rnd
        active = emitted < counts                 # still owes samples
        first = st.genre < 0
        exploit = (~first) & (u_br <= consts.eps)
        explore = (~first) & ~exploit

        # genre: Cat(pref) for first requests; explore masks the current
        # genre (the oracle's re-normalization over the other G-1 genres)
        glog = jnp.where(explore[:, None] & (g_ids == st.genre[:, None]),
                         -jnp.inf, consts.log_pref)
        g_draw = jnp.argmax(glog + gum_genre, axis=1).astype(jnp.int32)

        # Zipf-Mandelbrot rank through the genre's popularity order
        rank = jnp.argmax(consts.log_zipf[None, :] + gum_rank, axis=1)
        f_zipf = g_draw * P + consts.popularity[g_draw, rank]

        # exploit: top-K of the within-genre sims with the current file
        # masked out; argmax(sims + gumbel) over that set IS the oracle's
        # re-normalized top-K softmax draw
        f_safe = jnp.maximum(st.file, 0)
        sims = consts.own_sims[f_safe]            # (U, P)
        sims = jnp.where(p_ids == (f_safe % P)[:, None], -jnp.inf, sims)
        top_v, top_i = jax.lax.top_k(sims, topk)
        kwin = jnp.argmax(top_v + gum_top, axis=1)
        f_exploit = jnp.maximum(st.genre, 0) * P + jnp.take_along_axis(
            top_i, kwin[:, None], axis=1)[:, 0]

        f_new = jnp.where(exploit, f_exploit, f_zipf).astype(jnp.int32)
        genre = jnp.where(active, f_new // P, st.genre)
        file_ = jnp.where(active, f_new, st.file)

        if dataset == 1:
            # sliding window: previous request's feature predicts f_new;
            # emit (slot, label, previous id) — features are built after
            # the scan from the previous ids
            emit = active & st.has_last
            slot = jnp.where(emit, emitted, width)
            out = (slot, f_new, st.file)
            has_last = st.has_last | active
            hist, hist_len = st.hist, st.hist_len
        else:
            # history ring: the SEQ_LEN requests before f_new predict f_new
            emit = active & (st.hist_len >= SEQ_LEN)
            slot = jnp.where(emit, emitted, width)
            out = (slot, f_new, st.hist)
            pushed = jnp.concatenate(
                [st.hist[:, 1:], f_new[:, None].astype(st.hist.dtype)], 1)
            hist = jnp.where(active[:, None], pushed, st.hist)
            hist_len = jnp.where(active,
                                 jnp.minimum(st.hist_len + 1, SEQ_LEN),
                                 st.hist_len)
            has_last = st.has_last
        new_st = StreamState(st.key, genre, file_, has_last, hist, hist_len)
        return (new_st, emitted + emit), out

    init = (state, jnp.zeros(U, jnp.int32))
    (st, emitted), (slots, fids, payload) = jax.lax.scan(step, init, rnd)

    # assemble the padded blocks in one pass: each (u, slot < width) pair is
    # written by exactly one step and only slots < counts[u] are ever
    # emitted, so the zero-initialized padding needs no re-masking
    out_y = jnp.zeros((U, width), jnp.int32
                      ).at[uu[None, :], slots].set(fids, mode="drop")
    if dataset == 1:
        prev = jnp.zeros((U, width), jnp.int32
                         ).at[uu[None, :], slots].set(payload, mode="drop")
        # _features_for builds garbage rows from the prev=0 padding slots —
        # this mask (alone) is load-bearing
        valid = jnp.arange(width, dtype=jnp.int32)[None, :] < counts[:, None]
        out_x = jnp.where(valid[..., None], _features_for(consts, prev), 0.0)
    else:
        out_x = jnp.zeros((U, width, SEQ_LEN), state.hist.dtype
                          ).at[uu[None, :], slots].set(payload, mode="drop")
    return st, out_x, out_y


def warmup_deficit(state: StreamState, dataset: int) -> int:
    """Worst-case warmup requests any user still owes before it can emit a
    sample (0 once the cohort is warm). Host read of the device state; the
    fused round (``core/round_fused.py``) requires this to be 0 at segment
    entry since its in-scan draws run at static warmup=0."""
    if dataset == 1:
        return 0 if bool(np.asarray(state.has_last).all()) else 1
    return max(0, SEQ_LEN - int(np.asarray(state.hist_len).min()))


@dataclass
class StackedRequestStream:
    """Whole-cohort request stream: the vectorized twin of U
    ``RequestStream``s, drawing every user's next slot in one device call."""
    consts: StreamConsts
    state: StreamState
    topk: int
    # per-dataset host cache of "warmup deficit reached 0": the deficit is
    # monotone non-increasing, so once warm the per-draw device read (a
    # blocking transfer) is skipped; reset whenever state is replaced
    _warm: dict = None

    @classmethod
    def from_streams(cls, cat: Catalog, streams: List[RequestStream],
                     seed: int = 0) -> "StackedRequestStream":
        """Import a scalar population mid-stream: user parameters become
        ``(U, ...)`` constants, and each user's Markov state + sliding-window
        carries seed the device state. Only the RNG lineage differs (JAX
        counter PRNG from ``seed`` instead of U PCG64 streams)."""
        users = [s.user for s in streams]
        U = len(users)
        if U == 0:
            raise ValueError("empty population")
        topk = min(int(users[0].topk), FILES_PER_GENRE - 1)
        gamma, q = users[0].gamma, users[0].q
        for u in users:
            if (u.topk, u.gamma, u.q) != (users[0].topk, gamma, q):
                raise ValueError("stacked stream needs homogeneous "
                                 "topk/gamma/q across the cohort")
        own = cat.cos_sim.reshape(F_FILES, G_GENRES, FILES_PER_GENRE)[
            np.arange(F_FILES), np.arange(F_FILES) // FILES_PER_GENRE]
        pref = np.stack([u.genre_pref for u in users]).astype(np.float32)
        consts = StreamConsts(
            feat50=jnp.asarray(cat.features / np.float32(50.0)),
            own_sims=jnp.asarray(own.astype(np.float32)),
            popularity=jnp.asarray(cat.popularity, jnp.int32),
            pref=jnp.asarray(pref),
            log_pref=jnp.log(jnp.asarray(pref)),
            eps=jnp.asarray([u.eps for u in users], jnp.float32),
            log_zipf=jnp.log(jnp.asarray(
                zipf_mandelbrot_pmf(FILES_PER_GENRE, gamma, q),
                jnp.float32)))
        hist = np.zeros((U, SEQ_LEN), np.int32)
        hist_len = np.zeros(U, np.int32)
        for i, s in enumerate(streams):
            h = s._history[-SEQ_LEN:]
            if h:
                hist[i, SEQ_LEN - len(h):] = h
                hist_len[i] = len(h)
        state = StreamState(
            # fold in a tag so the stream's threefry lineage is decorrelated
            # from other PRNGKey(seed) consumers (e.g. model init splits the
            # bare key the same way the first draw block would)
            key=jax.random.fold_in(jax.random.PRNGKey(seed), 0x726571),
            genre=jnp.asarray([u._genre for u in users], jnp.int32),
            file=jnp.asarray([u._file for u in users], jnp.int32),
            has_last=jnp.asarray(
                [s._last_feat is not None for s in streams]),
            hist=jnp.asarray(hist), hist_len=jnp.asarray(hist_len))
        return cls(consts=consts, state=state, topk=topk)

    @property
    def num_users(self) -> int:
        return int(self.state.genre.shape[0])

    # -- drawing -------------------------------------------------------------
    def _draw(self, counts, width: int, dataset: int
              ) -> Tuple[jnp.ndarray, jnp.ndarray, np.ndarray]:
        counts = np.asarray(counts)
        width = int(width)
        if width < 1:
            raise ValueError(f"width must be >= 1, got {width}")
        if counts.shape != (self.num_users,):
            raise ValueError(f"counts shape {counts.shape} != "
                             f"({self.num_users},)")
        if counts.max(initial=0) > width:
            raise ValueError(f"max arrivals {int(counts.max())} > pad "
                             f"width {width}")
        # worst-case warmup requests still owed by any user (0 in steady
        # state, so post-fill rounds scan exactly `width` steps); reading it
        # costs a (U,)-int transfer and at most SEQ_LEN+1 extra traces, and
        # is skipped entirely once the cohort has been seen warm
        if self._warm is None:
            self._warm = {}
        if self._warm.get(dataset):
            warmup = 0
        else:
            warmup = warmup_deficit(self.state, dataset)
        self._warm[dataset] = warmup == 0
        self.state, xs, ys = _draw_block(
            self.consts, self.state, jnp.asarray(counts, jnp.int32),
            width, warmup, dataset, self.topk)
        return xs, ys, counts.astype(np.int32)

    def draw_dataset1(self, counts, width: int):
        """counts[u] fresh Dataset-1 samples per user, padded to
        ``(U, width, 3168)`` / ``(U, width)`` + the (U,) valid counts —
        exactly the ``StackedOnlineBuffer.stage`` argument layout."""
        return self._draw(counts, width, 1)

    def draw_dataset2(self, counts, width: int):
        """Dataset-2 twin: ``(U, width, SEQ_LEN)`` histories -> next ids."""
        return self._draw(counts, width, 2)

    def draw(self, counts, dataset: int, width: int):
        """Dispatch on the dataset id the harness configs carry."""
        return self._draw(counts, width, 1 if dataset == 1 else 2)

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Everything a draw mutates: the cohort PRNG key, per-user Markov
        state and both sliding-window carries. The catalog/user constants are
        rebuilt deterministically from the population seed."""
        st = self.state
        return {"key": st.key, "genre": st.genre, "file": st.file,
                "has_last": st.has_last,
                "hist": st.hist, "hist_len": st.hist_len}

    def load_state_dict(self, sd: dict) -> None:
        self._warm = {}                 # restored state may be colder
        self.state = StreamState(
            key=jnp.asarray(sd["key"]),
            genre=jnp.asarray(sd["genre"], jnp.int32),
            file=jnp.asarray(sd["file"], jnp.int32),
            has_last=jnp.asarray(sd["has_last"], bool),
            hist=jnp.asarray(sd["hist"], jnp.int32),
            hist_len=jnp.asarray(sd["hist_len"], jnp.int32))
