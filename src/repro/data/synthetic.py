"""Synthetic token/feature batches for the big-architecture paths.

Real batches (smoke tests, examples) and ShapeDtypeStruct specs (dry-run) for
every (architecture x input shape). Modality frontends are stubbed per the
assignment: whisper gets frame embeddings, the VLM gets patch embeddings.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig


def train_batch_shapes(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    """ShapeDtypeStructs for one training batch."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.encoder is not None:
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
    if cfg.vision is not None:
        specs["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision.n_patches, cfg.vision.d_vision), jnp.bfloat16)
    return specs


def make_train_batch(key, cfg: ModelConfig, batch: int, seq: int) -> Dict:
    """Concrete random batch with next-token labels."""
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = jax.random.randint(k1, (batch, seq + 1), 0, cfg.vocab_size)
    out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.encoder is not None:
        out["frames"] = 0.02 * jax.random.normal(
            k2, (batch, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
    if cfg.vision is not None:
        out["patches"] = 0.02 * jax.random.normal(
            k3, (batch, cfg.vision.n_patches, cfg.vision.d_vision),
            jnp.float32)
    return out


def learnable_sequence_batch(key, cfg: ModelConfig, batch: int, seq: int
                             ) -> Dict:
    """A *learnable* synthetic task (periodic token sequences) so smoke
    training can assert that loss decreases."""
    period = min(8, cfg.vocab_size - 1)
    phase = jax.random.randint(key, (batch, 1), 0, period)
    pos = jnp.arange(seq + 1)[None, :]
    tokens = (phase + pos) % period
    out = {"tokens": tokens[:, :-1].astype(jnp.int32),
           "labels": tokens[:, 1:].astype(jnp.int32)}
    if cfg.encoder is not None:
        out["frames"] = jnp.zeros((batch, cfg.encoder.n_frames, cfg.d_model),
                                  jnp.float32)
    if cfg.vision is not None:
        out["patches"] = jnp.zeros(
            (batch, cfg.vision.n_patches, cfg.vision.d_vision), jnp.float32)
    return out
