from repro.data.video_caching import (Catalog, RequestStream, UserModel,
                                      make_population, D1_DIM)
from repro.data.synthetic import (make_train_batch, train_batch_shapes,
                                  learnable_sequence_batch)
from repro.data.online import (binomial_arrivals_batched, dataset_layout,
                               draw_arrival_batch, pad_arrival_batch)

__all__ = ["Catalog", "RequestStream", "UserModel", "make_population",
           "D1_DIM", "make_train_batch", "train_batch_shapes",
           "learnable_sequence_batch", "binomial_arrivals_batched",
           "dataset_layout", "draw_arrival_batch", "pad_arrival_batch"]
