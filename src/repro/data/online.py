"""Host-side bridge from the per-user request streams to the stacked online
pipeline (paper Section II-A at cohort scale).

With ``request_backend="python"`` the arrival *samples* are drawn per user
from the stateful oracle streams (``video_caching.RequestStream``) and these
helpers pack them into the ``(U, A, ...)`` rectangular layout the jitted
staging/commit/gather ops consume. With ``request_backend="stacked"`` the
samples themselves are produced on device in that exact layout by the
batched Gumbel-trick sampler
(``data/video_caching_stacked.py::StackedRequestStream``) and this bridge is
bypassed. Arrival *counts* are the paper's Binomial(E_u, p_ac) either way
(``binomial_arrivals_batched``, the whole-cohort twin of
``core/buffer.py::binomial_arrivals``).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.data.video_caching import D1_DIM, RequestStream, SEQ_LEN


def dataset_layout(dataset: int) -> Tuple[tuple, type]:
    """(feature_shape, feature_dtype) of the two paper datasets."""
    if dataset == 1:
        return (D1_DIM,), np.float32
    return (SEQ_LEN,), np.int64


def binomial_arrivals_batched(rng: np.random.Generator, e_u: int,
                              p_ac: np.ndarray) -> np.ndarray:
    """(U,) new-sample counts between two rounds: Binomial(E_u, p_ac_u)."""
    return rng.binomial(e_u, np.asarray(p_ac))


def pad_arrival_batch(samples: Sequence[Optional[Tuple[np.ndarray,
                                                       np.ndarray]]],
                      width: int, dataset: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack per-client (x_u, y_u) pairs (or None) into padded (U, width, ...)
    feature/label arrays plus the (U,) valid-prefix counts that
    ``StackedOnlineBuffer.stage`` consumes."""
    feat, dtype = dataset_layout(dataset)
    U = len(samples)
    xs = np.zeros((U, width) + feat, dtype)
    ys = np.zeros((U, width), np.int64)
    counts = np.zeros(U, np.int32)
    for u, sample in enumerate(samples):
        if sample is None:
            continue
        x, y = sample
        n = len(y)
        if n > width:
            raise ValueError(f"client {u}: {n} arrivals > pad width {width}")
        xs[u, :n], ys[u, :n], counts[u] = x, y, n
    return xs, ys, counts


def draw_arrival_batch(streams: List[RequestStream], counts: np.ndarray,
                       dataset: int, width: Optional[int] = None
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Draw ``counts[u]`` fresh requests from every client's stream and pad.
    Pass a fixed ``width`` (e.g. E_u) so the jitted stage op never retraces."""
    counts = np.asarray(counts)
    samples = [
        (s.draw_dataset1(int(n)) if dataset == 1 else s.draw_dataset2(int(n)))
        if n else None
        for s, n in zip(streams, counts)]
    return pad_arrival_batch(samples, int(width or max(counts.max(), 1)),
                             dataset)


def streams_state_dict(streams: List[RequestStream]) -> list:
    """Cohort snapshot of every per-user request stream (Generator positions
    + sliding-window carries), for the RunState checkpoint."""
    return [s.state_dict() for s in streams]


def load_streams_state(streams: List[RequestStream], states: list) -> None:
    """Restore a ``streams_state_dict`` snapshot onto a freshly built
    population (same seed/topology; only the mutable state is overwritten)."""
    from repro.checkpoint.run_state import CheckpointError
    if len(states) != len(streams):
        raise CheckpointError(
            f"snapshot holds {len(states)} request streams, the live cohort "
            f"has {len(streams)}")
    for s, sd in zip(streams, states):
        s.load_state_dict(sd)
