"""Synthetic video-caching datasets (paper Section V-A1, Appendix D).

Content request model (Algorithm 5): F=100 files in G=5 genres (20 each).
A user picks a genre by its Dirichlet(0.3) genre preference, then a file by
the Zipf-Mandelbrot pmf over the genre's random popularity order. Subsequent
requests exploit (probability eps_u in [0.4, 0.9]): re-normalized softmax over
cosine similarities of the top-K most-similar files; or explore: new genre +
Zipf-Mandelbrot.

Dataset-1 sample (3168 features): [flattened 3x32x32 content feature (3072),
genre preferences (5), cosine sims to the 20 genre files (20), genre feature
(70), exploitation prob (1)]; label = g*20 + f. Sliding window: feature of
request i-1 predicts label of request i.

Dataset-2 sample: last L=10 content IDs -> next content ID.

The paper uses CIFAR-100 class features for x_ft; offline we substitute fixed
random per-file features (same shapes) — recorded in EXPERIMENTS.md.

This module is the **per-user oracle** of the request model: the loop harness
(`repro.harness.run` with `engine="loop"`) consumes it directly, and
`data/online.py` bridges it into the stacked online pipeline when
`request_backend="python"`. Its cohort-scale twin — all U users advanced per
slot by one jitted Gumbel-trick program — is
`data/video_caching_stacked.py::StackedRequestStream`
(`request_backend="stacked"`), which is distribution-parity-tested against
the classes here in `tests/test_request_stacked.py` (see DESIGN.md "Request
model").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import List, Optional, Tuple

import numpy as np

F_FILES = 100
G_GENRES = 5
FILES_PER_GENRE = F_FILES // G_GENRES
FEAT_DIM = 3 * 32 * 32
GENRE_FEAT_DIM = 70
SEQ_LEN = 10


@dataclass
class Catalog:
    """Global content catalog: per-file features, per-genre popularity order."""
    features: np.ndarray           # (F, 3072)
    popularity: np.ndarray         # (G, files_per_genre) rank -> file index
    cos_sim: np.ndarray            # (F, F) within-genre cosine similarities

    @classmethod
    def create(cls, rng: np.random.Generator) -> "Catalog":
        feats = rng.normal(size=(F_FILES, FEAT_DIM)).astype(np.float32)
        pop = np.stack([rng.permutation(FILES_PER_GENRE)
                        for _ in range(G_GENRES)])
        norm = feats / np.linalg.norm(feats, axis=1, keepdims=True)
        cos = norm @ norm.T
        return cls(feats, pop, cos)


@lru_cache(maxsize=None)
def _zipf_mandelbrot_cached(n: int, gamma: float, q: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = 1.0 / (ranks + q) ** gamma
    pmf = w / w.sum()
    pmf.setflags(write=False)       # shared across all users — keep immutable
    return pmf


def zipf_mandelbrot_pmf(n: int, gamma: float = 1.2, q: float = 2.0
                        ) -> np.ndarray:
    """Zipf-Mandelbrot popularity pmf over ranks 1..n. The pmf only depends
    on (n, gamma, q), which are population-wide constants, so it is computed
    once and shared (read-only) — every first/explore draw used to rebuild
    it. The stacked sampler caches the log-pmf the same way at build time."""
    return _zipf_mandelbrot_cached(int(n), float(gamma), float(q))


@dataclass
class UserModel:
    """One user's request process (Algorithm 5)."""
    genre_pref: np.ndarray         # (G,)
    eps: float                     # exploitation probability
    p_ac: float                    # arrival probability per slot
    topk: int
    gamma: float = 1.2
    q: float = 2.0
    _genre: int = -1
    _file: int = -1                # global file id

    @classmethod
    def create(cls, rng: np.random.Generator, topk: int) -> "UserModel":
        return cls(genre_pref=rng.dirichlet(0.3 * np.ones(G_GENRES)),
                   eps=rng.uniform(0.4, 0.9),
                   p_ac=rng.uniform(0.3, 0.8),
                   topk=topk)

    def _zipf_request(self, rng, cat: Catalog, genre: int) -> int:
        pmf = zipf_mandelbrot_pmf(FILES_PER_GENRE, self.gamma, self.q)
        rank = rng.choice(FILES_PER_GENRE, p=pmf)
        return genre * FILES_PER_GENRE + cat.popularity[genre][rank]

    def next_request(self, rng: np.random.Generator, cat: Catalog) -> int:
        if self._genre < 0:                       # first request
            g = rng.choice(G_GENRES, p=self.genre_pref)
            f = self._zipf_request(rng, cat, g)
        elif rng.uniform() <= self.eps:           # exploit: similar content
            g = self._genre
            lo = g * FILES_PER_GENRE
            members = np.arange(lo, lo + FILES_PER_GENRE)
            members = members[members != self._file]
            sims = cat.cos_sim[self._file, members]
            probs = np.exp(sims - sims.max())
            probs /= probs.sum()
            order = np.argsort(-probs)[:self.topk]
            p_top = probs[order] / probs[order].sum()
            f = int(members[order[rng.choice(len(order), p=p_top)]])
        else:                                     # explore: new genre
            others = [gg for gg in range(G_GENRES) if gg != self._genre]
            pref = self.genre_pref[others]
            pref = pref / pref.sum()
            g = int(others[rng.choice(len(others), p=pref)])
            f = self._zipf_request(rng, cat, g)
        self._genre, self._file = f // FILES_PER_GENRE, f
        return f


def genre_feature(genre: int) -> np.ndarray:
    return np.full((GENRE_FEAT_DIM,), float(genre), np.float32)


def dataset1_sample(cat: Catalog, user: UserModel, fid: int) -> np.ndarray:
    """3168-dim Dataset-1 feature vector for one request."""
    g = fid // FILES_PER_GENRE
    lo = g * FILES_PER_GENRE
    sims = cat.cos_sim[fid, lo:lo + FILES_PER_GENRE].astype(np.float32)
    return np.concatenate([
        cat.features[fid] / 50.0,                # scale down raw features
        user.genre_pref.astype(np.float32),
        sims,
        genre_feature(g) / G_GENRES,
        np.array([user.eps], np.float32),
    ])


D1_DIM = FEAT_DIM + G_GENRES + FILES_PER_GENRE + GENRE_FEAT_DIM + 1  # 3168


@dataclass
class RequestStream:
    """Stateful per-user request stream producing (feature, label) pairs with
    the paper's sliding-window construction: sample i = (x_{i-1}, y_i)."""
    cat: Catalog
    user: UserModel
    rng: np.random.Generator
    _last_feat: Optional[np.ndarray] = None
    _history: List[int] = field(default_factory=list)

    def draw_dataset1(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        xs, ys = [], []
        while len(xs) < n:
            fid = self.user.next_request(self.rng, self.cat)
            feat = dataset1_sample(self.cat, self.user, fid)
            if self._last_feat is not None:
                xs.append(self._last_feat)
                ys.append(fid)
            self._last_feat = feat
        return np.stack(xs), np.array(ys, np.int64)

    def draw_dataset2(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        xs, ys = [], []
        while len(xs) < n:
            fid = self.user.next_request(self.rng, self.cat)
            self._history.append(fid)
            if len(self._history) > SEQ_LEN:
                xs.append(np.array(self._history[-SEQ_LEN - 1:-1], np.int64))
                ys.append(fid)
        return np.stack(xs), np.array(ys, np.int64)

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Everything a draw mutates: the stream's Generator position, the
        sliding-window feature/history carry, and the user's Markov state.
        The Catalog and the static UserModel fields are reconstructed
        deterministically from the population seed, so they are not stored.
        Only the last SEQ_LEN+1 history entries are ever read by a draw, so
        the snapshot stays O(1) per client however long the run."""
        from repro.checkpoint.run_state import generator_state
        return {"rng": generator_state(self.rng),
                "last_feat": self._last_feat,
                "history": [int(h) for h in self._history[-SEQ_LEN - 1:]],
                "genre": int(self.user._genre),
                "file": int(self.user._file)}

    def load_state_dict(self, sd: dict) -> None:
        from repro.checkpoint.run_state import set_generator_state
        set_generator_state(self.rng, sd["rng"])
        lf = sd["last_feat"]
        self._last_feat = None if lf is None else np.asarray(lf, np.float32)
        self._history = [int(h) for h in sd["history"]]
        self.user._genre = int(sd["genre"])
        self.user._file = int(sd["file"])


def make_population(seed: int, num_users: int, topk: int = 1
                    ) -> Tuple[Catalog, List[RequestStream]]:
    rng = np.random.default_rng(seed)
    cat = Catalog.create(rng)
    streams = [RequestStream(cat, UserModel.create(rng, topk),
                             np.random.default_rng(seed * 977 + u + 1))
               for u in range(num_users)]
    return cat, streams
