"""The named wireless-world perturbations (DESIGN.md "Scenario layer").

Each class is one composable axis of the paper's motivating non-idealities —
client churn, flash-crowd request spikes, quiet hours, non-stationary channel
regimes, heterogeneous device classes (Han et al., 2308.03521), and
Pareto-biased partial participation (Jung et al. / SNIPPETS.md Snippet 1,
Dinh et al., 1910.13067). Specs compose with ``+``:

    churn(p_away=0.3)+flash_crowd(period=8,scale=3)

EXPERIMENTS.md "Scenario recipes" documents each knob at paper scale;
``tests/test_scenarios.py`` runs every name (and every pairwise composition)
on the dense-stacked and sparse-cohort paths, and ``tests/golden/`` pins each
name's metric trajectory.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Type

import numpy as np

from repro.scenarios.base import Perturbation

REGISTRY: Dict[str, Type[Perturbation]] = {}


def register(name: str):
    def deco(cls):
        cls.name = name
        REGISTRY[name] = cls
        return cls
    return deco


@register("churn")
class Churn(Perturbation):
    """Client departures/rejoins on a per-user duty cycle.

    A ``p_away`` fraction of users (drawn at bind) churns: each cycles
    through a ``period``-round window with a private phase and an away span
    of ``away`` rounds per cycle, during which the user is unavailable — it
    generates no arrivals, cannot be sampled round-active, and is masked out
    of aggregation. The schedule is pure in (seed, t), so departures and
    rejoins replay identically across engines and resume."""

    def __init__(self, p_away: float = 0.3, period: int = 6, away: int = 2):
        if not 0.0 <= p_away <= 1.0:
            raise ValueError(f"p_away must lie in [0, 1] (got {p_away})")
        if period < 2 or not 1 <= away < period:
            raise ValueError(
                f"need period >= 2 and 1 <= away < period "
                f"(got period={period}, away={away})")
        self.p_away = float(p_away)
        self.period = int(period)
        self.away = int(away)

    def bind(self, rng, num_users):
        self._churns = rng.random(num_users) < self.p_away
        self._phase = rng.integers(0, self.period, num_users)

    def available(self, rng, t, num_users):
        pos = (t + self._phase) % self.period
        return ~(self._churns & (pos < self.away))


@register("flash_crowd")
class FlashCrowd(Perturbation):
    """Request spikes: every ``period`` rounds the Binomial arrival budget
    E_u is multiplied by ``scale`` for ``duty`` consecutive rounds (the
    staging width is pre-sized by ``scale`` so the jitted stage op never
    retraces). Off-spike rounds are untouched."""

    def __init__(self, period: int = 8, duty: int = 2, scale: int = 3):
        if period < 1 or not 1 <= duty <= period:
            raise ValueError(
                f"need period >= 1 and 1 <= duty <= period "
                f"(got period={period}, duty={duty})")
        if int(scale) != scale or scale < 1:
            raise ValueError(f"scale must be an integer >= 1 (got {scale})")
        self.period = int(period)
        self.duty = int(duty)
        self.scale = int(scale)
        self.arrival_width_scale = int(scale)

    def arrivals(self, rng, t, e_u, p_ac):
        if t % self.period >= self.duty:
            return None
        return np.multiply(e_u, self.scale), p_ac


@register("quiet")
class Quiet(Perturbation):
    """Constant arrival-rate damping: every user's activity probability
    p_ac is scaled by ``scale`` in [0, 1]. ``quiet(scale=0.0)`` freezes the
    datasets entirely — the static-world half of Fig. 1."""

    def __init__(self, scale: float = 0.5):
        if not 0.0 <= scale <= 1.0:
            raise ValueError(f"scale must lie in [0, 1] (got {scale})")
        self.scale = float(scale)

    def arrivals(self, rng, t, e_u, p_ac):
        return e_u, np.asarray(p_ac) * self.scale


@register("radius_step")
class RadiusStep(Perturbation):
    """Non-stationary channel regime: from round ``at`` on, every client's
    distance to the BS is multiplied by ``factor`` (a cell-radius step —
    e.g. ``factor≈1.67`` turns the default 600 m cell into Fig. 3's 1 km
    straggler regime mid-run). Compose two steps for a step-up/step-down
    schedule."""

    def __init__(self, at: int = 0, factor: float = 2.0):
        if at < 0:
            raise ValueError(f"at must be >= 0 (got {at})")
        if not (math.isfinite(factor) and factor > 0):
            raise ValueError(f"factor must be finite and > 0 (got {factor})")
        self.at = int(at)
        self.factor = float(factor)

    def system(self, rng, t, sysb):
        if t < self.at:
            return None
        return dataclasses.replace(sysb,
                                   distance=sysb.distance * self.factor)


@register("device_classes")
class DeviceClasses(Perturbation):
    """Heterogeneous device classes: a ``weak_frac`` fraction of users
    (drawn at bind) is a *weak* class whose compute ceiling ``f_max``,
    transmit ceiling ``p_max`` and FIFO storage capacity D_u are scaled by
    ``f``/``p``/``cap`` (the heterogeneity axes of Han et al., 2308.03521).
    Static — applied once at setup to the resource-config rows and buffer
    capacities."""

    def __init__(self, weak_frac: float = 0.5, f: float = 0.5,
                 p: float = 0.5, cap: float = 0.5):
        if not 0.0 <= weak_frac <= 1.0:
            raise ValueError(
                f"weak_frac must lie in [0, 1] (got {weak_frac})")
        for name, v in (("f", f), ("p", p), ("cap", cap)):
            if not 0.0 < v <= 1.0:
                raise ValueError(f"{name} must lie in (0, 1] (got {v})")
        self.weak_frac = float(weak_frac)
        self.f = float(f)
        self.p = float(p)
        self.cap = float(cap)

    def bind(self, rng, num_users):
        self._weak = rng.random(num_users) < self.weak_frac

    def init_capacities(self, rng, caps):
        scale = np.where(self._weak[:len(caps)], self.cap, 1.0)
        return np.maximum((caps * scale).astype(caps.dtype), 4)

    def init_system(self, rng, sysb):
        w = self._weak[:len(sysb.f_max)]
        return dataclasses.replace(
            sysb,
            f_max=sysb.f_max * np.where(w, self.f, 1.0),
            p_max=sysb.p_max * np.where(w, self.p, 1.0))


@register("cluster_churn")
class ClusterChurn(Perturbation):
    """Edge-cluster membership churn (hierarchical runs, ``num_clusters``
    > 1): every ``period`` rounds an expected ``rate`` fraction of users is
    reassigned to a uniformly drawn cluster (a same-cluster draw is a
    no-op — real handovers are a subset of draws). Movers that are
    slot-resident migrate blocks immediately: carried score tables follow
    them, slot-resident contribution rows and FIFO datasets reset (see
    ``core/hierarchy.py``). Pure in (seed, t) like every hook, so the live
    cluster map at round t replays identically across resume. No effect on
    flat or K=1 runs (the hook returns None)."""

    moves_clusters = True

    def __init__(self, rate: float = 0.05, period: int = 1):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must lie in [0, 1] (got {rate})")
        if period < 1:
            raise ValueError(f"period must be >= 1 (got {period})")
        self.rate = float(rate)
        self.period = int(period)

    def cluster_moves(self, rng, t, num_users, num_clusters):
        if num_clusters <= 1 or t % self.period:
            return None
        users = np.flatnonzero(rng.random(num_users) < self.rate)
        if users.size == 0:
            return None
        dest = rng.integers(0, num_clusters, users.size)
        return users, dest


@register("pareto_select")
class ParetoSelect(Perturbation):
    """Pareto-biased client selection (SNIPPETS.md Snippet 1): per-user
    participation-sampling weights drawn once from a Pareto(``alpha``)
    distribution, so a heavy-tailed few are sampled round-active far more
    often. Requires the slot-pool engine's participation sampling
    (``cohort_size`` > 0, ``participation`` < 1) to have an effect — on the
    dense path every client already participates."""

    def __init__(self, alpha: float = 1.5):
        if not (math.isfinite(alpha) and alpha > 0):
            raise ValueError(f"alpha must be finite and > 0 (got {alpha})")
        self.alpha = float(alpha)

    def bind(self, rng, num_users):
        self._w = rng.pareto(self.alpha, num_users) + 1.0

    def selection_weights(self, rng, t, num_users):
        return self._w
