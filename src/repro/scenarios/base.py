"""Composable wireless-world scenarios (DESIGN.md "Scenario layer").

A *scenario* is a pure, seeded schedule of per-round perturbations applied to
the online FL harnesses through four explicit hook points in
``repro/harness/experiments.py``:

  * **setup hooks** (once, before round 0): per-client storage capacities
    (``init_capacities``) and the static resource-config rows — ``f_max``,
    ``p_max``, distances — (``init_system``);
  * **round hooks** (every round ``t``): the arrival process
    (``arrivals`` — E_u / p_ac scaling, e.g. flash crowds), the per-round
    resource rows (``system`` — e.g. cell-radius regime steps), client
    availability (``available`` — churn: departures/rejoins), and the
    participation-sampling bias (``selection_weights`` — e.g. Pareto-biased
    client selection).

Purity contract: every hook receives a ``np.random.Generator`` derived ONLY
from ``(scenario seed, round index, hook id)`` — never the harness host RNG —
and hooks must not keep mutable cross-round state outside ``bind`` (which is
re-run identically at checkpoint resume). Consequences:

  * perturbations at round ``t`` are a pure function of ``(spec, seed, t)``,
    so checkpoints need no scenario state and mid-stream resume stays
    bit-exact;
  * a hook that does not fire returns ``None`` and the harness keeps its
    original code path *byte for byte* — the null scenario (no
    perturbations, spec ``"null"``) is therefore bit-exact against the
    unscenarioed harness on every engine, which
    ``tests/test_scenarios.py`` asserts per engine.

Scenarios compose: ``parse("churn(p_away=0.3)+flash_crowd(scale=3)")`` chains
the two perturbations in order (arrival/system/capacity transforms chain,
availability masks AND, selection weights multiply). The named perturbations
live in ``scenarios/library.py``.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional, Sequence, Tuple

import numpy as np

# hook ids salting the per-(round, hook) RNG streams — stable across versions
# or golden curves shift
_H_BIND = 0
_H_CAPS = 1
_H_SYS0 = 2
_H_ARRIVALS = 3
_H_SYSTEM = 4
_H_AVAILABLE = 5
_H_SELECT = 6
_H_CLUSTER = 7
_SALT = 0x05AF1


class Perturbation:
    """One composable wireless-world perturbation. Every hook defaults to
    "does not fire" (``None``); subclasses override a subset. Hooks must be
    pure in the supplied ``rng`` (see module docstring)."""

    #: registry key; set by ``scenarios.library.register``
    name: str = "perturbation"
    #: integer factor by which the scenario can inflate a round's arrival
    #: count above the base E_u — sizes the (static) staging width so the
    #: jitted stage op never retraces mid-run
    arrival_width_scale: int = 1

    def bind(self, rng: np.random.Generator, num_users: int) -> None:
        """One-time per-run draws (per-user phases, class assignment, ...).
        Re-run identically at resume; only ``rng``/``num_users`` may feed
        the cached state."""

    # -- setup hooks --------------------------------------------------------
    def init_capacities(self, rng, caps: np.ndarray) -> Optional[np.ndarray]:
        """Transform the per-client FIFO capacities D_u. None = unchanged."""
        return None

    def init_system(self, rng, sysb) -> Optional[object]:
        """Transform the static ``ClientSystemBatch`` rows. None = unchanged."""
        return None

    # -- round hooks --------------------------------------------------------
    def arrivals(self, rng, t: int, e_u, p_ac: np.ndarray
                 ) -> Optional[Tuple[object, np.ndarray]]:
        """Transform the round's arrival process ``(E_u, p_ac)``; ``e_u`` may
        be a scalar or per-client array. None = unchanged."""
        return None

    def system(self, rng, t: int, sysb) -> Optional[object]:
        """Transform this round's ``ClientSystemBatch``. None = unchanged."""
        return None

    def available(self, rng, t: int, num_users: int) -> Optional[np.ndarray]:
        """(U,) bool availability mask (False = departed this round).
        None = everyone available."""
        return None

    def selection_weights(self, rng, t: int, num_users: int
                          ) -> Optional[np.ndarray]:
        """(U,) nonnegative participation-sampling weights. None = uniform."""
        return None

    def cluster_moves(self, rng, t: int, num_users: int, num_clusters: int
                      ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Edge-cluster membership churn for round t (hierarchical runs
        only): ``(users, dest_clusters)`` reassignments, or None = the
        cluster map is unchanged this round."""
        return None

    def __repr__(self):
        return f"{type(self).__name__}()"


class Scenario:
    """An ordered composition of perturbations under one seed (see module
    docstring for the purity/composition contract). Harness-facing: the
    ``setup_*``/``round_*`` methods apply every perturbation in order and
    return ``None`` when no perturbation fired, so the caller can keep its
    unscenarioed code path untouched."""

    def __init__(self, perturbations: Sequence[Perturbation] = (),
                 seed: int = 0, spec: str = "null"):
        self.perturbations: Tuple[Perturbation, ...] = tuple(perturbations)
        self.seed = int(seed)
        self.spec = spec
        self._bound_users: Optional[int] = None

    @property
    def is_null(self) -> bool:
        return not self.perturbations

    def __repr__(self):
        return f"Scenario({self.spec!r}, seed={self.seed})"

    # -- pure RNG derivation -------------------------------------------------
    def _rng(self, hook: int, t: int, i: int) -> np.random.Generator:
        """Generator for (hook, round, perturbation-index) — pure in the
        scenario seed; the harness host RNG is never consumed."""
        return np.random.default_rng([_SALT, self.seed, hook, t, i])

    # -- binding -------------------------------------------------------------
    def bind(self, num_users: int) -> "Scenario":
        """Run every perturbation's one-time draws for a U-user population.
        Idempotent for a fixed U (resume calls it again)."""
        if self._bound_users not in (None, int(num_users)):
            raise ValueError(
                f"scenario already bound to U={self._bound_users}, "
                f"cannot rebind to U={num_users}")
        for i, p in enumerate(self.perturbations):
            p.bind(self._rng(_H_BIND, 0, i), int(num_users))
        self._bound_users = int(num_users)
        return self

    def _check_bound(self):
        if self.perturbations and self._bound_users is None:
            raise RuntimeError(
                "scenario hooks called before bind(num_users)")

    # -- setup hooks ---------------------------------------------------------
    def arrival_width(self, base: int) -> int:
        """Static staging width covering every round's worst-case arrivals."""
        w = int(base)
        for p in self.perturbations:
            w *= int(p.arrival_width_scale)
        return w

    def setup_capacities(self, caps: np.ndarray) -> np.ndarray:
        self._check_bound()
        for i, p in enumerate(self.perturbations):
            out = p.init_capacities(self._rng(_H_CAPS, 0, i), caps)
            if out is not None:
                caps = np.asarray(out)
        return caps

    def setup_system(self, sysb):
        self._check_bound()
        for i, p in enumerate(self.perturbations):
            out = p.init_system(self._rng(_H_SYS0, 0, i), sysb)
            if out is not None:
                sysb = out
        return sysb

    # -- round hooks ---------------------------------------------------------
    def round_arrivals(self, t: int, e_u, p_ac: np.ndarray):
        """(E_u, p_ac) for round t — the inputs unchanged (same objects)
        when no perturbation fires."""
        self._check_bound()
        for i, p in enumerate(self.perturbations):
            out = p.arrivals(self._rng(_H_ARRIVALS, t, i), t, e_u, p_ac)
            if out is not None:
                e_u, p_ac = out
        return e_u, p_ac

    def round_system(self, t: int, sysb):
        self._check_bound()
        for i, p in enumerate(self.perturbations):
            out = p.system(self._rng(_H_SYSTEM, t, i), t, sysb)
            if out is not None:
                sysb = out
        return sysb

    def round_available(self, t: int, num_users: int) -> Optional[np.ndarray]:
        """AND of every perturbation's availability mask; None if none fired."""
        self._check_bound()
        mask = None
        for i, p in enumerate(self.perturbations):
            out = p.available(self._rng(_H_AVAILABLE, t, i), t, num_users)
            if out is not None:
                out = np.asarray(out, bool)
                mask = out if mask is None else (mask & out)
        return mask

    def round_selection_weights(self, t: int, num_users: int
                                ) -> Optional[np.ndarray]:
        """Product of every perturbation's selection weights; None if none
        fired."""
        self._check_bound()
        w = None
        for i, p in enumerate(self.perturbations):
            out = p.selection_weights(self._rng(_H_SELECT, t, i), t,
                                      num_users)
            if out is not None:
                out = np.asarray(out, np.float64)
                if (out < 0).any():
                    raise ValueError(
                        f"{p.name}: selection weights must be nonnegative")
                w = out if w is None else (w * out)
        return w

    @property
    def moves_clusters(self) -> bool:
        """True when any perturbation can rewrite the cluster map — the
        hierarchical harness only runs the churn hook (and the admission
        resets it implies) when this is set, keeping static-map runs on the
        unperturbed path."""
        return any(getattr(p, "moves_clusters", False)
                   for p in self.perturbations)

    def round_cluster_moves(self, t: int, num_users: int, num_clusters: int
                            ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Concatenation of every perturbation's cluster reassignments (in
        composition order — later terms win on a user moved twice, matching
        sequential application); None if none fired."""
        self._check_bound()
        users, dest = None, None
        for i, p in enumerate(self.perturbations):
            out = p.cluster_moves(self._rng(_H_CLUSTER, t, i), t,
                                  num_users, num_clusters)
            if out is not None:
                u = np.asarray(out[0], np.int64)
                d = np.asarray(out[1], np.int64)
                users = u if users is None else np.concatenate([users, u])
                dest = d if dest is None else np.concatenate([dest, d])
        if users is None:
            return None
        return users, dest


# ---------------------------------------------------------------------------
# spec DSL:  name(k=v, ...) + name2(...) + ...   |  "null"  |  ""
# ---------------------------------------------------------------------------

_TERM = re.compile(r"^\s*([a-z_][a-z0-9_]*)\s*(?:\((.*)\))?\s*$", re.S)


def _parse_kwargs(body: str, term: str) -> dict:
    if not body or not body.strip():
        return {}
    kwargs = {}
    for part in body.split(","):
        if not part.strip():
            continue
        if "=" not in part:
            raise ValueError(
                f"scenario term {term!r}: arguments must be k=v pairs "
                f"(got {part.strip()!r})")
        k, v = part.split("=", 1)
        try:
            kwargs[k.strip()] = ast.literal_eval(v.strip())
        except (ValueError, SyntaxError) as e:
            raise ValueError(
                f"scenario term {term!r}: cannot parse value {v.strip()!r} "
                f"for {k.strip()!r}") from e
    return kwargs


def parse_scenario(spec: Optional[str], seed: int = 0) -> Optional[Scenario]:
    """Parse a scenario spec string into a ``Scenario``.

    ``""``/None -> ``None`` (no scenario — the harness takes its historical
    code path with no scenario plumbing at all). ``"null"`` -> the empty
    scenario (same trajectory, but routed through the hook plumbing — the
    parity probe). Otherwise ``+``-separated registry terms, e.g.
    ``"churn(p_away=0.3)+flash_crowd(period=8,scale=3)"``; constructor
    kwargs are Python literals. ``seed`` feeds every scenario RNG stream
    (the harnesses pass the experiment seed).
    """
    if not spec:
        return None
    spec = spec.strip()
    if spec == "null":
        return Scenario((), seed=seed, spec="null")
    from repro.scenarios.library import REGISTRY
    perts: List[Perturbation] = []
    for term in spec.split("+"):
        m = _TERM.match(term)
        if not m:
            raise ValueError(f"malformed scenario term {term!r} in {spec!r}")
        name, body = m.group(1), m.group(2)
        if name == "null":
            raise ValueError(
                "'null' cannot be composed with other scenario terms")
        if name not in REGISTRY:
            raise ValueError(
                f"unknown scenario {name!r} (known: "
                + ", ".join(sorted(REGISTRY)) + ")")
        try:
            perts.append(REGISTRY[name](**_parse_kwargs(body, term)))
        except TypeError as e:
            raise ValueError(f"scenario term {term.strip()!r}: {e}") from e
    return Scenario(perts, seed=seed, spec=spec)
