"""Composable wireless-world scenario layer (DESIGN.md "Scenario layer").

``parse_scenario(spec, seed)`` turns a ``+``-composed spec string (e.g.
``"churn(p_away=0.3)+flash_crowd(scale=3)"``) into a ``Scenario`` whose pure,
seeded per-round hooks the online harnesses apply; ``REGISTRY`` maps the
named perturbations. See ``scenarios/base.py`` for the hook/purity contract
and ``scenarios/library.py`` for the named perturbations.
"""
from repro.scenarios.base import Perturbation, Scenario, parse_scenario
from repro.scenarios.library import REGISTRY

__all__ = ["Perturbation", "Scenario", "parse_scenario", "REGISTRY"]
