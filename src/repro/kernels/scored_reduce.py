"""Pallas TPU kernel for the OSAFL score hot-spot (paper eqs. 19-20).

Given U stacked client updates d (U, N) and the mean update (N,), one fused
pass over HBM computes everything the score needs:

    dots[u]   = <d_u, mean>
    norms[u]  = ||d_u||^2
    mean_sq   = ||mean||^2

Naively this is three separate O(U*N) reductions reading d twice and mean
twice; the fused kernel streams each operand exactly once through VMEM
(block (BLOCK_U, BLOCK_N)) and accumulates along the sequential N grid
dimension; the client dimension is blocked too, so thousand-client cohorts
stay within the ~16 MiB VMEM budget when compiled. On CPU it is validated
with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 2048          # compiled TPU path: (block_u, block_n) f32
DEFAULT_BLOCK_U = 512           # in VMEM: 512 * 2048 * 4B = 4 MiB
INTERPRET_BLOCK_N = 512 * 1024  # interpret mode runs the grid loop at Python
                                # speed, so large blocks (few grid steps) are
                                # ~30x faster on CPU and VMEM doesn't apply


def _scored_kernel(d_ref, mean_ref, dots_ref, norms_ref, msq_ref):
    u = pl.program_id(0)                        # client-block (parallel)
    i = pl.program_id(1)                        # N-block (sequential accum)
    d = d_ref[...].astype(jnp.float32)          # (bu, bn)
    m = mean_ref[...].astype(jnp.float32)       # (1, bn)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        norms_ref[...] = jnp.zeros_like(norms_ref)

    @pl.when((u == 0) & (i == 0))
    def _init_msq():
        msq_ref[...] = jnp.zeros_like(msq_ref)

    dots_ref[...] += jnp.sum(d * m, axis=1, keepdims=True)
    norms_ref[...] += jnp.sum(d * d, axis=1, keepdims=True)

    @pl.when(u == 0)                            # count ||mean||^2 once
    def _msq():
        msq_ref[...] += jnp.sum(m * m, axis=1, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_u", "interpret"))
def scored_reduce(d, mean, *, block_n=None, block_u=None, interpret=True):
    """d: (U, N); mean: (N,) -> (dots (U,), norms_sq (U,), mean_sq ())."""
    U, N = d.shape
    if block_n is None:
        block_n = INTERPRET_BLOCK_N if interpret else DEFAULT_BLOCK_N
    if block_u is None:
        block_u = U if interpret else DEFAULT_BLOCK_U
    block_n = min(block_n, N)
    block_u = min(block_u, U)
    pad_n = (-N) % block_n
    pad_u = (-U) % block_u
    if pad_n or pad_u:
        d = jnp.pad(d, ((0, pad_u), (0, pad_n)))   # zero rows: dots/norms 0
        mean = jnp.pad(mean, (0, pad_n))
    Up, Np = U + pad_u, N + pad_n
    grid = (Up // block_u, Np // block_n)
    dots, norms, msq = pl.pallas_call(
        _scored_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_u, block_n), lambda u, i: (u, i)),
            pl.BlockSpec((1, block_n), lambda u, i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((block_u, 1), lambda u, i: (u, 0)),
            pl.BlockSpec((block_u, 1), lambda u, i: (u, 0)),
            pl.BlockSpec((1, 1), lambda u, i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Up, 1), jnp.float32),
            jax.ShapeDtypeStruct((Up, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(d, mean.reshape(1, Np))
    return dots[:U, 0], norms[:U, 0], msq[0, 0]


def osafl_scores_fused(d, chi: float = 1.0, *, interpret=True):
    """End-to-end scored weights from stacked updates d (U, N):
    lambda_u = (chi + cos(d_u, mean)) / (chi + 1)."""
    U = d.shape[0]
    mean = jnp.mean(d, axis=0)
    dots, norms, msq = scored_reduce(d, mean, interpret=interpret)
    cos = dots / jnp.maximum(jnp.sqrt(norms) * jnp.sqrt(msq), 1e-12)
    return (chi + cos) / (chi + 1.0)
