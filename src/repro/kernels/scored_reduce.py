"""Pallas TPU kernel for the OSAFL score hot-spot (paper eqs. 19-20).

Given U stacked client updates d (U, N) and the mean update (N,), one fused
pass over HBM computes everything the score needs:

    dots[u]   = <d_u, mean>
    norms[u]  = ||d_u||^2
    mean_sq   = ||mean||^2

Naively this is three separate O(U*N) reductions reading d twice and mean
twice; the fused kernel streams each operand exactly once through VMEM
(block (U, BLOCK_N)) and accumulates in the (sequential) grid dimension.
On CPU it is validated with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 2048


def _scored_kernel(d_ref, mean_ref, dots_ref, norms_ref, msq_ref):
    i = pl.program_id(0)
    d = d_ref[...].astype(jnp.float32)          # (U, bn)
    m = mean_ref[...].astype(jnp.float32)       # (1, bn)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = jnp.zeros_like(dots_ref)
        norms_ref[...] = jnp.zeros_like(norms_ref)
        msq_ref[...] = jnp.zeros_like(msq_ref)

    dots_ref[...] += jnp.sum(d * m, axis=1, keepdims=True)
    norms_ref[...] += jnp.sum(d * d, axis=1, keepdims=True)
    msq_ref[...] += jnp.sum(m * m, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def scored_reduce(d, mean, *, block_n=DEFAULT_BLOCK_N, interpret=True):
    """d: (U, N); mean: (N,) -> (dots (U,), norms_sq (U,), mean_sq ())."""
    U, N = d.shape
    block_n = min(block_n, N)
    pad = (-N) % block_n
    if pad:
        d = jnp.pad(d, ((0, 0), (0, pad)))
        mean = jnp.pad(mean, (0, pad))
    Np = N + pad
    grid = (Np // block_n,)
    dots, norms, msq = pl.pallas_call(
        _scored_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((U, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((U, 1), lambda i: (0, 0)),
            pl.BlockSpec((U, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((U, 1), jnp.float32),
            jax.ShapeDtypeStruct((U, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(d, mean.reshape(1, Np))
    return dots[:, 0], norms[:, 0], msq[0, 0]


def osafl_scores_fused(d, chi: float = 1.0, *, interpret=True):
    """End-to-end scored weights from stacked updates d (U, N):
    lambda_u = (chi + cos(d_u, mean)) / (chi + 1)."""
    U = d.shape[0]
    mean = jnp.mean(d, axis=0)
    dots, norms, msq = scored_reduce(d, mean, interpret=interpret)
    cos = dots / jnp.maximum(jnp.sqrt(norms) * jnp.sqrt(msq), 1e-12)
    return (chi + cos) / (chi + 1.0)
