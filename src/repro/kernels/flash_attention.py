"""Pallas TPU flash attention (causal, GQA-aware) with explicit BlockSpec
VMEM tiling.

Target: TPU MXU — block shapes default to (128, 128) (MXU-aligned); the kernel
runs the kv-block loop with a running (m, l) online softmax so the (S, S)
score matrix never materializes in HBM. Validated on CPU via interpret=True
against kernels/ref.py.

Layout: q (B, H, S, D); k/v (B, Hkv, S, D). The grid is
(B * H, S // block_q); each program streams kv blocks of its (batch, head).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq_len,
                  scale, causal):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale                   # (bq, d)
    d = q.shape[-1]
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, d), jnp.float32)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(kj, carry):
        m, l, acc = carry
        # leading axis via dslice(0, 1): a bare int mixed with Slice indices
        # breaks pl.load on jax 0.4.x
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(kj * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(kj * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        s = q @ k.T                                            # (bq, bk)
        if causal:
            k_pos = kj * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    if causal:
        # only kv blocks at or before this q block
        num_k = qi + 1 if block_q == block_k else \
            ((qi + 1) * block_q + block_k - 1) // block_k
    else:
        num_k = seq_len // block_k
    m, l, acc = jax.lax.fori_loop(0, num_k, body, (m, l, acc))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal=True, scale=None,
                         block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                         interpret=True):
    """q: (B, H, S, D); k/v: (B, Hkv, S, D) with H % Hkv == 0."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    scale = D ** -0.5 if scale is None else scale
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    group = H // Hkv

    grid = (B * H, S // block_q)

    def q_map(bh, qi):
        return (bh, qi, 0)

    def kv_map(bh, qi):
        return (bh // group, 0, 0)

    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, seq_len=S, scale=scale,
                               causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D),
                         lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, S, D), kv_map),
            pl.BlockSpec((1, S, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        interpret=interpret,
    )(q.reshape(B * H, S, D),
      k.reshape(B * Hkv, S, D),
      v.reshape(B * Hkv, S, D))
    return out.reshape(B, H, S, D)
