from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.scored_reduce import osafl_scores_fused, scored_reduce

__all__ = ["ops", "ref", "flash_attention_bhsd", "osafl_scores_fused",
           "scored_reduce"]
