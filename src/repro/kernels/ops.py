"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels execute in interpret mode; on a real TPU set
REPRO_PALLAS_INTERPRET=0 to compile them natively.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.scored_reduce import osafl_scores_fused, scored_reduce


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def flash_attention(q, k, v, *, causal=True, scale=None):
    """Model-layout wrapper: q (B,S,H,D), k/v (B,S,Hkv,D) -> (B,S,H,D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, scale=scale,
                               interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def osafl_scores(d_stacked, chi: float = 1.0):
    """Fused OSAFL score computation; d_stacked (U, N)."""
    return osafl_scores_fused(d_stacked, chi, interpret=_interpret())


def fused_scored_reduce(d_stacked, mean):
    return scored_reduce(d_stacked, mean, interpret=_interpret())
