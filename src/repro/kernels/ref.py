"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_reference(q, k, v, *, causal=True, scale=None):
    """q: (B,H,S,D); k/v: (B,Hkv,S,D). Returns (B,H,S,D)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    scale = D ** -0.5 if scale is None else scale
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def scored_reduce_reference(d, mean):
    """d: (U,N); mean: (N,) -> (dots, norms_sq, mean_sq)."""
    d32 = d.astype(jnp.float32)
    m32 = mean.astype(jnp.float32)
    return (d32 @ m32, jnp.sum(d32 * d32, axis=1), jnp.sum(m32 * m32))


def osafl_scores_reference(d, chi: float = 1.0):
    mean = jnp.mean(d.astype(jnp.float32), axis=0)
    dots, norms, msq = scored_reduce_reference(d, mean)
    cos = dots / jnp.maximum(jnp.sqrt(norms) * jnp.sqrt(msq), 1e-12)
    return (chi + cos) / (chi + 1.0)
