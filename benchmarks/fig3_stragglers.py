"""Paper Fig. 3: model payload vs stragglers — the fraction of clients for
which the resource problem (5) is infeasible, per model, over rounds.
Reproduced on the stacked resource path: the whole cohort's kappa/f/p
solves run as one ``optimize_round_batched`` call per round, and the
paper's 1 km straggler regime is expressed through the scenario layer —
a ``radius_step`` perturbation steps every client's distance mid-run
(src/repro/scenarios/), producing a second per-model curve."""
from __future__ import annotations

import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np

from benchmarks import curves
from repro.harness import MODEL_PARAMS
from repro.core.resource import NetworkConfig, make_clients
from repro.core.resource_stacked import optimize_round_batched, stack_clients
from repro.scenarios import parse_scenario

PRESETS = {
    "smoke": dict(num_clients=40, rounds=10),
    # paper-scale cohort width (EXPERIMENTS.md): U=256 solved jointly
    "paper": dict(num_clients=256, rounds=40),
}

# 600 m default cell -> the paper's 1 km regime, stepped at mid-run
_STEP = "radius_step(at={at},factor=1.667)"


def _straggler_curve(rng, net, sysb, n_params, rounds, scn):
    """Per-round infeasible fraction + per-client infeasibility counts."""
    U = len(sysb.f_max)
    fracs, per_client = [], np.zeros(U)
    for t in range(rounds):
        sb = scn.round_system(t, sysb) if scn is not None else sysb
        kappas = optimize_round_batched(rng, net, sb, n_params).kappa
        infeas = kappas < 1
        fracs.append(float(infeas.mean()))
        per_client += infeas
    return fracs, per_client


def run(preset="smoke", seed=0, scenario="", out=None):
    t0 = time.time()
    cfg = PRESETS[preset]
    num_clients, rounds = cfg["num_clients"], cfg["rounds"]
    step_spec = curves.compose_specs(_STEP.format(at=rounds // 2), scenario)
    base_spec = curves.compose_specs(scenario)
    rng = np.random.default_rng(seed)
    net = NetworkConfig()
    sysb = stack_clients(make_clients(rng, num_clients))
    curve_list, summary = [], {}
    for model, n_params in sorted(MODEL_PARAMS.items(),
                                  key=lambda kv: -kv[1]):
        for label, spec in (("", base_spec), ("_1km_step", step_spec)):
            scn = parse_scenario(spec, seed=seed)
            if scn is not None:
                scn.bind(num_clients)
            sb = scn.setup_system(sysb) if scn is not None else sysb
            fracs, per_client = _straggler_curve(
                np.random.default_rng([seed, n_params]), net, sb, n_params,
                rounds, scn)
            curve_list.append(curves.series_curve(
                f"{model}{label}", {"straggler_frac": fracs}, scenario=spec))
            summary[f"fig3_{model}{label}_straggler_frac"] = \
                float(np.mean(fracs))
            if not label:
                # paper metric: clients infeasible in >= 50% of rounds
                summary[f"fig3_{model}_ge50pct_rounds"] = \
                    float(np.mean(per_client / rounds >= 0.5))
    doc = curves.make_doc(
        "fig3_stragglers", preset, dict(cfg, seed=seed, scenario=scenario),
        curve_list, summary)
    curves.finish(doc, out)
    return curves.summary_rows(doc), time.time() - t0, doc


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    curves.add_cli_args(p)
    a = p.parse_args()
    rows, dt, _ = run(preset=a.preset, seed=a.seed, scenario=a.scenario,
                      out=a.out)
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
