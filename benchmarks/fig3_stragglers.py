"""Paper Fig. 3: model payload vs stragglers — the fraction of clients for
which the resource problem (5) is infeasible, per model, over rounds."""
from __future__ import annotations

import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np

from benchmarks.common import MODEL_PARAMS
from repro.core.resource import NetworkConfig, make_clients, optimize_round


def run(num_clients=40, rounds=10, seed=0):
    t0 = time.time()
    rng = np.random.default_rng(seed)
    net = NetworkConfig()
    clients = make_clients(rng, num_clients)
    rows = []
    for model, n_params in sorted(MODEL_PARAMS.items(),
                                  key=lambda kv: -kv[1]):
        fracs = []
        per_client = np.zeros(num_clients)
        for t in range(rounds):
            dec = optimize_round(rng, net, clients, n_params)
            infeas = np.array([not d.feasible for d in dec])
            fracs.append(infeas.mean())
            per_client += infeas
        # paper metric: clients that are stragglers in >= 50% of rounds
        ge50 = float(np.mean(per_client / rounds >= 0.5))
        rows.append((f"fig3_{model}_straggler_frac", float(np.mean(fracs))))
        rows.append((f"fig3_{model}_ge50pct_rounds", ge50))
    return rows, time.time() - t0


if __name__ == "__main__":
    import argparse
    argparse.ArgumentParser(description=__doc__.splitlines()[0]).parse_args()
    rows, dt = run()
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
