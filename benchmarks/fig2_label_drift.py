"""Paper Fig. 2: per-user label-distribution drift across training rounds
(share of the initially top-2 and least-2 files in the FIFO buffer)."""
from __future__ import annotations

import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np

from repro.core.buffer import OnlineBuffer
from repro.data.video_caching import D1_DIM, make_population


def run(rounds=12, seed=0):
    t0 = time.time()
    cat, streams = make_population(seed, 1)
    s = streams[0]
    buf = OnlineBuffer.create(100, (D1_DIM,), 100)
    x, y = s.draw_dataset1(100)
    buf.stage(x, y)
    buf.commit()
    h0 = buf.label_histogram()
    top2 = np.argsort(-h0)[:2]
    least2 = [f for f in np.argsort(h0) if h0[f] > 0][:2]
    drift_top, drift_least, shifts = [], [], []
    for t in range(rounds):
        x, y = s.draw_dataset1(12)
        buf.stage(x, y)
        buf.commit()
        h = buf.label_histogram()
        drift_top.append(float(h[top2].sum()))
        drift_least.append(float(h[least2].sum()))
        shifts.append(buf.distribution_shift())
    rows = [("fig2_top2_share_initial", float(h0[top2].sum())),
            ("fig2_top2_share_final", drift_top[-1]),
            ("fig2_least2_share_final", drift_least[-1]),
            ("fig2_mean_round_shift", float(np.mean(shifts[1:])))]
    return rows, time.time() - t0


if __name__ == "__main__":
    import argparse
    argparse.ArgumentParser(description=__doc__.splitlines()[0]).parse_args()
    rows, dt = run()
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
