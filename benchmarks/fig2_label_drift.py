"""Paper Fig. 2: per-user label-distribution drift across training rounds
(share of the initially top-2 and least-2 files in the FIFO buffer).
Reproduced on the stacked data layer: the whole cohort's FIFO buffers and
request streams advance as one batched op per round
(``StackedOnlineBuffer`` + ``StackedRequestStream``), with the arrival
process routed through the scenario layer — the baseline drift curve runs
the native Binomial arrivals, and a ``flash_crowd`` curve shows how request
spikes accelerate the drift (src/repro/scenarios/)."""
from __future__ import annotations

import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np

from benchmarks import curves
from repro.core.buffer_stacked import StackedOnlineBuffer
from repro.data.online import binomial_arrivals_batched, dataset_layout
from repro.data.video_caching import make_population
from repro.data.video_caching_stacked import StackedRequestStream
from repro.scenarios import parse_scenario

PRESETS = {
    "smoke": dict(num_users=4, rounds=12, capacity=100, arrivals=12),
    # paper-scale cohort width (EXPERIMENTS.md): U=256 users drifting at once
    "paper": dict(num_users=256, rounds=100, capacity=320, arrivals=12),
}


def _drift_curve(preset_cfg, seed, spec):
    """Mean top-2/least-2 shares over users, per round, under ``spec``."""
    U, rounds = preset_cfg["num_users"], preset_cfg["rounds"]
    cap, arrivals = preset_cfg["capacity"], preset_cfg["arrivals"]
    cat, streams = make_population(seed, U)
    rstream = StackedRequestStream.from_streams(cat, streams, seed=seed)
    scn = parse_scenario(spec, seed=seed)
    if scn is not None:
        scn.bind(U)
    width = scn.arrival_width(arrivals) if scn else arrivals
    feat_shape, dtype = dataset_layout(1)
    buf = StackedOnlineBuffer.create(np.full(U, cap), feat_shape, 100,
                                     stage_capacity=max(width, cap),
                                     dtype=dtype)
    xs, ys, cnt = rstream.draw(np.full(U, cap), 1, cap)
    buf.stage(xs, ys, cnt)
    buf.commit()
    h0 = buf.label_histograms()                     # (U, L)
    top2 = np.argsort(-h0, axis=1)[:, :2]
    # least-2 present files per user (mask absent files out of the argsort)
    least = np.where(h0 > 0, h0, np.inf)
    least2 = np.argsort(least, axis=1)[:, :2]
    rowsel = np.arange(U)[:, None]
    p_ac = np.array([s.user.p_ac for s in streams])
    buf.distribution_shifts()                       # arm the shift baseline
    top_share, least_share, shifts = [], [], []
    for t in range(rounds):
        e_u, p = arrivals, p_ac
        if scn is not None:
            e_u, p = scn.round_arrivals(t, e_u, p)
        counts = binomial_arrivals_batched(
            np.random.default_rng([seed, t]), e_u, p)
        xs, ys, cnt = rstream.draw(counts, 1, width)
        buf.stage(xs, ys, cnt)
        buf.commit()
        h = buf.label_histograms()
        top_share.append(float(h[rowsel, top2].sum(axis=1).mean()))
        least_share.append(float(h[rowsel, least2].sum(axis=1).mean()))
        shifts.append(float(buf.distribution_shifts().mean()))
    series = {"top2_share": top_share, "least2_share": least_share,
              "dist_shift": shifts}
    h0_top = float(h0[rowsel, top2].sum(axis=1).mean())
    return series, h0_top


def run(preset="smoke", seed=0, scenario="", out=None):
    t0 = time.time()
    cfg = PRESETS[preset]
    base_spec = curves.compose_specs(scenario)
    spike_spec = curves.compose_specs("flash_crowd(period=4,duty=1,scale=3)",
                                      scenario)
    base, h0_top = _drift_curve(cfg, seed, base_spec)
    spike, _ = _drift_curve(cfg, seed, spike_spec)
    summary = {
        "fig2_top2_share_initial": h0_top,
        "fig2_top2_share_final": base["top2_share"][-1],
        "fig2_least2_share_final": base["least2_share"][-1],
        "fig2_mean_round_shift": float(np.mean(base["dist_shift"][1:])),
        "fig2_flashcrowd_mean_round_shift":
            float(np.mean(spike["dist_shift"][1:])),
    }
    doc = curves.make_doc(
        "fig2_label_drift", preset, dict(cfg, seed=seed, scenario=scenario),
        [curves.series_curve("drift", base, scenario=base_spec),
         curves.series_curve("drift_flash_crowd", spike,
                             scenario=spike_spec)],
        summary)
    curves.finish(doc, out)
    return curves.summary_rows(doc), time.time() - t0, doc


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    curves.add_cli_args(p)
    a = p.parse_args()
    rows, dt, _ = run(preset=a.preset, seed=a.seed, scenario=a.scenario,
                      out=a.out)
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
