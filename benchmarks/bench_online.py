"""Online-cohort benchmark: the paper's per-round *online wireless pipeline*
— Binomial(E_u, p_ac) FIFO arrivals, the joint kappa/f/p resource optimizer,
and the scored OSAFL aggregation round — loop (per-client NumPy/pytree
oracles) vs the vectorized stacked implementations, at U = 256 on CPU.

Three measurements:

  * pipeline: arrivals ingest (stage + FIFO commit) + resource optimization
    + server round on a fixed synthetic update matrix. This isolates exactly
    the components this pipeline vectorizes (local SGD is identical compute
    in both engines and is benchmarked by ``bench_stacked.py``). Acceptance
    target: >= 10x at U = 256.
  * request generation: one online round of request-model sampling —
    Binomial counts + per-user draws padded to the ``(U, A, ...)`` stage
    layout — for both request backends: the per-user Python oracle streams
    (``data/video_caching.py`` via ``draw_arrival_batch``) vs the batched
    Gumbel-trick sampler (``data/video_caching_stacked.py``). This was the
    last O(U) Python loop in the online harness. Acceptance target: >= 10x
    at U = 256.
  * full harness: end-to-end ``repro.harness.run`` round time, loop engine
    vs stacked engine, from the
    in-harness ``round_s`` history field with the first (compile-bearing)
    round dropped; the vectorized harness is run once per request backend
    and its per-round ``request_gen_s`` field is reported as a column.
  * fused: the single-dispatch device-resident round
    (``core/round_fused.FusedEngine``, ``rounds_per_dispatch`` rounds per
    XLA executable, f32 resource solve) vs the multi-dispatch engine's
    stacked-request round time, with the compiled segment's
    ``hlo_analysis.dispatch_report`` (executable / entry / while-trip
    counts) embedded in the measurement dict so the one-dispatch claim is
    recorded in the CI artifact, not just asserted locally. Measured at
    two operating points: U = 256, where the round is dominated by the
    local-SGD compute both engines share (fusing can only remove the
    per-round dispatch + host-draw overhead, measured ~1.1x; gated as a
    >= 1x no-regression bar), and U = 16 with an 8-round baseline, where
    that overhead IS the round (measured ~2.4-2.9x; gated >= 2x —
    this is the term that stays constant while compute shrinks on
    accelerators). ``single_dispatch`` must be true at both points.

Every timed region syncs ALL device outputs it produced
(``block_until_ready`` on weights + buffer state, features + labels, or
the whole per-round output pytree) — an unsynced output would let device
work leak out of the perf_counter window and inflate the speedups.

Usage: python benchmarks/bench_online.py [U] [rounds] [--smoke] [--json PATH]
(runs from any CWD: the script shims repo root + ``src/`` onto sys.path)

A fifth measurement runs at a different scale: U = 4096 with the
sparse-cohort slot-pool engine (``cohort_size=64``, ``core/cohort.py``)
vs the dense stacked engine — the round time should track the slot count,
not the population. Acceptance target: >= 5x (measured ~40x on 2-core CI
CPUs; the bar guards the scaling claim, not the constant).

``--smoke`` is the CI bench-gate mode: U = 256 with the minimum round
counts, the 10x pipeline / 10x request-gen acceptance bars, a >= 4x
end-to-end harness-round bar (the measured steady state is ~7-9x; the
slack absorbs noisy shared runners), the >= 1x fused no-regression bar at
U = 256 and the >= 2x fused overhead-elimination bar at U = 16 (all at
k=8 rounds/dispatch), the >= 5x sparse-cohort bar at U = 4096, plus the
<= 3x two-tier hierarchical-aggregation cost bar at U = 256, K = 8
(``bench_hier``: the K-cluster round vs the flat scored round on a fixed
update matrix).
``--json`` writes the measurement dicts to a file — CI uploads it as a
per-PR workflow artifact so the speedups are tracked, not just gated.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from repro import harness
from repro.harness import ExperimentConfig, build_fused_engine

from repro.configs.base import FLConfig
from repro.core.buffer import OnlineBuffer, binomial_arrivals
from repro.core.buffer_stacked import StackedOnlineBuffer
from repro.core.osafl import ClientUpdate, OSAFLServer, StackedOSAFLServer
from repro.core.resource import NetworkConfig, make_clients, optimize_round
from repro.core.resource_stacked import optimize_round_batched, stack_clients
from repro.data.online import binomial_arrivals_batched, draw_arrival_batch
from repro.data.video_caching import make_population
from repro.data.video_caching_stacked import StackedRequestStream
from repro.launch.hlo_analysis import dispatch_report
from repro.models.small import init_small


def bench_pipeline(U: int = 256, rounds: int = 5, n_params: int = 18_000,
                   e_u: int = 8, seed: int = 0) -> dict:
    """Per-round online pipeline: arrivals + optimizer + OSAFL round."""
    rng = np.random.default_rng(seed)
    net = NetworkConfig()
    clients = make_clients(rng, U)
    sysb = stack_clients(clients)
    caps = rng.integers(80, 160, size=U)
    feat = (10,)
    bufs = [OnlineBuffer.create(int(c), feat, 100, dtype=np.int64)
            for c in caps]
    for b, c in zip(bufs, caps):
        b.stage(np.zeros((c, 10), np.int64), np.zeros(c, np.int64))
        b.commit()
    sbuf = StackedOnlineBuffer.create(caps, feat, 100,
                                      stage_capacity=int(caps.max()),
                                      dtype=np.int64)
    sbuf.stage(np.zeros((U, int(caps.max()), 10), np.int64),
               np.zeros((U, int(caps.max())), np.int64), caps)
    sbuf.commit()
    p_ac = rng.uniform(0.3, 0.8, U)
    params = init_small(jax.random.PRNGKey(seed), "mlp")
    fl = FLConfig(num_clients=U, local_lr=0.1, global_lr=16.0)
    loop_srv = OSAFLServer(params, fl, U)
    st_srv = StackedOSAFLServer(params, fl, U)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), U)
    upds = [ClientUpdate(u, jax.tree.map(
        lambda p, k=k: jax.random.normal(k, p.shape), params), kappa=5)
        for u, k in enumerate(keys)]
    d_new = st_srv.codec.flatten_stacked(
        jax.tree.map(lambda *xs: jnp.stack(xs), *[u.d for u in upds]))
    active = np.ones(U, bool)
    ax = np.zeros((U, e_u, 10), np.int64)
    ay = np.zeros((U, e_u), np.int64)

    def loop_round():
        for c in range(U):
            n = binomial_arrivals(rng, e_u, p_ac[c])
            if n:
                bufs[c].stage(ax[c, :n], ay[c, :n])
            bufs[c].commit()
        optimize_round(rng, net, clients, n_params)
        loop_srv.round(upds)
        jax.block_until_ready(jax.tree.leaves(loop_srv.params))

    def vec_round():
        counts = binomial_arrivals_batched(rng, e_u, p_ac)
        sbuf.stage(ax, ay, counts)
        sbuf.commit()
        optimize_round_batched(rng, net, sysb, n_params)
        st_srv.round_stacked(d_new, active)
        # sync ALL async outputs of the timed round (weights AND the
        # committed buffer state), not just the weights — an unsynced
        # output would let device work leak out of the perf window
        jax.block_until_ready((st_srv.w, sbuf.state))

    loop_round()
    vec_round()                                   # warm dispatch + compile
    t0 = time.perf_counter()
    for _ in range(rounds):
        loop_round()
    t_loop = (time.perf_counter() - t0) / rounds
    t0 = time.perf_counter()
    for _ in range(rounds):
        vec_round()
    t_vec = (time.perf_counter() - t0) / rounds
    return {"U": U, "loop_s": t_loop, "vec_s": t_vec,
            "speedup": t_loop / t_vec}


def bench_request_gen(U: int = 256, rounds: int = 5, e_u: int = 8,
                      dataset: int = 2, seed: int = 0) -> dict:
    """One online round of request generation — Binomial(E_u, p_ac) counts
    + per-user draws in the padded stage layout — python oracle streams vs
    the stacked Gumbel-trick sampler, same population per seed."""
    cat, streams = make_population(seed, U)
    rstream = StackedRequestStream.from_streams(cat, streams, seed=seed + 1)
    p_ac = np.array([s.user.p_ac for s in streams])
    rng_py = np.random.default_rng(seed)
    rng_st = np.random.default_rng(seed)
    # warm both: stream sliding windows + the jitted scan — two stacked
    # draws so the cold-window trace AND the steady-state (warmup=0) trace
    # are both compiled before timing
    warm = np.full(U, e_u)
    draw_arrival_batch(streams, warm, dataset, width=e_u)
    jax.block_until_ready(rstream.draw(warm, dataset, e_u))
    jax.block_until_ready(rstream.draw(warm, dataset, e_u))

    t0 = time.perf_counter()
    for _ in range(rounds):
        counts = binomial_arrivals_batched(rng_py, e_u, p_ac)
        draw_arrival_batch(streams, counts, dataset, width=e_u)
    t_py = (time.perf_counter() - t0) / rounds
    t0 = time.perf_counter()
    for _ in range(rounds):
        counts = binomial_arrivals_batched(rng_st, e_u, p_ac)
        # block on the full (features, labels) draw — timing only the label
        # column would leave the feature scatter outside the perf window
        jax.block_until_ready(rstream.draw(counts, dataset, e_u))
    t_st = (time.perf_counter() - t0) / rounds
    return {"U": U, "dataset": dataset, "python_s": t_py, "stacked_s": t_st,
            "speedup": t_py / t_st}


def bench_harness(U: int = 256, rounds: int = 3, model: str = "mlp",
                  dataset: int = 2, seed: int = 0) -> dict:
    """End-to-end harness rounds: mean in-harness ``round_s`` over the
    steady-state rounds (the first round pays jit compilation and is
    dropped). The vectorized harness runs once per request backend; its
    per-round ``request_gen_s`` field becomes the request_gen_s columns."""
    xc = ExperimentConfig(model=model, dataset=dataset, num_clients=U,
                          rounds=1 + rounds, seed=seed)
    hv = harness.run("osafl", xc)[1:]
    hs = harness.run(
        "osafl", dataclasses.replace(xc, request_backend="stacked"))[1:]
    t_loop = float(np.mean(
        [h["round_s"] for h in
         harness.run("osafl",
                     dataclasses.replace(xc, engine="loop"))[1:]]))
    t_vec = float(np.mean([h["round_s"] for h in hv]))
    t_vec_st = float(np.mean([h["round_s"] for h in hs]))
    return {"U": U, "rounds": rounds, "model": model, "loop_s": t_loop,
            "vec_s": t_vec, "vec_stacked_req_s": t_vec_st,
            "request_gen_s": {
                "python": float(np.mean([h["request_gen_s"] for h in hv])),
                "stacked": float(np.mean([h["request_gen_s"] for h in hs]))},
            "speedup": t_loop / t_vec,
            "speedup_stacked_req": t_loop / t_vec_st}


def bench_fused(U: int = 256, rounds: int = 2, rounds_per_dispatch: int = 8,
                model: str = "mlp", dataset: int = 2, seed: int = 0,
                dispatch_s: float = None) -> dict:
    """Fused single-dispatch rounds vs the multi-dispatch engine.

    The fused side drives ``core/round_fused.FusedEngine`` directly (not the
    harness) so the compiled segment's optimized HLO is in hand for
    ``launch/hlo_analysis.dispatch_report`` — the artifact records the
    executable/while-loop counts that substantiate the one-dispatch claim.
    ``dispatch_s`` (mean steady-state round_s of the dispatch engine with
    stacked requests) can be passed in from ``bench_harness`` to avoid
    re-measuring; standalone runs measure it here. Timed fused segments are
    fully synced (``block_until_ready`` on every per-round output column)."""
    xc = ExperimentConfig(model=model, dataset=dataset, num_clients=U,
                          rounds=1 + rounds, seed=seed,
                          request_backend="stacked")
    if dispatch_s is None:
        hd = harness.run("osafl", xc)[1:]
        dispatch_s = float(np.mean([h["round_s"] for h in hd]))
    fxc = dataclasses.replace(xc, round_backend="fused",
                              resource_backend="f32",
                              rounds_per_dispatch=rounds_per_dispatch)
    engine, s = build_fused_engine("osafl", fxc)
    carry = engine.init_carry(s.server, s.sbuf, s.rstream, 0)
    carry, outs = engine.run_segment(carry, rounds_per_dispatch)   # compile
    jax.block_until_ready(outs)
    segments = max(2, -(-rounds // rounds_per_dispatch))
    t0 = time.perf_counter()
    for _ in range(segments):
        carry, outs = engine.run_segment(carry, rounds_per_dispatch)
        jax.block_until_ready(outs)
    t_fused = (time.perf_counter() - t0) / (segments * rounds_per_dispatch)
    engine.check_outputs(jax.tree.map(np.asarray, outs))
    rep = dispatch_report(engine.compiled_text(rounds_per_dispatch),
                          rounds_per_dispatch=rounds_per_dispatch)
    return {"U": U, "rounds_per_dispatch": rounds_per_dispatch,
            "dispatch_s": dispatch_s, "fused_s": t_fused,
            "dispatch_rounds_per_s": 1.0 / dispatch_s,
            "fused_rounds_per_s": 1.0 / t_fused,
            "speedup": dispatch_s / t_fused,
            "dispatch_report": rep}


def bench_sparse(U: int = 4096, C: int = 64, rounds: int = 2,
                 model: str = "mlp", dataset: int = 2, seed: int = 0) -> dict:
    """Sparse-cohort slot-pool engine (``cohort_size=C``) vs the dense
    stacked engine at a population far beyond the dense working set: the
    dense round materializes and trains all ``(U, ...)`` rows while the
    sparse round touches only the C slots plus O(U) carry tables, so the
    round time should scale with C, not U (DESIGN.md "Sparse cohorts").
    Steady-state in-harness ``round_s``, first (compile-bearing) round
    dropped. Acceptance target: >= 5x at U=4096, C=64 on 2-core CI CPUs
    (the measured ratio is far larger; the bar only guards the scaling
    claim, not the constant)."""
    xc = ExperimentConfig(model=model, dataset=dataset, num_clients=U,
                          rounds=1 + rounds, capacity=(12, 24), arrivals=4,
                          batch=8, seed=seed, request_backend="stacked")
    hd = harness.run("osafl", xc, eval_samples=64)[1:]
    hs = harness.run(
        "osafl", dataclasses.replace(xc, cohort_size=C),
        eval_samples=64)[1:]
    dense_s = float(np.mean([h["round_s"] for h in hd]))
    sparse_s = float(np.mean([h["round_s"] for h in hs]))
    return {"U": U, "C": C, "rounds": rounds, "model": model,
            "dense_s": dense_s, "sparse_s": sparse_s,
            "speedup": dense_s / sparse_s}


def bench_hier(U: int = 256, K: int = 8, rounds: int = 5,
               seed: int = 0) -> dict:
    """Two-tier hierarchical aggregation (``core/hierarchy.py``, K edge
    clusters + PS combine with cluster-level scores) vs the flat scored
    round, server-side on a fixed update matrix at U = 256. The two-tier
    round runs the same O(U·N) scored reduction (in K blocks) plus an
    O(K·N) second stage, so its cost must stay within a small constant of
    the flat round — the gate guards against the per-block unroll
    regressing to K full-width passes. Acceptance (``--smoke``): hier
    <= 3x flat."""
    from repro.core.osafl import StackedOSAFLServer
    from repro.core.hierarchy import HierStackedOSAFLServer
    params = init_small(jax.random.PRNGKey(seed), "mlp")
    fl = FLConfig(num_clients=U, local_lr=0.1, global_lr=16.0)
    flat = StackedOSAFLServer(params, fl, U)
    hier = HierStackedOSAFLServer(
        params, dataclasses.replace(fl, num_clusters=K), U)
    d_new = jnp.asarray(np.random.default_rng(seed).normal(
        size=(U, flat.codec.n)).astype(np.float32))
    active = np.ones(U, bool)
    for srv in (flat, hier):                       # warm compile
        srv.round_stacked(d_new, active)
        jax.block_until_ready(srv.w)
    ts = {}
    for name, srv in (("flat", flat), ("hier", hier)):
        t0 = time.perf_counter()
        for _ in range(rounds):
            srv.round_stacked(d_new, active)
            jax.block_until_ready(srv.w)
        ts[name] = (time.perf_counter() - t0) / rounds
    return {"U": U, "K": K, "rounds": rounds, "flat_s": ts["flat"],
            "hier_s": ts["hier"], "ratio": ts["hier"] / ts["flat"]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("U", nargs="?", type=int, default=256)
    ap.add_argument("rounds", nargs="?", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI bench-gate mode: U=256, minimum rounds, all "
                         "speedup bars enforced (incl. >= 4x harness round)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the measurement dicts to PATH (CI artifact)")
    args = ap.parse_args()
    U, rounds = (256, 2) if args.smoke else (args.U, args.rounds)
    p = bench_pipeline(U, max(rounds, 3))
    print(f"U={U} online pipeline (arrivals+optimizer+OSAFL round): "
          f"loop {p['loop_s']*1e3:.0f} ms vs vectorized "
          f"{p['vec_s']*1e3:.1f} ms -> {p['speedup']:.1f}x")
    g = bench_request_gen(U, max(rounds, 5))
    print(f"U={U} request generation (one online round of samples): "
          f"python streams {g['python_s']*1e3:.1f} ms vs stacked Gumbel "
          f"{g['stacked_s']*1e3:.2f} ms -> {g['speedup']:.1f}x")
    h = bench_harness(U, rounds)
    rg = h["request_gen_s"]
    print(f"U={U} full harness round: loop {h['loop_s']*1e3:.0f} ms vs "
          f"vectorized {h['vec_s']*1e3:.1f} ms (python requests) / "
          f"{h['vec_stacked_req_s']*1e3:.1f} ms (stacked requests) "
          f"-> {h['speedup']:.1f}x")
    print(f"U={U} in-harness request_gen_s column: "
          f"python {rg['python']*1e3:.1f} ms, "
          f"stacked {rg['stacked']*1e3:.2f} ms per round")
    f = bench_fused(U, rounds, dispatch_s=h["vec_stacked_req_s"])
    rep = f["dispatch_report"]
    print(f"U={U} fused single-dispatch round "
          f"(k={f['rounds_per_dispatch']} rounds/dispatch): dispatch "
          f"{f['dispatch_s']*1e3:.1f} ms vs fused {f['fused_s']*1e3:.1f} ms "
          f"-> {f['speedup']:.1f}x ({f['fused_rounds_per_s']:.0f} rounds/s); "
          f"HLO: {rep['hlo_modules']} module / {rep['entry_computations']} "
          f"entry, single_dispatch={rep['single_dispatch']}")
    # overhead-dominated operating point: at a small cohort the per-round
    # dispatch + host-draw overhead (the thing fusing eliminates) IS the
    # round; 8 baseline rounds because 2 steady-state samples are too noisy
    # to gate on at ~30 ms/round
    fs = bench_fused(16, 8)
    reps = fs["dispatch_report"]
    print(f"U=16 fused single-dispatch round (overhead-dominated point): "
          f"dispatch {fs['dispatch_s']*1e3:.1f} ms vs fused "
          f"{fs['fused_s']*1e3:.1f} ms -> {fs['speedup']:.1f}x; "
          f"single_dispatch={reps['single_dispatch']}")
    # the scale point: the sparse slot-pool engine at a population the
    # dense engine can only crawl through (round time ~ C, not U)
    sp = bench_sparse()
    print(f"U={sp['U']} sparse cohort (C={sp['C']} slots): dense "
          f"{sp['dense_s']*1e3:.0f} ms vs sparse {sp['sparse_s']*1e3:.0f} ms "
          f"per round -> {sp['speedup']:.1f}x")
    hr = bench_hier(U, rounds=max(rounds, 5))
    print(f"U={hr['U']} two-tier aggregation (K={hr['K']} clusters): flat "
          f"{hr['flat_s']*1e3:.1f} ms vs hier {hr['hier_s']*1e3:.1f} ms "
          f"per round -> {hr['ratio']:.2f}x the flat cost")
    if args.json:
        Path(args.json).write_text(json.dumps(
            {"pipeline": p, "request_gen": g, "harness": h, "fused": f,
             "fused_small": fs, "sparse": sp, "hier": hr,
             "smoke": args.smoke},
            indent=2, default=float))
        print(f"wrote measurements -> {args.json}")
    if U < 256:                  # the acceptance bars are defined at U=256
        print("done (speedup bars only gated at U >= 256)")
    elif p["speedup"] < 10:
        raise SystemExit("FAIL: vectorized online pipeline speedup < 10x")
    elif g["speedup"] < 10:
        raise SystemExit("FAIL: stacked request generation speedup < 10x")
    elif args.smoke and h["speedup_stacked_req"] < 4:
        raise SystemExit("FAIL: end-to-end harness round speedup < 4x "
                         f"(got {h['speedup_stacked_req']:.1f}x)")
    elif args.smoke and not (rep["single_dispatch"]
                             and reps["single_dispatch"]):
        raise SystemExit("FAIL: fused segment did not compile to one "
                         f"executable (dispatch_report: U=256 {rep}, "
                         f"U=16 {reps})")
    elif args.smoke and f["speedup"] < 1:
        raise SystemExit("FAIL: fused round slower than multi-dispatch at "
                         f"U=256 (got {f['speedup']:.2f}x, need >= 1x; the "
                         "compute-bound point is a no-regression bar)")
    elif args.smoke and fs["speedup"] < 2:
        raise SystemExit("FAIL: fused round speedup < 2x vs multi-dispatch "
                         f"at the overhead-dominated U=16 point (got "
                         f"{fs['speedup']:.1f}x)")
    elif args.smoke and sp["speedup"] < 5:
        raise SystemExit("FAIL: sparse-cohort round speedup < 5x vs the "
                         f"dense engine at U={sp['U']}, C={sp['C']} (got "
                         f"{sp['speedup']:.1f}x; the round should scale "
                         "with the slot count, not the population)")
    elif args.smoke and hr["ratio"] > 3:
        raise SystemExit("FAIL: two-tier aggregation round costs more than "
                         f"3x the flat round at U={hr['U']}, K={hr['K']} "
                         f"(got {hr['ratio']:.2f}x; the per-cluster unroll "
                         "should add an O(K*N) second stage, not K "
                         "full-width passes)")
    else:
        print("PASS: pipeline >= 10x, request generation >= 10x"
              + (", harness round >= 4x, fused single-dispatch >= 1x "
                 "at U=256 and >= 2x at U=16, sparse cohort >= 5x "
                 "at U=4096, two-tier aggregation <= 3x flat at K=8"
                 if args.smoke else ""))


if __name__ == "__main__":
    main()
