"""Serving-path benchmark: hot-reload latency + checkpoint-writer overhead.

Two measurements against the streaming checkpoint layer
(``checkpoint/streaming.py``) and the train-while-serve server
(``launch/serve.py``):

  * reload: a short mlp run publishes real RunState snapshots; then (a)
    cold maps — a fresh ``ModelServer`` polls and maps the newest snapshot,
    timed end-to-end (scan + claim + load + unflatten + jit-bind) — and (b)
    hot swaps — a server already serving round k remaps when round k+1
    appears, the production reload. Medians over ``--trials`` fresh
    servers; per-reload staleness comes from the server's own reload log.
  * round_overhead: the same stacked-engine ``harness.run`` mlp run three
    ways — no checkpointing, ``checkpoint_async=True`` (the v2 background
    writer: submit = tree walk only) and ``checkpoint_async=False`` (the
    blocking v1 npz save on the round loop) — with ``save_every_k=1`` so
    every round pays the writer. Reported as steady-state mean ``round_s``
    (first, compile-bearing round dropped) and the per-round overhead each
    writer adds over the no-checkpoint baseline. The async overhead should
    be a small fraction of the blocking one; the numbers land in the CI
    artifact (serve-smoke lane) rather than behind a brittle wall-clock
    gate.

Usage: python benchmarks/bench_serve.py [--smoke] [--json PATH]
(runs from any CWD: the script shims repo root + ``src/`` onto sys.path)
"""
from __future__ import annotations

import argparse
import json
import shutil
import statistics
import sys
import tempfile
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np

from repro import harness
from repro.harness import ExperimentConfig, checkpoint_path
from repro.launch.serve import ModelServer, make_request_batch


def _bench_cfg(rounds: int) -> ExperimentConfig:
    return ExperimentConfig(model="mlp", dataset=2, num_clients=32,
                            rounds=rounds, capacity=(12, 24), arrivals=4,
                            batch=8, seed=7)


def _steady_round_s(history) -> float:
    """Mean round_s with the first (compile-bearing) round dropped."""
    rs = [h["round_s"] for h in history[1:]] or \
        [h["round_s"] for h in history]
    return float(statistics.fmean(rs))


def bench_reload(workdir: Path, rounds: int, trials: int) -> dict:
    """Cold-map and hot-swap reload latency over real snapshots."""
    src = workdir / "train"
    harness.run("osafl", _bench_cfg(rounds), eval_samples=32,
                save_every_k=1, checkpoint_dir=src)
    snaps = sorted(p for p in src.iterdir() if p.is_dir())
    assert len(snaps) >= 2, snaps
    cold, swap, behind = [], [], []
    for trial in range(trials):
        serve_dir = workdir / f"serve{trial}"
        shutil.copytree(src / snaps[-2].name,
                        serve_dir / snaps[-2].name)
        with ModelServer(serve_dir) as server:
            assert server.poll(), "cold map did not happen"
            # pin + score once so the jitted forward is compiled before the
            # hot swap is timed (a production server is warm)
            server.score(make_request_batch(
                np.random.default_rng(0), 8, 2))
            shutil.copytree(src / snaps[-1].name,
                            serve_dir / snaps[-1].name)
            assert server.poll(), "hot swap did not happen"
            log = server.stats()["reloads"]
        cold.append(log[0]["reload_s"])
        swap.append(log[1]["reload_s"])
        behind.append(log[1]["behind"])
        shutil.rmtree(serve_dir)
    return {"trials": trials,
            "cold_map_s": float(statistics.median(cold)),
            "hot_swap_s": float(statistics.median(swap)),
            "behind_at_swap": behind}


def bench_round_overhead(workdir: Path, rounds: int) -> dict:
    """Steady-state round time without checkpoints vs the async v2 writer
    vs the blocking v1 save, save_every_k=1."""
    xc = _bench_cfg(rounds)
    out = {}
    for mode, kw in (
            ("none", {}),
            ("async_v2", {"save_every_k": 1,
                          "checkpoint_dir": workdir / "async",
                          "checkpoint_async": True}),
            ("blocking_v1", {"save_every_k": 1,
                             "checkpoint_dir": workdir / "blocking",
                             "checkpoint_async": False})):
        t0 = time.perf_counter()
        hist = harness.run("osafl", xc, eval_samples=32, **kw)
        out[mode] = {"round_s": _steady_round_s(hist),
                     "total_s": time.perf_counter() - t0}
    base = out["none"]["round_s"]
    for mode in ("async_v2", "blocking_v1"):
        out[mode]["overhead_s_per_round"] = out[mode]["round_s"] - base
    # sanity: both checkpointed runs actually published their snapshots
    for mode in ("async", "blocking"):
        final = checkpoint_path(workdir / mode, rounds)
        assert final.exists() or final.with_suffix(".npz").exists(), final
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Benchmark serving hot-reload latency and the round-"
        "loop overhead of async (v2) vs blocking (v1) checkpointing.")
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: fewer rounds/trials")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--trials", type=int, default=None)
    ap.add_argument("--json", type=Path, default=None,
                    help="write the measurement dict to this path")
    args = ap.parse_args(argv)
    rounds = args.rounds or (4 if args.smoke else 10)
    trials = args.trials or (3 if args.smoke else 5)

    results = {"schema": "bench_serve/v1", "rounds": rounds}
    with tempfile.TemporaryDirectory(ignore_cleanup_errors=True) as td:
        td = Path(td)
        results["reload"] = bench_reload(td / "reload", rounds, trials)
        results["round_overhead"] = bench_round_overhead(td / "ovh", rounds)

    rel = results["reload"]
    print(f"reload: cold map {rel['cold_map_s'] * 1e3:.1f} ms, "
          f"hot swap {rel['hot_swap_s'] * 1e3:.1f} ms "
          f"(median of {rel['trials']})")
    ovh = results["round_overhead"]
    print(f"round: none {ovh['none']['round_s'] * 1e3:.1f} ms, "
          f"async v2 +{ovh['async_v2']['overhead_s_per_round'] * 1e3:.1f} "
          f"ms, blocking v1 "
          f"+{ovh['blocking_v1']['overhead_s_per_round'] * 1e3:.1f} ms")
    if args.json:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(results, indent=2))
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
