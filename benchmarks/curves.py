"""Reproduced-curve JSON artifacts (library — not a benchmark entry point).

Every ported figure/table script (`fig1`/`fig2`/`fig3`/`table2`/`table4`)
records its reproduced trajectories as one curve document: a plain-JSON dict
with a pinned ``schema`` tag, the run configuration, a list of named curves
(per-round metric series of equal length), and a flat scalar ``summary``
(the table cells / single-number figure metrics). The documents are fully
deterministic in the run seed — no timestamps, no wall-clock fields — so
``tests/golden/`` can pin them and ``tools/gen_golden.py`` can regenerate
them byte-comparably.

Layout (``SCHEMA = "osafl-curves/v1"``)::

    {"schema": "osafl-curves/v1",
     "name": "fig1_static_vs_timevarying",
     "preset": "smoke",
     "config": {...},                      # plain-JSON run shape
     "curves": [
        {"name": "timevarying", "algorithm": "osafl", "scenario": "",
         "round": [0, 1, ...], "test_loss": [...], "test_acc": [...],
         "participants": [...]},
        ...],
     "summary": {"fig1_timevarying_final_acc": 0.61, ...}}

``validate_doc`` is the well-formedness contract the CLI tests assert on
(`tests/test_benchmarks_cli.py`): schema tag, curve-key completeness,
equal series lengths, and finite metric values.
"""
from __future__ import annotations

import json
import math
from pathlib import Path

SCHEMA = "osafl-curves/v1"

# every curve carries these series, all of equal length
_SERIES = ("round", "test_loss", "test_acc", "participants")
_INT_SERIES = ("round", "participants")


def curve_from_history(name: str, history, algorithm: str = "",
                       scenario: str = "") -> dict:
    """One named curve from a harness history (list of per-round dicts).
    Wall-clock fields (``round_s``, ``request_gen_s``) are dropped — curve
    docs are deterministic in the seed."""
    return {
        "name": str(name),
        "algorithm": str(algorithm),
        "scenario": str(scenario),
        "round": [int(h["round"]) for h in history],
        "test_loss": [float(h["test_loss"]) for h in history],
        "test_acc": [float(h["test_acc"]) for h in history],
        "participants": [int(h.get("participants", 0)) for h in history],
    }


def series_curve(name: str, series: dict, algorithm: str = "",
                 scenario: str = "") -> dict:
    """A curve from raw per-round series (for scripts whose metric is not a
    harness history — fig2's drift shares, fig3's straggler fractions).
    ``series`` maps a subset of {test_loss, test_acc, participants} plus any
    extra float series; ``round`` is derived from the longest series."""
    n = max(len(v) for v in series.values())
    curve = {"name": str(name), "algorithm": str(algorithm),
             "scenario": str(scenario), "round": list(range(n))}
    for k in ("test_loss", "test_acc"):
        curve[k] = [float(v) for v in series.get(k, [0.0] * n)]
    curve["participants"] = [int(v)
                             for v in series.get("participants", [0] * n)]
    for k, v in series.items():
        if k not in _SERIES:
            curve[k] = [float(x) for x in v]
    return curve


def make_doc(name: str, preset: str, config: dict, curves: list,
             summary: dict) -> dict:
    # round-trip config through JSON so an in-memory doc compares equal to
    # its loaded pin (tuples -> lists, numpy scalars -> python numbers)
    doc = {"schema": SCHEMA, "name": str(name), "preset": str(preset),
           "config": json.loads(json.dumps(dict(config), default=float)),
           "curves": list(curves),
           "summary": {k: float(v) for k, v in summary.items()}}
    validate_doc(doc)
    return doc


def validate_doc(doc: dict) -> dict:
    """Raise ValueError unless ``doc`` is a well-formed curve document;
    returns the doc. This is the contract the CLI subprocess tests and the
    golden layer assert on."""
    if not isinstance(doc, dict):
        raise ValueError(f"curve doc must be a dict, got {type(doc)}")
    if doc.get("schema") != SCHEMA:
        raise ValueError(f"bad schema tag {doc.get('schema')!r} "
                         f"(expected {SCHEMA!r})")
    for key in ("name", "preset", "config", "curves", "summary"):
        if key not in doc:
            raise ValueError(f"curve doc missing key {key!r}")
    if not isinstance(doc["curves"], list) or not doc["curves"]:
        raise ValueError("curve doc needs a non-empty 'curves' list")
    for c in doc["curves"]:
        for key in ("name", "algorithm", "scenario") + _SERIES:
            if key not in c:
                raise ValueError(
                    f"curve {c.get('name', '?')!r} missing key {key!r}")
        lengths = {k: len(c[k]) for k in c
                   if isinstance(c[k], list)}
        if len(set(lengths.values())) != 1:
            raise ValueError(
                f"curve {c['name']!r} has unequal series lengths {lengths}")
        for k, v in c.items():
            if not isinstance(v, list):
                continue
            if any(isinstance(x, float) and not math.isfinite(x)
                   for x in v):
                raise ValueError(
                    f"curve {c['name']!r} series {k!r} has non-finite values")
    for k, v in doc["summary"].items():
        if not math.isfinite(float(v)):
            raise ValueError(f"summary metric {k!r} is non-finite ({v})")
    return doc


def write_doc(path, doc: dict) -> None:
    validate_doc(doc)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")


def load_doc(path) -> dict:
    return validate_doc(json.loads(Path(path).read_text()))


def summary_rows(doc: dict) -> list:
    """The legacy ``(key, value)`` CSV rows every script's ``__main__``
    prints, derived from the doc's summary (sorted for determinism)."""
    return sorted(doc["summary"].items())


def add_cli_args(parser, presets=("smoke", "paper")) -> None:
    """The shared figure/table CLI surface: ``--preset``, ``--out``,
    ``--scenario`` (an overlay composed onto whatever scenario the script
    itself uses), ``--seed``."""
    parser.add_argument("--preset", choices=presets, default="smoke",
                        help="run shape: smoke (seconds, CI scale) or paper "
                             "(EXPERIMENTS.md paper-scale recipe)")
    parser.add_argument("--out", default=None, metavar="PATH",
                        help="write the reproduced-curve JSON document here")
    parser.add_argument("--scenario", default="",
                        help="scenario overlay spec, composed (+) onto each "
                             "run's own scenario (src/repro/scenarios/)")
    parser.add_argument("--seed", type=int, default=0)


def compose_specs(*specs: str) -> str:
    """Compose scenario spec strings with ``+``, dropping empties; "null"
    terms are absorbed (null is the identity of composition)."""
    terms = [s for s in specs if s and s != "null"]
    if not terms:
        return "null" if any(s == "null" for s in specs) else ""
    return "+".join(terms)


def finish(doc: dict, out) -> dict:
    """Common ``run()`` tail: validate, optionally write, return the doc."""
    validate_doc(doc)
    if out:
        write_doc(out, doc)
    return doc
