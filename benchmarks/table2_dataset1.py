"""Paper Tables II-III / Figs. 4-5: test accuracy/loss of OSAFL vs the five
modified baselines (+ centralized Genie) on video-caching Dataset-1.
Reduced scale: FCN + CNN models, fewer clients/rounds (EXPERIMENTS.md)."""
from __future__ import annotations

import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np

from benchmarks.common import (ALL_ALGS, ExperimentConfig,
                               run_centralized_sgd, run_experiment)


def run(models=("fcn",), topks=(1, 2), rounds=25, num_clients=12, seed=0):
    t0 = time.time()
    rows = []
    summary = {}
    for model in models:
        for k in topks:
            xc = ExperimentConfig(model=model, dataset=1, rounds=rounds,
                                  num_clients=num_clients, topk=k, seed=seed)
            cen = run_centralized_sgd(xc)
            best = max(h["test_acc"] for h in cen)
            rows.append((f"table2_{model}_K{k}_central_acc", best))
            for alg in ALL_ALGS:
                hist = run_experiment(alg, xc)
                accs = [h["test_acc"] for h in hist]
                losses = [h["test_loss"] for h in hist]
                i = int(np.argmax(accs))
                rows.append((f"table2_{model}_K{k}_{alg}_acc", accs[i]))
                rows.append((f"table2_{model}_K{k}_{alg}_loss", losses[i]))
                summary[(model, k, alg)] = (accs[i], losses[i])
    return rows, time.time() - t0, summary


if __name__ == "__main__":
    import argparse
    argparse.ArgumentParser(description=__doc__.splitlines()[0]).parse_args()
    rows, dt, _ = run()
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
