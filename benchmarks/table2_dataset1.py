"""Paper Tables II-III / Figs. 4-5: test accuracy/loss of OSAFL vs the five
modified baselines (+ centralized Genie) on video-caching Dataset-1.
Reproduced on the stacked engine: every algorithm runs the full online
wireless setting under ``repro.harness.run`` (one vmapped cohort,
batched FIFO arrivals, joint resource solve), optionally under a scenario
overlay (``--scenario``, src/repro/scenarios/). ``--preset paper`` runs
the EXPERIMENTS.md Dataset-1 paper-scale shape; the smoke preset keeps CI
to seconds."""
from __future__ import annotations

import dataclasses
import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np

from benchmarks import curves
from repro import harness
from repro.harness import ALL_ALGS, ExperimentConfig

PRESETS = {
    "smoke": dict(models=("fcn",), topks=(1,), rounds=6, num_clients=8),
    # EXPERIMENTS.md Dataset-1 paper scale: U=256 with the CPU-safe
    # capacity band (Dataset-1's 3168-dim features at D_u=640 need ~2 GB)
    "paper": dict(models=("fcn", "cnn"), topks=(1, 2), rounds=100,
                  num_clients=256, capacity=(80, 160),
                  request_backend="stacked"),
}


def run(preset="smoke", seed=0, scenario="", out=None):
    t0 = time.time()
    cfg = dict(PRESETS[preset])
    models, topks = cfg.pop("models"), cfg.pop("topks")
    spec = curves.compose_specs(scenario)
    curve_list, summary = [], {}
    legacy = {}
    for model in models:
        for k in topks:
            xc = ExperimentConfig(model=model, dataset=1, topk=k, seed=seed,
                                  scenario=spec, **cfg)
            # the Genie pools every client's stream centrally: it has no
            # wireless world for a scenario to perturb, so it is only run
            # for the unperturbed table column
            if not spec or spec == "null":
                cen = harness.run(
                    "centralized", dataclasses.replace(xc, scenario=""))
                summary[f"table2_{model}_K{k}_central_acc"] = \
                    max(h["test_acc"] for h in cen)
                curve_list.append(curves.curve_from_history(
                    f"{model}_K{k}_central", cen, algorithm="central"))
            for alg in ALL_ALGS:
                hist = harness.run(alg, xc)
                accs = [h["test_acc"] for h in hist]
                losses = [h["test_loss"] for h in hist]
                i = int(np.argmax(accs))
                summary[f"table2_{model}_K{k}_{alg}_acc"] = accs[i]
                summary[f"table2_{model}_K{k}_{alg}_loss"] = losses[i]
                legacy[(model, k, alg)] = (accs[i], losses[i])
                curve_list.append(curves.curve_from_history(
                    f"{model}_K{k}_{alg}", hist, algorithm=alg,
                    scenario=spec))
    doc = curves.make_doc(
        "table2_dataset1", preset,
        dict(cfg, models=list(models), topks=list(topks), seed=seed,
             scenario=scenario),
        curve_list, summary)
    curves.finish(doc, out)
    return curves.summary_rows(doc), time.time() - t0, doc, legacy


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    curves.add_cli_args(p)
    a = p.parse_args()
    rows, dt, _, _ = run(preset=a.preset, seed=a.seed, scenario=a.scenario,
                         out=a.out)
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
