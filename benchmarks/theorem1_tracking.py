"""Theorem 1 empirical tracking: run OSAFL on the paper's task, estimate the
assumption constants (beta from gradient Lipschitz probes, sigma^2 from
minibatch gradient variance), evaluate the eq. 24 bracket per round, and
check that the measured average squared global-gradient norm respects the
bound. This connects the convergence calculator (core/convergence.py) to a
real training trajectory."""
from __future__ import annotations

import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.buffer import OnlineBuffer, binomial_arrivals
from repro.core.client import local_train
from repro.core.convergence import BoundHypers, lr_condition, round_bound
from repro.core.osafl import ClientUpdate, OSAFLServer
from repro.core.scores import tree_dot, tree_norm, tree_scale, tree_sub
from repro.data.video_caching import D1_DIM, make_population
from repro.models.small import init_small, small_loss


def _estimate_beta(grad_fn, params, batch, key, probes=4, eps=1e-3):
    """beta ~ max ||g(w+d) - g(w)|| / ||d|| over random directions."""
    g0 = grad_fn(params, batch)
    best = 0.0
    for i in range(probes):
        key, k = jax.random.split(key)
        leaves, tdef = jax.tree_util.tree_flatten(params)
        ks = jax.random.split(k, len(leaves))
        d = jax.tree_util.tree_unflatten(
            tdef, [eps * jax.random.normal(kk, l.shape)
                   for kk, l in zip(ks, leaves)])
        g1 = grad_fn(jax.tree.map(lambda a, b: a + b, params, d), batch)
        num = float(tree_norm(tree_sub(g1, g0)))
        den = float(tree_norm(d))
        best = max(best, num / max(den, 1e-12))
    return best, key


def run(rounds=10, num_clients=6, seed=0):
    t0 = time.time()
    cat, streams = make_population(seed, num_clients)
    rng = np.random.default_rng(seed)
    bufs = []
    for s in streams:
        buf = OnlineBuffer.create(80, (D1_DIM,), 100)
        x, y = s.draw_dataset1(80)
        buf.stage(x, y)
        buf.commit()
        bufs.append(buf)
    fl = FLConfig(num_clients=num_clients, local_lr=0.02, global_lr=1.0)
    params = init_small(jax.random.PRNGKey(seed), "fcn")
    server = OSAFLServer(params, fl, num_clients)
    grad_fn = jax.jit(jax.grad(lambda p, b: small_loss(p, b, "fcn")[0]))
    key = jax.random.PRNGKey(seed + 1)

    def pooled_batch():
        xs, ys = zip(*[b.dataset() for b in bufs])
        return {"x": jnp.asarray(np.concatenate(xs)),
                "y": jnp.asarray(np.concatenate(ys))}

    # assumption constants on the initial state
    batch0 = pooled_batch()
    beta, key = _estimate_beta(grad_fn, params, batch0, key)
    gfull = grad_fn(params, batch0)
    sub_gs = []
    for _ in range(6):
        idx = rng.integers(0, len(batch0["y"]), 32)
        gb = grad_fn(params, {"x": batch0["x"][idx], "y": batch0["y"][idx]})
        sub_gs.append(float(tree_norm(tree_sub(gb, gfull))) ** 2)
    sigma2 = float(np.mean(sub_gs))
    h = BoundHypers(beta=beta, sigma2=sigma2, rho1=1.0, rho2=0.0,
                    eta=fl.local_lr, eta_g=fl.global_lr)

    grad_norms, brackets = [], []
    prev_loss = float(small_loss(params, batch0, "fcn")[0])
    alpha = np.full(num_clients, 1.0 / num_clients)
    for t in range(rounds):
        updates, kappas, phis = [], [], []
        for c, s in enumerate(streams):
            n = binomial_arrivals(rng, 6, s.user.p_ac)
            if n:
                x, y = s.draw_dataset1(n)
                bufs[c].stage(x, y)
            bufs[c].commit()
            phis.append(bufs[c].distribution_shift())
            kappa = int(rng.integers(1, 5))
            kappas.append(kappa)
            d, _ = local_train(server.params, grad_fn, bufs[c], kappa,
                               fl.local_lr, 16, rng)
            updates.append(ClientUpdate(c, d, kappa))
        server.round(updates)
        batch = pooled_batch()
        g = grad_fn(server.params, batch)
        grad_norms.append(float(tree_norm(g)) ** 2)
        loss = float(small_loss(server.params, batch, "fcn")[0])
        lam = server.last_scores
        brackets.append(round_bound(
            h, prev_loss, loss, alpha, np.array(kappas, float), lam, lam,
            np.array(phis), np.zeros(num_clients)))
        prev_loss = loss

    avg_grad = float(np.mean(grad_norms))
    avg_bound = float(np.mean([b["total"] for b in brackets]))
    rows = [
        ("theorem1_beta_hat", beta),
        ("theorem1_sigma2_hat", sigma2),
        ("theorem1_lr_condition_ok", float(lr_condition(h, 5))),
        ("theorem1_avg_sq_grad_norm", avg_grad),
        ("theorem1_avg_bound_rhs", avg_bound),
        ("theorem1_bound_holds", float(avg_grad <= avg_bound)),
    ]
    return rows, time.time() - t0


if __name__ == "__main__":
    import argparse
    argparse.ArgumentParser(description=__doc__.splitlines()[0]).parse_args()
    rows, dt = run()
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
