"""Golden-curve run registry (library — not a benchmark entry point).

``GOLDEN_RUNS`` names every pinned reproduction: one smoke-preset,
seed-0 run per ported figure/table script. ``tools/gen_golden.py``
regenerates the pinned documents under ``tests/golden/`` and
``tests/test_scenarios_golden.py`` re-runs each definition and compares
against the pin with tolerances (float series loosely, integer series —
rounds, participants — exactly). Regenerate after any intentional
trajectory change:

    PYTHONPATH=src:. python tools/gen_golden.py            # all
    PYTHONPATH=src:. python tools/gen_golden.py fig1 fig3  # a subset
"""
from __future__ import annotations

import sys
from pathlib import Path

if __package__ in (None, ""):    # imported by path from tools/gen_golden.py
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

GOLDEN_DIR = Path(__file__).resolve().parent.parent / "tests" / "golden"

# heavy runs (LSTM / full-algorithm sweeps, ~1 min each) carry the ``slow``
# pytest marker in tests/test_scenarios_golden.py; the rest run in tier 1
SLOW = ("table2", "table4")


def _fig1():
    from benchmarks import fig1_static_vs_timevarying
    return fig1_static_vs_timevarying.run(preset="smoke", seed=0)[2]


def _fig2():
    from benchmarks import fig2_label_drift
    return fig2_label_drift.run(preset="smoke", seed=0)[2]


def _fig3():
    from benchmarks import fig3_stragglers
    return fig3_stragglers.run(preset="smoke", seed=0)[2]


def _table2():
    from benchmarks import table2_dataset1
    return table2_dataset1.run(preset="smoke", seed=0)[2]


def _table4():
    from benchmarks import table4_dataset2
    return table4_dataset2.run(preset="smoke", seed=0)[2]


GOLDEN_RUNS = {
    "fig1": _fig1,
    "fig2": _fig2,
    "fig3": _fig3,
    "table2": _table2,
    "table4": _table4,
}


def golden_path(name: str) -> Path:
    return GOLDEN_DIR / f"{name}_smoke.json"


def generate(names=None, out_dir: Path = None) -> list:
    """Regenerate the pinned docs; returns the written paths."""
    from benchmarks import curves
    out_dir = Path(out_dir) if out_dir else GOLDEN_DIR
    written = []
    for name in names or sorted(GOLDEN_RUNS):
        if name not in GOLDEN_RUNS:
            raise SystemExit(f"unknown golden run {name!r} "
                             f"(expected one of {sorted(GOLDEN_RUNS)})")
        doc = GOLDEN_RUNS[name]()
        path = out_dir / f"{name}_smoke.json"
        curves.write_doc(path, doc)
        written.append(path)
    return written
