"""Wall-clock benchmark: loop ``OSAFLServer.round`` vs the stacked engine.

One synthetic OSAFL server round over U clients (default 256): the loop path
scores and aggregates per-client pytrees with O(U) Python tree traversals;
the stacked path runs the identical math as one jitted update over a (U, N)
buffer with fused-Pallas scoring. Acceptance target for the stacked engine is
a >= 10x round-time speedup at U = 256.

Usage: python benchmarks/bench_stacked.py [U] [rounds]
(runs from any CWD: the script shims repo root + ``src/`` onto sys.path)
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.osafl import ClientUpdate, OSAFLServer, StackedOSAFLServer


def synth_params(key):
    """~21k-parameter two-layer pytree, the size class of the `mlp` scale
    model the vectorized cohort harness trains (per-leaf shapes exercise the
    codec). The loop path's cost is per-client Python dispatch, so its
    round time barely depends on N; the stacked path is bandwidth-bound."""
    ks = jax.random.split(key, 4)
    return {"w1": jax.random.normal(ks[0], (128, 128)) * 0.1,
            "b1": jnp.zeros((128,)),
            "w2": jax.random.normal(ks[1], (128, 32)) * 0.1,
            "b2": jnp.zeros((32,))}


def bench(U: int = 256, rounds: int = 3, seed: int = 0) -> dict:
    params = synth_params(jax.random.PRNGKey(seed))
    fl = FLConfig(num_clients=U, local_lr=0.1, global_lr=2.0)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), U)
    updates = [ClientUpdate(u, jax.tree.map(
        lambda p, k=k: jax.random.normal(k, p.shape), params), kappa=1)
        for u, k in enumerate(keys)]
    jax.block_until_ready(jax.tree.leaves([u.d for u in updates]))

    loop = OSAFLServer(params, fl, U)
    loop.round(updates)                           # warm dispatch caches
    t0 = time.perf_counter()
    for _ in range(rounds):
        loop.round(updates)
    jax.block_until_ready(jax.tree.leaves(loop.params))
    t_loop = (time.perf_counter() - t0) / rounds

    stacked = StackedOSAFLServer(params, fl, U)
    d_new = stacked.codec.flatten_stacked(
        jax.tree.map(lambda *xs: jnp.stack(xs), *[u.d for u in updates]))
    active = np.ones(U, bool)
    stacked.round_stacked(d_new, active)          # warm-up / compile
    jax.block_until_ready(stacked.w)
    t0 = time.perf_counter()
    for _ in range(rounds):
        stacked.round_stacked(d_new, active)
    # sync every async output of the round (weights AND the contribution
    # buffer) inside the perf window
    jax.block_until_ready((stacked.w, stacked.d_buffer))
    t_stacked = (time.perf_counter() - t0) / rounds

    # the two engines must agree before a speedup means anything
    drift = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(loop.params), jax.tree.leaves(stacked.params)))
    return {"U": U, "n_params": stacked.codec.n, "loop_s": t_loop,
            "stacked_s": t_stacked, "speedup": t_loop / t_stacked,
            "max_param_drift": drift}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("U", nargs="?", type=int, default=256)
    ap.add_argument("rounds", nargs="?", type=int, default=3)
    args = ap.parse_args()
    r = bench(args.U, args.rounds)
    print(f"U={r['U']} N={r['n_params']}: loop {r['loop_s']*1e3:.1f} ms/round"
          f" vs stacked {r['stacked_s']*1e3:.2f} ms/round"
          f" -> {r['speedup']:.1f}x (param drift {r['max_param_drift']:.2e})")
    if r["speedup"] < 10:
        raise SystemExit("FAIL: stacked engine speedup < 10x")
    print("PASS: >= 10x")
