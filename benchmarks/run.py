"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Reduced scales for CPU are
documented in EXPERIMENTS.md (the mechanisms are the paper's, the scale is
not). The roofline rows require dry-run artifacts in experiments/dryrun/.

Usage: python benchmarks/run.py
(runs from any CWD: the script shims repo root + ``src/`` onto sys.path,
so ``from benchmarks import ...`` resolves without PYTHONPATH juggling)
"""
from __future__ import annotations

import argparse
import sys
import traceback
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/run.py
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

from benchmarks import (ablation_scores, fig1_static_vs_timevarying,
                        fig2_label_drift, fig3_stragglers, roofline,
                        table2_dataset1, table4_dataset2, theorem1_tracking)


def main() -> None:
    argparse.ArgumentParser(description=__doc__.splitlines()[0]).parse_args()
    suites = [
        # the figure/table reproductions return (rows, dt, doc[, ...]) —
        # the curve JSON doc rides along for --out users (benchmarks/curves.py)
        ("fig2_label_drift", lambda: fig2_label_drift.run()[:2]),
        ("fig3_stragglers", lambda: fig3_stragglers.run()[:2]),
        ("fig1_static_vs_timevarying",
         lambda: fig1_static_vs_timevarying.run()[:2]),
        ("table2_dataset1", lambda: table2_dataset1.run()[:2]),
        ("table4_dataset2", lambda: table4_dataset2.run()[:2]),
        ("ablation_scores", lambda: ablation_scores.run()),
        ("theorem1_tracking", lambda: theorem1_tracking.run()),
        ("roofline", lambda: roofline.run()),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            rows, dt = fn()
            us = dt * 1e6 / max(len(rows), 1)
            for k, v in rows:
                print(f"{k},{us:.0f},{v:.6f}")
            sys.stdout.flush()
        except Exception:
            failures += 1
            print(f"{name},0,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
