"""Roofline report: aggregates the dry-run artifacts (experiments/dryrun/*.json)
into the per-(arch x shape x mesh) table of EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import time
from pathlib import Path

DEFAULT_DIR = Path("experiments/dryrun")


def load_records(dirpath=DEFAULT_DIR):
    recs = []
    for f in sorted(Path(dirpath).glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def table(recs) -> str:
    hdr = ("| arch | shape | mesh | engine | compute_s | memory_s | "
           "collective_s | dominant | useful_flops | peak GB/dev |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in recs:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | "
                         f"SKIP | - | - |")
            continue
        rl = r["roofline"]
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        peak_gb = r["per_device"]["memory"]["peak_bytes"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {r['engine']} | "
            f"{rl['compute_s']:.4f} | {rl['memory_s']:.4f} | "
            f"{rl['collective_s']:.4f} | {rl['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.3f} | {peak_gb:.1f} |")
    return "\n".join(lines)


def run(dirpath=DEFAULT_DIR):
    t0 = time.time()
    recs = load_records(dirpath)
    rows = []
    for r in recs:
        if "skipped" in r:
            continue
        key = f"roofline_{r['arch']}_{r['shape']}"
        if r.get("multi_pod"):
            key += "_multipod"
        rows.append((key + "_bound_s",
                     r["roofline"]["step_time_lower_bound_s"]))
    return rows, time.time() - t0


if __name__ == "__main__":
    import argparse
    argparse.ArgumentParser(description=__doc__.splitlines()[0]).parse_args()
    recs = load_records()
    print(table(recs))
