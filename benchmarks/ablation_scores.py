"""Ablation: OSAFL score variants (exact / sketched / stale) on the paper's
Dataset-1 FCN task — validates that the §Perf systems optimizations (count-
sketch scores, one-round-stale scores) do not degrade task accuracy."""
from __future__ import annotations

import dataclasses
import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np

from repro.harness import ExperimentConfig
from repro.configs.base import FLConfig


def run(rounds=15, num_clients=8, seed=0):
    t0 = time.time()
    rows = []
    xc = ExperimentConfig(model="fcn", dataset=1, rounds=rounds,
                          num_clients=num_clients, seed=seed)
    variants = {
        "exact": {},
        "sketch256": {"score_sketch_dim": 256},
        "stale": {"stale_scores": True},
        "stale_sketch256": {"stale_scores": True, "score_sketch_dim": 256},
    }
    finals = {}
    for name, kw in variants.items():
        hist, params = _run_variant(xc, kw)
        finals[name] = params
        accs = [h["test_acc"] for h in hist]
        rows.append((f"ablation_osafl_{name}_best_acc", max(accs)))
        rows.append((f"ablation_osafl_{name}_final_acc", accs[-1]))
    # parameter-space divergence vs exact: proves the variants differ while
    # task accuracy stays equivalent
    import jax
    import numpy as np
    ref = finals["exact"]
    for name, p in finals.items():
        if name == "exact":
            continue
        num = sum(float(np.linalg.norm(np.asarray(a - b)))
                  for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(ref)))
        den = sum(float(np.linalg.norm(np.asarray(a)))
                  for a in jax.tree.leaves(ref))
        rows.append((f"ablation_osafl_{name}_rel_param_dist", num / den))
    return rows, time.time() - t0


def _run_variant(xc, fl_overrides):
    import jax
    import jax.numpy as jnp
    from repro.harness.experiments import MODEL_PARAMS, _draw
    from repro.core.baselines import make_server
    from repro.core.buffer import OnlineBuffer, binomial_arrivals
    from repro.core.client import local_train
    from repro.core.osafl import ClientUpdate
    from repro.data.video_caching import D1_DIM, make_population
    from repro.models.small import init_small, small_loss

    cat, streams = make_population(xc.seed, xc.num_clients, topk=xc.topk)
    rng = np.random.default_rng(xc.seed)
    bufs = []
    for s in streams:
        cap = int(rng.integers(*xc.capacity))
        buf = OnlineBuffer.create(cap, (D1_DIM,), 100)
        x, y = s.draw_dataset1(cap)
        buf.stage(x, y)
        buf.commit()
        bufs.append(buf)
    tests = [s.draw_dataset1(50) for s in streams]
    tx = np.concatenate([t_[0] for t_ in tests])
    ty = np.concatenate([t_[1] for t_ in tests])
    test_batch = {"x": jnp.asarray(tx), "y": jnp.asarray(ty)}
    grad_fn = jax.grad(lambda p, b: small_loss(p, b, xc.model)[0])
    params = init_small(jax.random.PRNGKey(xc.seed), xc.model)
    fl = FLConfig(num_clients=xc.num_clients, local_lr=xc.local_lr,
                  global_lr=xc.global_lr, algorithm="osafl", **fl_overrides)
    server = make_server(params, fl, xc.num_clients, seed=xc.seed)
    history = []
    for t in range(xc.rounds):
        updates = []
        for c, s in enumerate(streams):
            n = binomial_arrivals(rng, xc.arrivals, s.user.p_ac)
            if n:
                x, y = s.draw_dataset1(n)
                bufs[c].stage(x, y)
            bufs[c].commit()
            kappa = int(rng.integers(1, 5))
            d, _ = local_train(server.params, grad_fn, bufs[c], kappa,
                               fl.local_lr, xc.batch, rng)
            updates.append(ClientUpdate(c, d, kappa, data_size=bufs[c].size))
        server.round(updates)
        from repro.models.small import small_loss as sl
        loss, m = sl(server.params, test_batch, xc.model)
        history.append({"round": t, "test_loss": float(loss),
                        "test_acc": float(m["accuracy"])})
    return history, server.params


if __name__ == "__main__":
    import argparse
    argparse.ArgumentParser(description=__doc__.splitlines()[0]).parse_args()
    rows, dt = run()
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
