"""Paper Tables IV-V / Fig. 6: Dataset-2 (pure time-series of content IDs)
with the LSTM model: OSAFL vs modified baselines + centralized Genie.
Reproduced on the stacked engine: every algorithm runs the full online
wireless setting under ``repro.harness.run``; ``--preset paper``
is exactly the EXPERIMENTS.md paper-scale recipe (LSTM / Dataset-2 /
U=256 / T=100 / D_u in [320, 640] / stacked request backend), and
``--scenario`` overlays a wireless-world perturbation
(src/repro/scenarios/)."""
from __future__ import annotations

import dataclasses
import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np

from benchmarks import curves
from repro import harness
from repro.harness import ALL_ALGS, ExperimentConfig

PRESETS = {
    "smoke": dict(model="lstm", topks=(1,), rounds=6, num_clients=8,
                  local_lr=0.2, global_lr=16.0),
    # EXPERIMENTS.md paper-scale recipe (T=100+, D_u=320-640, U=256)
    "paper": dict(model="lstm", topks=(1, 2), rounds=100, num_clients=256,
                  capacity=(320, 640), arrivals=8,
                  local_lr=0.2, global_lr=20.0,
                  request_backend="stacked"),
}


def run(preset="smoke", seed=0, scenario="", out=None):
    t0 = time.time()
    cfg = dict(PRESETS[preset])
    topks = cfg.pop("topks")
    spec = curves.compose_specs(scenario)
    curve_list, summary = [], {}
    for k in topks:
        xc = ExperimentConfig(dataset=2, topk=k, seed=seed, scenario=spec,
                              **cfg)
        # the Genie has no wireless world for a scenario to perturb — only
        # run it for the unperturbed table column (python streams only)
        if not spec or spec == "null":
            cen = harness.run("centralized", dataclasses.replace(
                xc, scenario="", request_backend="python"))
            summary[f"table4_K{k}_central_acc"] = \
                max(h["test_acc"] for h in cen)
            curve_list.append(curves.curve_from_history(
                f"K{k}_central", cen, algorithm="central"))
        for alg in ALL_ALGS:
            hist = harness.run(alg, xc)
            accs = [h["test_acc"] for h in hist]
            losses = [h["test_loss"] for h in hist]
            i = int(np.argmax(accs))
            summary[f"table4_K{k}_{alg}_acc"] = accs[i]
            summary[f"table4_K{k}_{alg}_loss"] = losses[i]
            curve_list.append(curves.curve_from_history(
                f"K{k}_{alg}", hist, algorithm=alg, scenario=spec))
    doc = curves.make_doc(
        "table4_dataset2", preset,
        dict(cfg, topks=list(topks), seed=seed, scenario=scenario),
        curve_list, summary)
    curves.finish(doc, out)
    return curves.summary_rows(doc), time.time() - t0, doc


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    curves.add_cli_args(p)
    a = p.parse_args()
    rows, dt, _ = run(preset=a.preset, seed=a.seed, scenario=a.scenario,
                      out=a.out)
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
