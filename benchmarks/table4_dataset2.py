"""Paper Tables IV-V / Fig. 6: Dataset-2 (pure time-series of content IDs)
with the LSTM model: OSAFL vs modified baselines + centralized Genie."""
from __future__ import annotations

import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np

from benchmarks.common import (ALL_ALGS, ExperimentConfig,
                               run_centralized_sgd, run_experiment)


def run(topks=(1, 2), rounds=25, num_clients=12, seed=0):
    t0 = time.time()
    rows = []
    for k in topks:
        xc = ExperimentConfig(model="lstm", dataset=2, rounds=rounds,
                              num_clients=num_clients, topk=k, seed=seed,
                              local_lr=0.2, global_lr=16.0)
        cen = run_centralized_sgd(xc)
        rows.append((f"table4_K{k}_central_acc",
                     max(h["test_acc"] for h in cen)))
        for alg in ALL_ALGS:
            hist = run_experiment(alg, xc)
            accs = [h["test_acc"] for h in hist]
            losses = [h["test_loss"] for h in hist]
            i = int(np.argmax(accs))
            rows.append((f"table4_K{k}_{alg}_acc", accs[i]))
            rows.append((f"table4_K{k}_{alg}_loss", losses[i]))
    return rows, time.time() - t0


if __name__ == "__main__":
    import argparse
    argparse.ArgumentParser(description=__doc__.splitlines()[0]).parse_args()
    rows, dt = run()
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
