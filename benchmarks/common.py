"""Deprecated location: the FL-experiment harness moved to ``repro.harness``.

Everything that used to live here is re-exported so existing imports keep
working; new code should call ``repro.harness.run(alg, xc)`` (the unified
facade over the loop/stacked/pod/centralized engines) and import config/
helpers from ``repro.harness`` directly."""
from repro.harness.compat import (ALL_ALGS, ENGINES,  # noqa: F401
                                  POD_ENGINES, ExperimentConfigError,
                                  ResolvedPlan, resolve)
from repro.harness.experiments import (MODEL_PARAMS,  # noqa: F401
                                       ExperimentConfig, _check_snapshot,
                                       _draw, _draw_round_inputs,
                                       _run_shape, _stacked_setup,
                                       _validate_ckpt_args,
                                       build_fused_engine, checkpoint_path,
                                       resume_smoke_config, run,
                                       run_centralized_sgd, run_experiment,
                                       run_pod_online_experiment,
                                       run_vectorized_experiment)
