"""Shared FL-experiment harness for the paper's tables/figures (reduced scale
for CPU: knobs recorded in EXPERIMENTS.md)."""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.baselines import make_server
from repro.core.buffer import OnlineBuffer, binomial_arrivals
from repro.core.buffer_stacked import StackedOnlineBuffer
from repro.core.client import local_train, make_vmapped_local_train
from repro.core.osafl import ClientUpdate
from repro.core.resource import (NetworkConfig, make_clients, optimize_round)
from repro.core.resource_stacked import optimize_round_batched, stack_clients
from repro.data.online import (binomial_arrivals_batched, dataset_layout,
                               draw_arrival_batch, pad_arrival_batch)
from repro.data.video_caching import make_population
from repro.models.small import REGISTRY, init_small, small_loss

MODEL_PARAMS = {"fcn": 3_900_000, "cnn": 1_100_000, "squeezenet": 740_000,
                "lstm": 430_000, "mlp": 18_000}


@dataclass
class ExperimentConfig:
    model: str = "fcn"
    dataset: int = 1                  # 1 | 2
    num_clients: int = 12
    rounds: int = 25
    capacity: tuple = (80, 160)       # D_u range (reduced from paper 320-640)
    arrivals: int = 8                 # E_u (paper: ceil(32 p_u))
    local_lr: float = 0.1
    global_lr: float = 16.0   # paper tunes 20-35; 16 is stable at T=25
    batch: int = 16
    topk: int = 1                     # K (request-model randomness)
    seed: int = 0
    use_resource_opt: bool = True
    cell_radius_m: float = 600.0      # milder than Fig.3's 1 km so the
                                      # reduced-round runs see participants


def _draw(stream, n, dataset):
    return (stream.draw_dataset1(n) if dataset == 1
            else stream.draw_dataset2(n))


def run_experiment(alg: str, xc: ExperimentConfig, eval_samples: int = 400):
    """One FL training run; returns per-round test metrics."""
    model = xc.model
    cat, streams = make_population(xc.seed, xc.num_clients, topk=xc.topk)
    rng = np.random.default_rng(xc.seed)
    feat_shape, dtype = dataset_layout(xc.dataset)
    bufs = []
    for s in streams:
        cap = int(rng.integers(*xc.capacity))
        buf = OnlineBuffer.create(cap, feat_shape, 100, dtype=dtype)
        x, y = _draw(s, cap, xc.dataset)
        buf.stage(x, y)
        buf.commit()
        bufs.append(buf)
    # online evaluation: the clients' own *future* requests (paper setting —
    # predicting an unseen user's preference-driven stream is not the task)
    per = max(eval_samples // xc.num_clients, 20)
    tests = [_draw(s, per, xc.dataset) for s in streams]
    tx = np.concatenate([t[0] for t in tests])
    ty = np.concatenate([t[1] for t in tests])
    test_batch = {"x": jnp.asarray(tx), "y": jnp.asarray(ty)}

    grad_fn = jax.grad(lambda p, b: small_loss(p, b, model)[0])
    params = init_small(jax.random.PRNGKey(xc.seed), model)
    glr = xc.global_lr if alg in ("osafl", "afa_cd") else 1.0
    fl = FLConfig(num_clients=xc.num_clients, local_lr=xc.local_lr,
                  global_lr=glr, algorithm=alg)
    server = make_server(params, fl, xc.num_clients, seed=xc.seed)

    net = NetworkConfig()
    clients_sys = make_clients(rng, xc.num_clients,
                               cell_radius_m=xc.cell_radius_m)
    n_params = MODEL_PARAMS.get(model, 1_000_000)

    history = []
    for t in range(xc.rounds):
        t_start = time.perf_counter()
        if xc.use_resource_opt:
            decisions = optimize_round(rng, net, clients_sys, n_params)
        updates = []
        for c, s in enumerate(streams):
            n = binomial_arrivals(rng, xc.arrivals, s.user.p_ac)
            if n:
                x, y = _draw(s, n, xc.dataset)
                bufs[c].stage(x, y)
            bufs[c].commit()
            kappa = decisions[c].kappa if xc.use_resource_opt else 5
            if kappa < 1:
                continue                      # straggler
            d, w = local_train(
                server.params, grad_fn, bufs[c], kappa, fl.local_lr,
                xc.batch, rng,
                prox_mu=fl.fedprox_mu if alg == "fedprox" else 0.0)
            upd = d if alg in ("osafl", "fednova", "afa_cd") else w
            updates.append(ClientUpdate(
                c, upd, kappa, data_size=bufs[c].size,
                label_hist=bufs[c].label_histogram()))
        server.round(updates)
        loss, m = small_loss(server.params, test_batch, model)
        history.append({"round": t, "test_loss": float(loss),
                        "test_acc": float(m["accuracy"]),
                        "participants": len(updates),
                        "round_s": time.perf_counter() - t_start})
    return history


def run_vectorized_experiment(alg: str, xc: ExperimentConfig,
                              eval_samples: int = 400):
    """Stacked-engine counterpart of ``run_experiment``: the whole cohort
    trains under one ``jax.vmap``, the server round is one vectorized
    (U, N)-buffer update, and the paper's full *online* setting runs in
    stacked form too — per-client FIFO buffers with Binomial(E_u, p_ac)
    arrivals (``StackedOnlineBuffer``, committed at round boundaries as one
    jitted scatter) and the joint kappa/f/p resource optimizer
    (``resource_stacked``, all clients in one jitted f64 solve). So
    ``xc.num_clients`` can be hundreds to thousands with no loss of paper
    fidelity; only the request streams themselves stay per-client Python.
    """
    model = xc.model
    U = xc.num_clients
    cat, streams = make_population(xc.seed, U, topk=xc.topk)
    rng = np.random.default_rng(xc.seed)
    feat_shape, dtype = dataset_layout(xc.dataset)
    lo, hi = xc.capacity
    caps = rng.integers(lo, max(hi, lo + 1), size=U)
    sbuf = StackedOnlineBuffer.create(
        caps, feat_shape, 100, stage_capacity=xc.arrivals, dtype=dtype)
    # initial fill: FIFO commits compose, so ingest the cap_u seed samples
    # in arrival-width chunks rather than sizing the staging area (kept for
    # the whole run) for caps.max()
    init = [_draw(s, int(c), xc.dataset) for s, c in zip(streams, caps)]
    for off in range(0, int(caps.max()), xc.arrivals):
        chunk = [(x[off:off + xc.arrivals], y[off:off + xc.arrivals])
                 if off < len(y) else None for x, y in init]
        sbuf.stage(*pad_arrival_batch(chunk, xc.arrivals, xc.dataset))
        sbuf.commit()
    p_ac = np.array([s.user.p_ac for s in streams])

    per = max(eval_samples // U, 4)
    tests = [_draw(s, per, xc.dataset) for s in streams]
    test_batch = {"x": jnp.asarray(np.concatenate([t[0] for t in tests])),
                  "y": jnp.asarray(np.concatenate([t[1] for t in tests]))}

    grad_fn = jax.grad(lambda p, b: small_loss(p, b, model)[0])
    params = init_small(jax.random.PRNGKey(xc.seed), model)
    glr = xc.global_lr if alg in ("osafl", "afa_cd") else 1.0
    fl = FLConfig(num_clients=U, local_lr=xc.local_lr, global_lr=glr,
                  algorithm=alg, engine="stacked")
    server = make_server(params, fl, U, seed=xc.seed)
    codec = server.codec

    local_step = make_vmapped_local_train(
        grad_fn, fl.local_lr, fl.kappa_max,
        prox_mu=fl.fedprox_mu if alg == "fedprox" else 0.0)
    weights_alg = alg in ("fedavg", "fedprox", "feddisco")

    net = NetworkConfig()
    sysb = stack_clients(make_clients(rng, U,
                                      cell_radius_m=xc.cell_radius_m))
    n_params = MODEL_PARAMS.get(model, 1_000_000)

    history = []
    for t in range(xc.rounds):
        t_start = time.perf_counter()
        counts = binomial_arrivals_batched(rng, xc.arrivals, p_ac)
        sbuf.stage(*draw_arrival_batch(streams, counts, xc.dataset,
                                       width=xc.arrivals))
        sbuf.commit()
        if xc.use_resource_opt:
            dec = optimize_round_batched(rng, net, sysb, n_params)
            kappas = dec.kappa
        else:
            kappas = np.full(U, fl.kappa_max)
        active = kappas >= 1                    # kappa = 0 => straggler
        slots = sbuf.sample_slots(rng, (fl.kappa_max, xc.batch))
        d, w = local_step(server.params, sbuf.gather(slots),
                          jnp.asarray(kappas))
        upd = codec.flatten_stacked(w if weights_alg else d)
        if alg == "fednova":
            # round_stacked merges sizes/kappas for active clients only, so
            # stragglers keep their last-seen kappa (loop meta semantics)
            server.round_stacked(upd, active, sizes=sbuf.sizes,
                                 kappas=kappas)
        elif alg == "feddisco":
            server.round_stacked(upd, active, sizes=sbuf.sizes,
                                 hists=sbuf.label_histograms())
        else:
            server.round_stacked(upd, active)
        loss, m = small_loss(server.params, test_batch, model)
        history.append({"round": t, "test_loss": float(loss),
                        "test_acc": float(m["accuracy"]),
                        "participants": int(active.sum()),
                        "round_s": time.perf_counter() - t_start})
    return history


def run_centralized_sgd(xc: ExperimentConfig, eval_samples: int = 400):
    """Genie baseline: all clients' current datasets pooled each round."""
    model = xc.model
    cat, streams = make_population(xc.seed, xc.num_clients, topk=xc.topk)
    rng = np.random.default_rng(xc.seed)
    feat_shape, dtype = dataset_layout(xc.dataset)
    bufs = []
    for s in streams:
        cap = int(rng.integers(*xc.capacity))
        buf = OnlineBuffer.create(cap, feat_shape, 100, dtype=dtype)
        x, y = _draw(s, cap, xc.dataset)
        buf.stage(x, y)
        buf.commit()
        bufs.append(buf)
    per = max(eval_samples // xc.num_clients, 20)
    tests = [_draw(s, per, xc.dataset) for s in streams]
    tx = np.concatenate([t[0] for t in tests])
    ty = np.concatenate([t[1] for t in tests])
    test_batch = {"x": jnp.asarray(tx), "y": jnp.asarray(ty)}
    params = init_small(jax.random.PRNGKey(xc.seed), model)
    grad_fn = jax.jit(jax.grad(lambda p, b: small_loss(p, b, model)[0]))
    history = []
    for t in range(xc.rounds):
        for c, s in enumerate(streams):
            n = binomial_arrivals(rng, xc.arrivals, s.user.p_ac)
            if n:
                x, y = _draw(s, n, xc.dataset)
                bufs[c].stage(x, y)
            bufs[c].commit()
        xs, ys = zip(*[b.dataset() for b in bufs])
        X, Y = np.concatenate(xs), np.concatenate(ys)
        for _ in range(5):                     # kappa=5 epochs-ish steps
            idx = rng.integers(0, len(Y), xc.batch * 4)
            g = grad_fn(params, {"x": jnp.asarray(X[idx]),
                                 "y": jnp.asarray(Y[idx])})
            params = jax.tree.map(lambda w, gg: w - xc.local_lr * gg,
                                  params, g)
        loss, m = small_loss(params, test_batch, model)
        history.append({"round": t, "test_loss": float(loss),
                        "test_acc": float(m["accuracy"])})
    return history


ALL_ALGS = ("osafl", "fedavg", "fedprox", "fednova", "afa_cd", "feddisco")
