"""Shared FL-experiment harness for the paper's tables/figures (reduced scale
for CPU: knobs recorded in EXPERIMENTS.md)."""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.baselines import make_server
from repro.core.buffer import OnlineBuffer, binomial_arrivals
from repro.core.client import local_train, make_vmapped_local_train
from repro.core.osafl import ClientUpdate
from repro.core.resource import (NetworkConfig, make_clients, optimize_round)
from repro.data.video_caching import D1_DIM, make_population
from repro.models.small import REGISTRY, init_small, small_loss

MODEL_PARAMS = {"fcn": 3_900_000, "cnn": 1_100_000, "squeezenet": 740_000,
                "lstm": 430_000, "mlp": 18_000}


@dataclass
class ExperimentConfig:
    model: str = "fcn"
    dataset: int = 1                  # 1 | 2
    num_clients: int = 12
    rounds: int = 25
    capacity: tuple = (80, 160)       # D_u range (reduced from paper 320-640)
    arrivals: int = 8                 # E_u (paper: ceil(32 p_u))
    local_lr: float = 0.1
    global_lr: float = 16.0   # paper tunes 20-35; 16 is stable at T=25
    batch: int = 16
    topk: int = 1                     # K (request-model randomness)
    seed: int = 0
    use_resource_opt: bool = True
    cell_radius_m: float = 600.0      # milder than Fig.3's 1 km so the
                                      # reduced-round runs see participants


def _draw(stream, n, dataset):
    return (stream.draw_dataset1(n) if dataset == 1
            else stream.draw_dataset2(n))


def run_experiment(alg: str, xc: ExperimentConfig, eval_samples: int = 400):
    """One FL training run; returns per-round test metrics."""
    model = xc.model
    cat, streams = make_population(xc.seed, xc.num_clients, topk=xc.topk)
    rng = np.random.default_rng(xc.seed)
    feat_shape = (D1_DIM,) if xc.dataset == 1 else (10,)
    dtype = np.float32 if xc.dataset == 1 else np.int64
    bufs = []
    for s in streams:
        cap = int(rng.integers(*xc.capacity))
        buf = OnlineBuffer.create(cap, feat_shape, 100, dtype=dtype)
        x, y = _draw(s, cap, xc.dataset)
        buf.stage(x, y)
        buf.commit()
        bufs.append(buf)
    # online evaluation: the clients' own *future* requests (paper setting —
    # predicting an unseen user's preference-driven stream is not the task)
    per = max(eval_samples // xc.num_clients, 20)
    tests = [_draw(s, per, xc.dataset) for s in streams]
    tx = np.concatenate([t[0] for t in tests])
    ty = np.concatenate([t[1] for t in tests])
    test_batch = {"x": jnp.asarray(tx), "y": jnp.asarray(ty)}

    grad_fn = jax.grad(lambda p, b: small_loss(p, b, model)[0])
    params = init_small(jax.random.PRNGKey(xc.seed), model)
    glr = xc.global_lr if alg in ("osafl", "afa_cd") else 1.0
    fl = FLConfig(num_clients=xc.num_clients, local_lr=xc.local_lr,
                  global_lr=glr, algorithm=alg)
    server = make_server(params, fl, xc.num_clients, seed=xc.seed)

    net = NetworkConfig()
    clients_sys = make_clients(rng, xc.num_clients,
                               cell_radius_m=xc.cell_radius_m)
    n_params = MODEL_PARAMS.get(model, 1_000_000)

    history = []
    for t in range(xc.rounds):
        if xc.use_resource_opt:
            decisions = optimize_round(rng, net, clients_sys, n_params)
        updates = []
        for c, s in enumerate(streams):
            n = binomial_arrivals(rng, xc.arrivals, s.user.p_ac)
            if n:
                x, y = _draw(s, n, xc.dataset)
                bufs[c].stage(x, y)
            bufs[c].commit()
            kappa = decisions[c].kappa if xc.use_resource_opt else 5
            if kappa < 1:
                continue                      # straggler
            d, w = local_train(
                server.params, grad_fn, bufs[c], kappa, fl.local_lr,
                xc.batch, rng,
                prox_mu=fl.fedprox_mu if alg == "fedprox" else 0.0)
            upd = d if alg in ("osafl", "fednova", "afa_cd") else w
            updates.append(ClientUpdate(
                c, upd, kappa, data_size=bufs[c].size,
                label_hist=bufs[c].label_histogram()))
        server.round(updates)
        loss, m = small_loss(server.params, test_batch, model)
        history.append({"round": t, "test_loss": float(loss),
                        "test_acc": float(m["accuracy"]),
                        "participants": len(updates)})
    return history


def run_vectorized_experiment(alg: str, xc: ExperimentConfig,
                              eval_samples: int = 400):
    """Stacked-engine counterpart of ``run_experiment``: the whole cohort
    trains under one ``jax.vmap`` and the server round is one vectorized
    (U, N)-buffer update, so ``xc.num_clients`` can be hundreds to thousands.

    Scale-harness simplifications vs the paper-faithful loop harness
    (recorded in EXPERIMENTS.md): every client holds a fixed-size stationary
    dataset of ``capacity[0]`` samples (drawn once — no FIFO arrivals), and
    round participation is Bernoulli(p_ac) with kappa ~ Uniform{1..kappa_max}
    instead of the per-client numpy resource optimizer.
    """
    model = xc.model
    U = xc.num_clients
    cat, streams = make_population(xc.seed, U, topk=xc.topk)
    rng = np.random.default_rng(xc.seed)
    cap = xc.capacity[0]
    data = [_draw(s, cap, xc.dataset) for s in streams]
    data_x = np.stack([d[0] for d in data])           # (U, cap, ...)
    data_y = np.stack([d[1] for d in data])           # (U, cap)
    p_ac = np.array([s.user.p_ac for s in streams])

    per = max(eval_samples // U, 4)
    tests = [_draw(s, per, xc.dataset) for s in streams]
    test_batch = {"x": jnp.asarray(np.concatenate([t[0] for t in tests])),
                  "y": jnp.asarray(np.concatenate([t[1] for t in tests]))}

    grad_fn = jax.grad(lambda p, b: small_loss(p, b, model)[0])
    params = init_small(jax.random.PRNGKey(xc.seed), model)
    glr = xc.global_lr if alg in ("osafl", "afa_cd") else 1.0
    fl = FLConfig(num_clients=U, local_lr=xc.local_lr, global_lr=glr,
                  algorithm=alg, engine="stacked")
    server = make_server(params, fl, U, seed=xc.seed)
    codec = server.codec

    local_step = make_vmapped_local_train(
        grad_fn, fl.local_lr, fl.kappa_max,
        prox_mu=fl.fedprox_mu if alg == "fedprox" else 0.0)
    if alg == "feddisco":
        hists = np.stack([np.bincount(y, minlength=100) / len(y)
                          for y in data_y])
    weights_alg = alg in ("fedavg", "fedprox", "feddisco")

    history = []
    for t in range(xc.rounds):
        active = rng.random(U) < p_ac
        kappas = np.where(active, rng.integers(1, fl.kappa_max + 1, U), 0)
        idx = rng.integers(0, cap, (U, fl.kappa_max, xc.batch))
        batches = {
            "x": jnp.asarray(data_x[np.arange(U)[:, None, None], idx]),
            "y": jnp.asarray(data_y[np.arange(U)[:, None, None], idx])}
        d, w = local_step(server.params, batches, jnp.asarray(kappas))
        upd = codec.flatten_stacked(w if weights_alg else d)
        if alg == "fednova":
            # round_stacked merges sizes/kappas for active clients only, so
            # stragglers keep their last-seen kappa (loop meta semantics)
            server.round_stacked(upd, active, sizes=np.full(U, cap),
                                 kappas=kappas)
        elif alg == "feddisco":
            server.round_stacked(upd, active, sizes=np.full(U, cap),
                                 hists=hists)
        else:
            server.round_stacked(upd, active)
        loss, m = small_loss(server.params, test_batch, model)
        history.append({"round": t, "test_loss": float(loss),
                        "test_acc": float(m["accuracy"]),
                        "participants": int(active.sum())})
    return history


def run_centralized_sgd(xc: ExperimentConfig, eval_samples: int = 400):
    """Genie baseline: all clients' current datasets pooled each round."""
    model = xc.model
    cat, streams = make_population(xc.seed, xc.num_clients, topk=xc.topk)
    rng = np.random.default_rng(xc.seed)
    feat_shape = (D1_DIM,) if xc.dataset == 1 else (10,)
    dtype = np.float32 if xc.dataset == 1 else np.int64
    bufs = []
    for s in streams:
        cap = int(rng.integers(*xc.capacity))
        buf = OnlineBuffer.create(cap, feat_shape, 100, dtype=dtype)
        x, y = _draw(s, cap, xc.dataset)
        buf.stage(x, y)
        buf.commit()
        bufs.append(buf)
    per = max(eval_samples // xc.num_clients, 20)
    tests = [_draw(s, per, xc.dataset) for s in streams]
    tx = np.concatenate([t[0] for t in tests])
    ty = np.concatenate([t[1] for t in tests])
    test_batch = {"x": jnp.asarray(tx), "y": jnp.asarray(ty)}
    params = init_small(jax.random.PRNGKey(xc.seed), model)
    grad_fn = jax.jit(jax.grad(lambda p, b: small_loss(p, b, model)[0]))
    history = []
    for t in range(xc.rounds):
        for c, s in enumerate(streams):
            n = binomial_arrivals(rng, xc.arrivals, s.user.p_ac)
            if n:
                x, y = _draw(s, n, xc.dataset)
                bufs[c].stage(x, y)
            bufs[c].commit()
        xs, ys = zip(*[b.dataset() for b in bufs])
        X, Y = np.concatenate(xs), np.concatenate(ys)
        for _ in range(5):                     # kappa=5 epochs-ish steps
            idx = rng.integers(0, len(Y), xc.batch * 4)
            g = grad_fn(params, {"x": jnp.asarray(X[idx]),
                                 "y": jnp.asarray(Y[idx])})
            params = jax.tree.map(lambda w, gg: w - xc.local_lr * gg,
                                  params, g)
        loss, m = small_loss(params, test_batch, model)
        history.append({"round": t, "test_loss": float(loss),
                        "test_acc": float(m["accuracy"])})
    return history


ALL_ALGS = ("osafl", "fedavg", "fedprox", "fednova", "afa_cd", "feddisco")
