"""Paper Fig. 1: centralized mini-batch SGD with a static dataset vs a
time-varying (FIFO, online-arrival) dataset. Reduced scale: video-caching
Dataset-1 stands in for CIFAR-10 (offline container; same mechanism)."""
from __future__ import annotations

import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ExperimentConfig, run_centralized_sgd
from repro.core.buffer import OnlineBuffer
from repro.data.video_caching import D1_DIM, make_population
from repro.models.small import init_small, small_loss


def run(rounds=15, seed=0):
    t0 = time.time()
    # time-varying: arrivals + FIFO
    xc = ExperimentConfig(model="fcn", rounds=rounds, num_clients=6,
                          seed=seed)
    tv = run_centralized_sgd(xc)
    # static: no arrivals
    xc2 = ExperimentConfig(model="fcn", rounds=rounds, num_clients=6,
                           arrivals=0, seed=seed)
    st = run_centralized_sgd(xc2)
    tv_acc = [h["test_acc"] for h in tv]
    st_acc = [h["test_acc"] for h in st]
    # instability metric: std of round-to-round accuracy deltas
    tv_var = float(np.std(np.diff(tv_acc)))
    st_var = float(np.std(np.diff(st_acc)))
    rows = [("fig1_static_final_acc", st_acc[-1]),
            ("fig1_timevarying_final_acc", tv_acc[-1]),
            ("fig1_static_instability", st_var),
            ("fig1_timevarying_instability", tv_var)]
    return rows, time.time() - t0


if __name__ == "__main__":
    import argparse
    argparse.ArgumentParser(description=__doc__.splitlines()[0]).parse_args()
    rows, dt = run()
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
