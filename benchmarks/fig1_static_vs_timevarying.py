"""Paper Fig. 1: learning on a static dataset vs a time-varying (FIFO,
online-arrival) dataset. Reproduced on the stacked engine as a scenario
pair: the time-varying world is the harness's native online setting; the
static world is the same run under ``quiet(scale=0.0)`` — the scenario
layer damps every arrival probability to zero, so the FIFO buffers freeze
at their initial fill (src/repro/scenarios/). Reduced scale by default:
video-caching Dataset-2 stands in for CIFAR-10 (same mechanism);
``--preset paper`` runs the EXPERIMENTS.md paper-scale shape."""
from __future__ import annotations

import dataclasses
import sys
import time
from pathlib import Path

if __package__ in (None, ""):    # executed as a script: python benchmarks/...
    _ROOT = Path(__file__).resolve().parent.parent
    for _p in (str(_ROOT / "src"), str(_ROOT)):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import numpy as np

from benchmarks import curves
from repro import harness
from repro.harness import ExperimentConfig

PRESETS = {
    # CI scale: seconds on a 2-core CPU
    "smoke": dict(model="mlp", dataset=2, num_clients=6, rounds=8,
                  arrivals=8, batch=4, capacity=(16, 24)),
    # EXPERIMENTS.md paper-scale recipe (Dataset-2 / U=256 / T=100)
    "paper": dict(model="mlp", dataset=2, num_clients=256, rounds=100,
                  arrivals=8, capacity=(320, 640),
                  request_backend="stacked"),
}


def run(preset="smoke", seed=0, scenario="", out=None):
    t0 = time.time()
    base = ExperimentConfig(seed=seed, **PRESETS[preset])
    # time-varying: the native online world (plus any CLI overlay)
    xc_tv = dataclasses.replace(
        base, scenario=curves.compose_specs(scenario))
    tv = harness.run("osafl", xc_tv)
    # static: freeze the datasets through the scenario layer
    xc_st = dataclasses.replace(
        base, scenario=curves.compose_specs("quiet(scale=0.0)", scenario))
    st = harness.run("osafl", xc_st)
    tv_acc = [h["test_acc"] for h in tv]
    st_acc = [h["test_acc"] for h in st]
    # instability metric: std of round-to-round accuracy deltas
    summary = {
        "fig1_static_final_acc": st_acc[-1],
        "fig1_timevarying_final_acc": tv_acc[-1],
        "fig1_static_instability": float(np.std(np.diff(st_acc))),
        "fig1_timevarying_instability": float(np.std(np.diff(tv_acc))),
    }
    doc = curves.make_doc(
        "fig1_static_vs_timevarying", preset,
        dict(PRESETS[preset], seed=seed, scenario=scenario),
        [curves.curve_from_history("timevarying", tv, algorithm="osafl",
                                   scenario=xc_tv.scenario),
         curves.curve_from_history("static", st, algorithm="osafl",
                                   scenario=xc_st.scenario)],
        summary)
    curves.finish(doc, out)
    return curves.summary_rows(doc), time.time() - t0, doc


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    curves.add_cli_args(p)
    a = p.parse_args()
    rows, dt, _ = run(preset=a.preset, seed=a.seed, scenario=a.scenario,
                      out=a.out)
    for k, v in rows:
        print(f"{k},{dt * 1e6:.0f},{v:.4f}")
