"""CI scenario smoke: null-scenario parity plus a composed perturbation
run on the sparse-cohort engine.

Two gates, both on the engine where scenarios interact with the most
machinery (slot pool, participation sampling, carry tables):

1. **Null parity** — ``scenario="null"`` must be bit-exact against the
   unscenarioed run: identical per-round metrics and participant counts.
   Any hook that touches the host RNG, resizes a draw, or fires when it
   should not shows up here as a trajectory divergence.
2. **Composed scenario** — ``churn(...)+flash_crowd(...)`` (availability
   mask x arrival spike) must run to completion with finite losses,
   participant counts within the sampling budget, and a trajectory that
   actually differs from baseline (a scenario that parses but never
   applies is a silent no-op).

Every run's curve is written as an ``osafl-curves/v1`` JSON document under
``--out`` (default ``experiments/scenario-smoke``); CI uploads them
``if: always()`` so a red gate still publishes the curves that explain it.

Usage: PYTHONPATH=src python tools/scenario_smoke.py [--out DIR]
"""
from __future__ import annotations

import argparse
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402

from benchmarks import curves  # noqa: E402
from repro.harness import ExperimentConfig, run  # noqa: E402

U, C, ROUNDS, PARTICIPATION = 32, 8, 4, 0.75
COMPOSED = "churn(p_away=0.5,period=2,away=1)+flash_crowd(period=2,duty=1,scale=2)"
METRICS = ("round", "test_loss", "test_acc", "participants")


def _xc(scenario: str) -> ExperimentConfig:
    return ExperimentConfig(model="mlp", dataset=2, num_clients=U,
                            rounds=ROUNDS, capacity=(12, 24), arrivals=4,
                            batch=8, seed=9, request_backend="stacked",
                            cohort_size=C, participation=PARTICIPATION,
                            scenario=scenario)


def _key(history):
    return [tuple(h[k] for k in METRICS) for h in history]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=os.path.join(_ROOT, "experiments",
                                                  "scenario-smoke"),
                    help="directory for per-scenario curve JSON documents")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    runs = {"baseline": "", "null": "null", "composed": COMPOSED}
    hists = {}
    for name, spec in runs.items():
        print(f"plan[{name}]:", _xc(spec).validate("osafl").describe())
        hists[name] = run("osafl", _xc(spec), eval_samples=64)
        doc = curves.make_doc(
            name="scenario_smoke", preset="smoke",
            config={"U": U, "C": C, "rounds": ROUNDS,
                    "participation": PARTICIPATION, "scenario": spec},
            curves=[curves.curve_from_history(name, hists[name], "osafl",
                                              spec)],
            summary={"final_loss": float(hists[name][-1]["test_loss"])})
        curves.write_doc(os.path.join(args.out, f"{name}.json"), doc)

    bad = []
    if _key(hists["baseline"]) != _key(hists["null"]):
        bad.append("null scenario diverged from the unscenarioed run")
    if _key(hists["baseline"]) == _key(hists["composed"]):
        bad.append("composed scenario did not perturb the trajectory")
    budget = max(1, int(round(PARTICIPATION * C)))
    for name, hist in hists.items():
        if len(hist) != ROUNDS:
            bad.append(f"{name}: {len(hist)} rounds, expected {ROUNDS}")
        for h in hist:
            if not np.isfinite(h["test_loss"]):
                bad.append(f"{name} round {h['round']}: non-finite loss")
            if h["participants"] > budget:
                bad.append(f"{name} round {h['round']}: "
                           f"{h['participants']} participants > {budget}")
    for name, hist in hists.items():
        print(f"{name:>9}: participants="
              f"{[h['participants'] for h in hist]} "
              f"final_loss={hist[-1]['test_loss']:.4f}")
    for msg in bad:
        print("FAIL:", msg)
    if bad:
        print("scenario smoke FAILED")
        return 1
    print(f"scenario smoke OK: null bit-exact on the cohort engine "
          f"(U={U}, C={C}), '{COMPOSED}' composes and perturbs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
