"""CI train-while-serve smoke: 3 online pod rounds on a faked 2x4 mesh with
a live hot-reloading model server polling the checkpoint directory.

The trainer (``repro.harness.run`` on the pod engine, OSAFL, mesh-sharded
FIFO buffer)
runs in a background thread publishing a streaming-v2 snapshot every round
with ``keep_last=2`` retention; the foreground ``serve_loop`` polls, maps
only committed snapshots, scores synthetic request batches on pinned
handles, and exits once round 3 is mapped. Fails (exit 1) on:

  * the server ever failing a load (claims make prune-vs-reload safe),
  * mapped rounds not strictly increasing (staleness must be monotone),
  * the final mapped round != the trainer's last round,
  * zero request batches served, or non-finite logits on the final batch.

Writes a JSON summary next to the bench_serve artifact (CI uploads both).

Usage: PYTHONPATH=src python tools/serve_smoke.py [--out DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import threading
from pathlib import Path

os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.harness import ExperimentConfig, resolve, run  # noqa: E402
from repro.launch.serve import make_request_batch, serve_loop  # noqa: E402

ROUNDS = 3


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=Path, default=None,
                    help="directory for the JSON summary artifact")
    args = ap.parse_args()

    if jax.device_count() < 8:
        print(f"serve smoke FAILED: needs 8 faked CPU devices, got "
              f"{jax.device_count()} (XLA_FLAGS not applied before jax "
              "import?)")
        return 1
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    xc = ExperimentConfig(model="mlp", dataset=2, num_clients=8,
                          rounds=ROUNDS, capacity=(12, 24), arrivals=4,
                          batch=8, seed=11)
    print("plan:", resolve("osafl", xc, mesh=mesh).describe())
    failures = []
    with tempfile.TemporaryDirectory(ignore_cleanup_errors=True) as td:
        ckpt_dir = Path(td) / "ckpt"
        train_err = []

        def train():
            try:
                run("osafl", xc, eval_samples=32, mesh=mesh,
                    save_every_k=1, checkpoint_dir=ckpt_dir, keep_last=2)
            except BaseException as e:          # surfaced after join
                train_err.append(e)

        trainer = threading.Thread(target=train, name="trainer")
        trainer.start()
        try:
            stats = serve_loop(ckpt_dir, until_round=ROUNDS, poll_s=0.05,
                               batch=16, dataset=xc.dataset,
                               timeout_s=600.0, verbose=True)
        finally:
            trainer.join(timeout=600.0)
        if train_err:
            raise train_err[0]

        rounds_seen = stats["mapped_rounds"]
        if stats["failed_loads"]:
            failures.append(f"server failed {stats['failed_loads']} loads "
                            f"(last: {stats['last_error']})")
        if rounds_seen != sorted(set(rounds_seen)):
            failures.append(f"mapped rounds not strictly increasing: "
                            f"{rounds_seen}")
        if stats["mapped_round"] != ROUNDS:
            failures.append(f"final mapped round {stats['mapped_round']} "
                            f"!= {ROUNDS}")
        if not stats["batches"]:
            failures.append("no request batches served")
        if any(r["behind"] < 0 for r in stats["reloads"]):
            failures.append(f"negative staleness: {stats['reloads']}")

        # the final mapped model must actually score: finite logits, right
        # width (trained on dataset 2 -> 100-class content ids)
        from repro.launch.serve import ModelServer
        with ModelServer(ckpt_dir) as server:
            server.poll()
            logits = server.score(make_request_batch(
                np.random.default_rng(0), 16, xc.dataset))
        if logits.shape[0] != 16 or not np.isfinite(logits).all():
            failures.append(f"bad logits from the final model: "
                            f"shape {logits.shape}")

        if args.out:
            args.out.mkdir(parents=True, exist_ok=True)
            doc = {"schema": "serve_smoke/v1", "rounds": ROUNDS,
                   "mesh": {"pod": 2, "data": 4}, "stats": stats,
                   "failures": failures}
            (args.out / "serve_smoke.json").write_text(
                json.dumps(doc, indent=2))

    for f in failures:
        print("serve smoke FAILURE:", f)
    if failures:
        print("serve smoke FAILED")
        return 1
    print(f"serve smoke OK: {len(stats['reloads'])} hot reloads to round "
          f"{stats['mapped_round']}, {stats['requests_scored']} requests "
          "scored, staleness monotone, no failed loads")
    return 0


if __name__ == "__main__":
    sys.exit(main())
