"""CI hierarchical-aggregation smoke: 3 online rounds at U = 4096 with a
C = 64 slot pool split into K = 8 edge clusters, on a faked 2x4 mesh.

Runs the two-tier aggregation (``core/hierarchy.py``) through the pod
harness at scale: 4096 registered users, a 64-slot pool in 8 per-cluster
blocks of 8 (each mesh shard owning whole blocks), participation sampling
stratified over the live cluster map, and ``cluster_churn`` membership
moves firing every round. Fails (exit 1) on a non-finite loss, a
participant count over the sampling budget, a snapshot whose slot pool is
not K per-cluster sub-pools, a missing/wrong-shape cluster-score carry
(``clam_prev``), or a churned cluster map that stopped being a valid
K-way partition. Prints the resolved plan line + per-round wall-clock so
regressions are visible in the CI log (the <= 3x hier-vs-flat aggregation
cost is gated separately by ``benchmarks/bench_online.py --smoke``).

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
       PYTHONPATH=src python tools/hier_smoke.py
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import checkpoint  # noqa: E402
from repro.harness import (ExperimentConfig, checkpoint_path,  # noqa: E402
                           resolve, run)

U, C, K, ROUNDS, PARTICIPATION = 4096, 64, 8, 3, 0.5


def main() -> int:
    if jax.device_count() < 8:
        print(f"hier smoke FAILED: needs 8 faked CPU devices, got "
              f"{jax.device_count()} (XLA_FLAGS not applied before jax "
              "import?)")
        return 1
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    xc = ExperimentConfig(model="mlp", dataset=2, num_clients=U,
                          rounds=ROUNDS, capacity=(12, 24), arrivals=4,
                          batch=8, seed=5, request_backend="stacked",
                          cohort_size=C, participation=PARTICIPATION,
                          num_clusters=K,
                          scenario="cluster_churn(rate=0.05)")
    print("plan:", resolve("osafl", xc, mesh=mesh).describe())
    with tempfile.TemporaryDirectory(ignore_cleanup_errors=True) as td:
        hist = run("osafl", xc, eval_samples=64, mesh=mesh,
                   save_every_k=ROUNDS, checkpoint_dir=td)
        snap = checkpoint.load_run_state(checkpoint_path(td, ROUNDS))
    sv = snap["server"]
    # stratified sampling draws ceil(m * n_k / U) per cluster, so the round
    # budget is the flat target plus at most one rounding unit per cluster
    # (and never more than the pool): sum_k ceil(x_k) < sum_k x_k + K
    m = max(1, int(round(PARTICIPATION * C)))
    budget = min(C, m + K - 1)
    bad = []
    pool = sv["pool"]
    if "pools" not in pool or len(pool["pools"]) != K:
        bad.append(f"snapshot slot pool is not {K} per-cluster sub-pools "
                   f"(keys: {sorted(pool)})")
    if int(pool.get("num_clusters", -1)) != K:
        bad.append(f"snapshot pool num_clusters={pool.get('num_clusters')}, "
                   f"expected {K}")
    assign = np.asarray(pool["assign"])
    if assign.shape != (U,) or assign.min() < 0 or assign.max() >= K:
        bad.append(f"churned cluster map is not a valid {K}-way partition "
                   f"of {U} users (shape={assign.shape}, "
                   f"range=[{assign.min()}, {assign.max()}])")
    clam = np.asarray(sv["inner"].get("clam_prev", np.empty(0)))
    if clam.shape != (K,) or not np.isfinite(clam).all():
        bad.append(f"cluster-score carry clam_prev has shape {clam.shape} "
                   f"(expected ({K},)) or non-finite entries")
    if sv["inner"]["d_buffer"].shape[0] != C:
        bad.append(f"slot buffer is {sv['inner']['d_buffer'].shape[0]} rows "
                   f"wide, expected C={C}")
    for h in hist:
        print(f"round={h['round']} test_loss={h['test_loss']:.4f} "
              f"participants={h['participants']} "
              f"round_s={h['round_s']:.2f}")
        if not np.isfinite(h["test_loss"]):
            bad.append(f"round {h['round']}: non-finite loss")
        if h["participants"] > budget:
            bad.append(f"round {h['round']}: {h['participants']} "
                       f"participants > budget {budget}")
    if len(hist) != ROUNDS:
        bad.append(f"history has {len(hist)} rounds, expected {ROUNDS}")
    for msg in bad:
        print("FAIL:", msg)
    if bad:
        print("hier smoke FAILED")
        return 1
    print(json.dumps({"U": U, "C": C, "K": K, "rounds": ROUNDS,
                      "round_s": [h["round_s"] for h in hist],
                      "cluster_sizes": np.bincount(assign,
                                                   minlength=K).tolist(),
                      "final_loss": hist[-1]["test_loss"]}, default=float))
    print(f"hier smoke OK: U={U} population, C={C} slots in K={K} cluster "
          f"blocks on a 2x4 mesh, churned map still partitions, "
          f"participants <= {budget}, losses finite")
    return 0


if __name__ == "__main__":
    sys.exit(main())
