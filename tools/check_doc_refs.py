"""Dangling-documentation-link checker.

Greps every tracked markdown file and every module docstring for tokens that
look like references to repo files (path-like tokens with a known top-level
prefix, ``repro/``-rooted module paths, or all-caps root-level markdown
names) and fails if a referenced file does not exist. This is the CI guard
against DESIGN.md-style references to documents that were never written.

Usage: python tools/check_doc_refs.py  (exit 0 = clean, 1 = dangling refs)
"""
from __future__ import annotations

import ast
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# files whose references are prospective or about external repos
EXCLUDE = {"ISSUE.md", "PAPERS.md", "SNIPPETS.md"}

TOKEN = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:py|md|toml|txt|ya?ml)\b")

# directories a path-like reference may be rooted at
TOP_DIRS = ("src", "tests", "benchmarks", "examples", "tools", ".github",
            "experiments")


def tracked_files() -> list[str]:
    out = subprocess.run(["git", "ls-files", "-z"], cwd=ROOT,
                         capture_output=True, text=True, check=True)
    return [p for p in out.stdout.split("\0") if p]


def candidate_paths(token: str) -> list[Path]:
    """Repo locations a doc token may resolve to."""
    token = token.lstrip("./")
    cands = [ROOT / token]
    if token.startswith("repro/"):
        cands.append(ROOT / "src" / token)
    if token.startswith("github/"):
        # the TOKEN regex cannot start at '.', so .github/... loses its dot
        cands.append(ROOT / ("." + token))
    if "/" in token and not token.startswith(TOP_DIRS):
        # module-relative references like core/buffer.py or kernels/ref.py
        cands.append(ROOT / "src" / "repro" / token)
    return cands


def is_repo_reference(token: str, basenames: set) -> bool:
    """Heuristic: which tokens claim to name a file of THIS repo?"""
    token = token.lstrip("./")
    if any(ch in token for ch in "*{<"):
        return False
    if "/" in token:
        head = token.split("/")[0]
        return token.startswith(TOP_DIRS) or head in ("repro", "github",
                                                      "core", "kernels",
                                                      "models", "data",
                                                      "launch", "configs",
                                                      "checkpoint")
    # bare names: root-level UPPERCASE.md docs must exist at the root;
    # bare code names (client.py, ci.yml) must exist *somewhere* tracked
    if token.endswith(".md"):
        return token[:-3].isupper()
    return token in basenames or token.endswith((".py", ".yml", ".yaml"))


def doc_sources() -> list[tuple[str, str]]:
    """(origin, text) pairs: tracked markdown + module docstrings."""
    sources = []
    for rel in tracked_files():
        if rel in EXCLUDE or Path(rel).name in EXCLUDE:
            continue
        path = ROOT / rel
        if rel.endswith(".md"):
            sources.append((rel, path.read_text()))
        elif rel.endswith(".py"):
            try:
                doc = ast.get_docstring(ast.parse(path.read_text()))
            except SyntaxError:
                doc = None
            if doc:
                sources.append((rel, doc))
    return sources


def main() -> int:
    tracked = tracked_files()
    basenames = {Path(t).name for t in tracked}
    dangling = []
    for origin, text in doc_sources():
        for token in set(TOKEN.findall(text)):
            if not is_repo_reference(token, basenames):
                continue
            bare = token.lstrip("./")
            if "/" not in bare:
                if bare.endswith(".md") and not (ROOT / bare).exists():
                    dangling.append((origin, token))
                elif not bare.endswith(".md") and bare not in basenames:
                    dangling.append((origin, token))
                continue
            if not any(p.exists() for p in candidate_paths(token)):
                dangling.append((origin, token))
    if dangling:
        print("dangling repo-file references:")
        for origin, token in sorted(dangling):
            print(f"  {origin}: {token}")
        return 1
    print(f"doc refs OK ({len(doc_sources())} sources scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
