"""CI save/resume smoke: 6 rounds on the vectorized online harness.

Runs the stacked engine uninterrupted for 6 rounds, then again as
3 rounds -> RunState snapshot -> resume -> 3 rounds, and fails (exit 1) on
any per-round metric divergence or any non-identical leaf in the end-of-run
snapshots (wall-clock timings excluded). This is the cheap tier-1 guard in
front of the full resume-determinism suite (tests/test_checkpoint_resume.py;
the cross-engine x algorithm matrix runs under ``-m slow``).

Usage: PYTHONPATH=src python tools/resume_smoke.py
"""
from __future__ import annotations

import os
import sys
import tempfile

os.environ.setdefault("JAX_PLATFORMS", "cpu")
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from repro import checkpoint  # noqa: E402
from repro.checkpoint import diff_snapshots  # noqa: E402
from repro.harness import (checkpoint_path,  # noqa: E402
                           resume_smoke_config, run)

ROUNDS, HALF = 6, 3
METRICS = ("round", "test_loss", "test_acc", "participants")
_cfg = resume_smoke_config


def main() -> int:
    # TemporaryDirectory contexts guarantee the checkpoint trees are removed
    # on every exit path — success, assertion failure, or an exception from
    # the harness — so repeated CI retries on one runner always start clean;
    # ignore_cleanup_errors keeps a half-written npz (e.g. the run dying
    # inside np.savez) from turning teardown itself into the failure.
    with tempfile.TemporaryDirectory(ignore_cleanup_errors=True) as da, \
            tempfile.TemporaryDirectory(ignore_cleanup_errors=True) as db:
        print("plan:", _cfg(ROUNDS).validate("osafl").describe())
        full = run("osafl", _cfg(ROUNDS), eval_samples=64,
                   save_every_k=ROUNDS, checkpoint_dir=da)
        run("osafl", _cfg(HALF), eval_samples=64, save_every_k=HALF,
            checkpoint_dir=db)
        resumed = run("osafl", _cfg(ROUNDS), eval_samples=64,
                      save_every_k=HALF, checkpoint_dir=db,
                      resume_from=checkpoint_path(db, HALF))
        bad = False
        for h_full, h_res in zip(full, resumed):
            line = " ".join(f"{k}={h_full[k]}" for k in METRICS)
            diverged = [k for k in METRICS if h_full[k] != h_res[k]]
            if diverged:
                bad = True
                line += "  DIVERGED: " + ", ".join(
                    f"{k} {h_full[k]!r} != {h_res[k]!r}" for k in diverged)
            print(line)
        diffs = diff_snapshots(
            checkpoint.load_run_state(checkpoint_path(da, ROUNDS)),
            checkpoint.load_run_state(checkpoint_path(db, ROUNDS)))
        for d in diffs:
            print("state mismatch:", d)
        if bad or diffs:
            print("resume smoke FAILED")
            return 1
    print(f"resume smoke OK: {ROUNDS}-round run == {HALF}+resume+{HALF}, "
          "metrics and final RunState bit-identical")
    return 0


if __name__ == "__main__":
    sys.exit(main())
