"""CI sparse-cohort smoke: 3 online rounds at U = 4096 with C = 64 slots.

Runs the sparse slot-pool engine (``core/cohort.py``) through the
vectorized online harness at a population the dense engines cannot
materialize in CI time: 4096 registered users, a 64-slot active pool,
participation sampling at 0.5 (so every round admits a fresh cohort,
FIFO-evicts stale residents and resets the recycled buffer rows). Fails
(exit 1) on a non-finite loss, on a round whose participant count exceeds
the participation budget, on a dense ``(U, N)`` ghost in the RunState
snapshot, or on an untouched-user violation — a carry can only change
while its user is seated, so the set of users whose (U,) table rows moved
must stay within the admission budget (> 95% of the population bit-
untouched). Also prints per-round wall-clock so regressions are visible in
the CI log (the >= 5x sparse-vs-dense ratio is gated separately by
``benchmarks/bench_online.py --smoke``).

Usage: PYTHONPATH=src python tools/cohort_smoke.py
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

os.environ.setdefault("JAX_PLATFORMS", "cpu")
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402

from repro import checkpoint  # noqa: E402
from repro.harness import (ExperimentConfig, checkpoint_path,  # noqa: E402
                           run)

U, C, ROUNDS, PARTICIPATION = 4096, 64, 3, 0.5


def main() -> int:
    xc = ExperimentConfig(model="mlp", dataset=2, num_clients=U,
                          rounds=ROUNDS, capacity=(12, 24), arrivals=4,
                          batch=8, seed=5, request_backend="stacked",
                          cohort_size=C, participation=PARTICIPATION)
    print("plan:", xc.validate("osafl").describe())
    with tempfile.TemporaryDirectory(ignore_cleanup_errors=True) as td:
        hist = run("osafl", xc, eval_samples=64, save_every_k=ROUNDS,
                   checkpoint_dir=td)
        sv = checkpoint.load_run_state(checkpoint_path(td, ROUNDS))["server"]
    budget = max(1, int(round(PARTICIPATION * C)))
    bad = []
    # no dense ghost in the snapshot; untouched users carry initial state
    if sv["inner"]["d_buffer"].shape[0] != C:
        bad.append(f"slot buffer is {sv['inner']['d_buffer'].shape[0]} "
                   f"rows wide, expected C={C}")
    # a user's carry can only change while seated in a slot (trained, or
    # score-refreshed as a resident), so the touched set is bounded by the
    # initial fill plus the per-round admission budget — at U=4096 that
    # leaves > 95% of the population bit-untouched
    part = np.asarray(sv["tables"]["participated"], bool)
    scores = np.asarray(sv["tables"]["scores"])
    touched = int((part | (scores != 1.0)).sum())
    if touched > C + ROUNDS * budget:
        bad.append(f"{touched} users' carries were touched; at most "
                   f"{C + ROUNDS * budget} were ever admitted")
    for h in hist:
        print(f"round={h['round']} test_loss={h['test_loss']:.4f} "
              f"participants={h['participants']} "
              f"round_s={h['round_s']:.2f}")
        if not np.isfinite(h["test_loss"]):
            bad.append(f"round {h['round']}: non-finite loss")
        if h["participants"] > budget:
            bad.append(f"round {h['round']}: {h['participants']} "
                       f"participants > budget {budget}")
    if len(hist) != ROUNDS:
        bad.append(f"history has {len(hist)} rounds, expected {ROUNDS}")
    for msg in bad:
        print("FAIL:", msg)
    if bad:
        print("cohort smoke FAILED")
        return 1
    print(json.dumps({"U": U, "C": C, "rounds": ROUNDS,
                      "round_s": [h["round_s"] for h in hist],
                      "final_loss": hist[-1]["test_loss"]}, default=float))
    print(f"cohort smoke OK: U={U} population on a C={C} slot pool, "
          f"participants <= {budget} every round, losses finite")
    return 0


if __name__ == "__main__":
    sys.exit(main())
