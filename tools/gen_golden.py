"""Regenerate the pinned golden-curve documents under tests/golden/.

Usage (repo root):

    PYTHONPATH=src:. python tools/gen_golden.py            # all runs
    PYTHONPATH=src:. python tools/gen_golden.py fig1 fig3  # a subset

Run this after any *intentional* change to a reproduced trajectory (new
RNG consumption order, harness semantics, scenario defaults) and commit
the refreshed JSON together with the change;
``tests/test_scenarios_golden.py`` is the gate that catches the
unintentional ones.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT / "src"), str(_ROOT)):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.golden import GOLDEN_RUNS, generate


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("names", nargs="*", metavar="NAME",
                   help=f"golden runs to regenerate "
                        f"(default: all of {sorted(GOLDEN_RUNS)})")
    a = p.parse_args()
    for path in generate(a.names or None):
        print(f"wrote {path.relative_to(_ROOT)}")


if __name__ == "__main__":
    main()
