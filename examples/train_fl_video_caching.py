"""End-to-end driver: the paper's full pipeline at a ~100M-parameter scale.

A 100M-parameter transformer (a shrunk h2o-danube-3 family member) is trained
for a few hundred OSAFL pod-engine rounds on a synthetic next-token task,
with the wireless resource optimizer budgeting each round's local work
(kappa) exactly as the paper's clients do.

    PYTHONPATH=src python examples/train_fl_video_caching.py \
        [--steps 200] [--engine exact_tp]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import FLConfig
from repro.core.pod import make_tp_train_step
from repro.core.resource import NetworkConfig, make_clients, optimize_round
from repro.data.synthetic import learnable_sequence_batch
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import init_model, param_count
from repro import checkpoint


def build_100m_config():
    """~100M params from the danube-3 family (same block structure)."""
    base = get_config("h2o-danube-3-4b")
    return dataclasses.replace(
        base, n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab_size=32_000, sliding_window=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/fl_100m.npz")
    args = ap.parse_args()

    cfg = build_100m_config()
    mesh = make_host_mesh()
    fl = FLConfig(kappa_max=1, local_lr=0.05, global_lr=1.0)
    params = init_model(jax.random.PRNGKey(0), cfg)
    print(f"model: {param_count(params) / 1e6:.1f}M params")

    # wireless resource budgeting: how many microbatches this round affords
    rng = np.random.default_rng(0)
    net = NetworkConfig()
    clients = make_clients(rng, 8, cell_radius_m=500.0)
    # uplink payload: at 100M raw params every client violates the deadline
    # (the paper's Fig. 3 effect taken to its limit), so budget the round for
    # an 8-bit-quantized + 4x-sparsified payload — the compression regime the
    # paper cites ([30]-[34]) for models of this size
    n_params = param_count(params) // 32

    key = jax.random.PRNGKey(1)
    with mesh:
        step = jax.jit(make_tp_train_step(cfg, fl, mesh))
        t0 = time.time()
        for t in range(args.steps):
            key, bk = jax.random.split(key)
            batch = learnable_sequence_batch(bk, cfg, args.batch, args.seq)
            params, metrics = step(params, batch)
            if t % 20 == 0 or t == args.steps - 1:
                decisions = optimize_round(rng, net, clients, n_params)
                kappas = [d.kappa for d in decisions]
                stragglers = sum(1 for d in decisions if not d.feasible)
                print(f"step {t:4d} loss={float(metrics['loss']):.4f} "
                      f"lambda={float(metrics['lambda_mean']):.3f} "
                      f"| wireless round: kappas={kappas} "
                      f"stragglers={stragglers}/8")
    print(f"trained {args.steps} steps in {time.time() - t0:.1f}s")
    checkpoint.save(args.ckpt, params, step=args.steps)
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
