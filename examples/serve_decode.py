"""Serve a small model with batched requests: prefill + KV-cache decode.

Demonstrates the serving path used by the decode_32k / long_500k dry-run
shapes, on a reduced zamba2 (hybrid Mamba2 + shared-attention) whose decode
state is O(1) in context length.

    PYTHONPATH=src python examples/serve_decode.py [--arch zamba2-2.7b]
"""
import argparse

from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--decode-steps", type=int, default=24)
    args = ap.parse_args()
    run(args.arch, reduced=True, batch=args.batch,
        prompt_len=args.prompt_len, decode_steps=args.decode_steps)


if __name__ == "__main__":
    main()
