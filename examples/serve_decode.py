"""Serve a small model with batched requests: prefill + KV-cache decode.

Demonstrates the serving path used by the decode_32k / long_500k dry-run
shapes, on a reduced zamba2 (hybrid Mamba2 + shared-attention) whose decode
state is O(1) in context length. (This is the transformer decode driver
that used to live in ``repro.launch.serve``; that module now hosts the FL
train-while-serve loop.)

    PYTHONPATH=src python examples/serve_decode.py [--arch zamba2-2.7b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.pod import make_serve_step
from repro.core.shmap import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import init_cache, init_model, whisper_encode


def run(arch: str, *, reduced=True, batch=4, prompt_len=32, decode_steps=16,
        cache_len=128, seed=0, verbose=True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg)
    memory = None
    if cfg.encoder is not None:
        frames = 0.02 * jax.random.normal(
            key, (batch, cfg.encoder.n_frames, cfg.d_model))
        memory = whisper_encode(params, frames, cfg)
        cache_len = min(cache_len, cfg.encoder.max_decoder_len)
    if cfg.vision is not None:
        patches = 0.02 * jax.random.normal(
            key, (batch, cfg.vision.n_patches, cfg.vision.d_vision))
        memory = patches.astype(jnp.bfloat16) @ params["vision_proj"].astype(
            jnp.bfloat16)

    cache = init_cache(cfg, batch, cache_len)
    prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    serve = jax.jit(make_serve_step(cfg))

    with use_mesh(mesh):
        # prefill via sequential decode (cache-exact; a fused prefill kernel
        # is the production path, exercised by the prefill_32k dry-run)
        t0 = time.time()
        tok = prompt[:, :1]
        for i in range(prompt_len):
            tok = prompt[:, i:i + 1]
            nxt, cache = serve(params, cache, tok, jnp.int32(i), memory)
        prefill_s = time.time() - t0
        out = []
        t0 = time.time()
        tok = nxt
        for i in range(decode_steps):
            tok, cache = serve(params, cache, tok,
                               jnp.int32(prompt_len + i), memory)
            out.append(tok)
        decode_s = time.time() - t0
    tokens = jnp.concatenate(out, axis=1)
    if verbose:
        print(f"{cfg.name}: prefill {prompt_len} toks in {prefill_s:.2f}s; "
              f"decoded {decode_steps} toks in {decode_s:.2f}s "
              f"({batch * decode_steps / max(decode_s, 1e-9):.1f} tok/s)")
        print("sampled token ids:", tokens[0][:12].tolist())
    return tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--decode-steps", type=int, default=24)
    args = ap.parse_args()
    run(args.arch, reduced=not args.full, batch=args.batch,
        prompt_len=args.prompt_len, decode_steps=args.decode_steps)


if __name__ == "__main__":
    main()
