"""Quickstart: OSAFL in ~60 lines.

Four wireless clients with time-varying FIFO datasets train the paper's FCN
on the video-caching task; the server weights their normalized updates by the
online cosine-similarity score (paper eq. 35).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import (ClientUpdate, OnlineBuffer, OSAFLServer,
                        binomial_arrivals, local_train)
from repro.data import D1_DIM, make_population
from repro.models import init_small, small_loss

U, ROUNDS, CAPACITY = 4, 15, 80

# --- data: each client has a FIFO buffer fed by its own request stream ------
cat, streams = make_population(seed=0, num_users=U)
buffers = []
for s in streams:
    buf = OnlineBuffer.create(CAPACITY, (D1_DIM,), 100)
    x, y = s.draw_dataset1(CAPACITY)
    buf.stage(x, y)
    buf.commit()
    buffers.append(buf)

# --- model + server ----------------------------------------------------------
fl = FLConfig(num_clients=U, local_lr=0.05, global_lr=2.0, algorithm="osafl")
params = init_small(jax.random.PRNGKey(0), "fcn")
server = OSAFLServer(params, fl, U)
grad_fn = jax.grad(lambda p, b: small_loss(p, b, "fcn")[0])
rng = np.random.default_rng(0)

for t in range(ROUNDS):
    updates = []
    for u in range(U):
        # new samples arrive Binomial(E_u, p_ac); FIFO evicts the oldest
        n = binomial_arrivals(rng, 8, streams[u].user.p_ac)
        if n:
            x, y = streams[u].draw_dataset1(n)
            buffers[u].stage(x, y)
        buffers[u].commit()
        # kappa_u local SGD steps -> normalized accumulated gradient d_u
        kappa = int(rng.integers(1, 5))
        d, _ = local_train(server.params, grad_fn, buffers[u], kappa,
                           fl.local_lr, batch_size=16, rng=rng)
        updates.append(ClientUpdate(u, d, kappa))
    server.round(updates)

    xs, ys = zip(*[b.dataset() for b in buffers])
    batch = {"x": jnp.asarray(np.concatenate(xs)),
             "y": jnp.asarray(np.concatenate(ys))}
    loss, m = small_loss(server.params, batch, "fcn")
    print(f"round {t:2d}  loss={float(loss):.3f} acc={float(m['accuracy']):.3f}"
          f"  scores={np.round(server.last_scores, 3)}")
