"""The paper's per-client wireless resource optimizer, standalone.

Reproduces the Fig. 3 mechanism: as the model payload grows, more clients
become stragglers (problem (5) infeasible), and feasible clients trade local
SGD steps against upload energy.

    PYTHONPATH=src python examples/resource_optimization.py
"""
import numpy as np

from repro.core.resource import (NetworkConfig, make_clients, optimize_round,
                                 sample_channel)

rng = np.random.default_rng(0)
net = NetworkConfig()
clients = make_clients(rng, 30)

print(f"{'payload':>12} {'stragglers':>11} {'kappa':>18} {'P tx (mW)':>12}")
for n_params, name in [(430_000, "LSTM 0.4M"), (740_000, "SqzNet 0.7M"),
                       (1_100_000, "CNN 1.1M"), (3_900_000, "FCN 3.9M")]:
    dec = optimize_round(rng, net, clients, n_params)
    feas = [d for d in dec if d.feasible]
    kappas = [d.kappa for d in feas]
    powers = [d.p * 1e3 for d in feas]
    print(f"{name:>12} {30 - len(feas):>8}/30 "
          f"{np.mean(kappas) if kappas else 0:>10.2f} (max 5) "
          f"{np.mean(powers) if powers else 0:>10.1f}")

print("\nper-client detail (FCN payload):")
dec = optimize_round(rng, net, clients[:8], 3_900_000)
for i, d in enumerate(dec):
    status = (f"kappa={d.kappa} f={d.f / 1e9:.2f}GHz p={d.p * 1e3:.1f}mW "
              f"t={d.t_total:.1f}s e={d.e_total:.2f}J"
              if d.feasible else "STRAGGLER (problem (5) infeasible)")
    print(f"  client {i}: {status}")
