"""FIFO online buffer invariants (hypothesis) + video-caching dataset."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.buffer import OnlineBuffer, binomial_arrivals
from repro.data.video_caching import (D1_DIM, F_FILES, FILES_PER_GENRE,
                                      G_GENRES, make_population,
                                      zipf_mandelbrot_pmf)


@given(st.integers(1, 40), st.lists(st.integers(0, 9), min_size=0,
                                    max_size=120))
@settings(max_examples=30, deadline=None)
def test_fifo_buffer_invariants(capacity, labels):
    buf = OnlineBuffer.create(capacity, (3,), 10)
    for i, y in enumerate(labels):
        buf.stage(np.full((1, 3), i, np.float32), np.array([y]))
        buf.commit()
        assert buf.size <= capacity
    x, y = buf.dataset()
    assert len(y) == min(len(labels), capacity)
    # FIFO: buffer holds exactly the last `size` samples, in arrival order
    expect = labels[-buf.size:] if buf.size else []
    assert list(y) == expect
    if buf.size:
        assert x[0, 0] == len(labels) - buf.size    # oldest retained sample


def test_fifo_head_wraps_around_on_eviction():
    """Once full, each insert overwrites the oldest slot and the head
    pointer wraps modulo capacity."""
    buf = OnlineBuffer.create(3, (1,), 10)
    buf.stage(np.zeros((3, 1), np.float32), np.array([0, 1, 2]))
    buf.commit()
    assert (buf.size, buf.head) == (3, 0)
    buf.stage(np.zeros((2, 1), np.float32), np.array([3, 4]))
    buf.commit()
    assert (buf.size, buf.head) == (3, 2)       # two evictions, head wrapped
    assert list(buf.dataset()[1]) == [2, 3, 4]  # FIFO order preserved
    buf.stage(np.zeros((1, 1), np.float32), np.array([5]))
    buf.commit()
    assert buf.head == 0                        # wrapped past the end
    assert list(buf.dataset()[1]) == [3, 4, 5]


def test_single_commit_larger_than_capacity_keeps_last():
    """One commit of more staged samples than capacity retains exactly the
    last `capacity` samples (earlier ones are immediately overwritten)."""
    buf = OnlineBuffer.create(4, (1,), 100)
    buf.stage(np.arange(11, dtype=np.float32).reshape(11, 1), np.arange(11))
    assert buf.commit() == 11
    assert buf.size == 4
    assert list(buf.dataset()[1]) == [7, 8, 9, 10]
    # again from a non-empty, wrapped state
    buf.stage(np.arange(9, dtype=np.float32).reshape(9, 1),
              np.arange(20, 29))
    buf.commit()
    assert buf.size == 4
    assert list(buf.dataset()[1]) == [25, 26, 27, 28]


def test_sharded_buffer_capacity_and_wraparound_device_arrivals():
    """Mesh-sharded ``StackedOnlineBuffer`` driven by the fused round's
    on-device Binomial arrival draw (``round_fused.draw_counts``): an
    exact-capacity fill, a burst larger than capacity, and multi-round
    wrap-around must all leave the sharded state bit-identical to the
    per-client ``OnlineBuffer`` oracle fed the same counts."""
    import jax

    from repro.core.buffer_stacked import StackedOnlineBuffer
    from repro.core.round_fused import draw_counts, fused_base_key

    U, width = 4, 6
    caps = np.array([4, 5, 6, 6])       # cap == width lanes hit the
    feat = (3,)                         # exact-capacity boundary; cap <
    mesh = jax.make_mesh((1, 1), ("data", "model"))   # width lanes overflow
    sbuf = StackedOnlineBuffer.create(caps, feat, 100, stage_capacity=width,
                                      dtype=np.float32, mesh=mesh)
    assert sbuf.mesh is not None
    oracles = [OnlineBuffer.create(int(c), feat, 100, dtype=np.float32)
               for c in caps]
    key = fused_base_key(123)
    sample = 0
    for rnd in range(6):
        # round 0: p_ac = 1 -> every count == width (the boundary bursts);
        # afterwards: genuine on-device Binomial thinning
        p_ac = np.ones(U, np.float32) if rnd == 0 \
            else np.full(U, 0.7, np.float32)
        counts = np.asarray(draw_counts(
            jax.random.fold_in(key, rnd), p_ac, width))
        xs = np.zeros((U, width) + feat, np.float32)
        ys = np.zeros((U, width), np.int64)
        for u in range(U):
            n = int(counts[u])
            xs[u, :n] = np.arange(sample, sample + n
                                  ).reshape(n, 1) + np.zeros(feat)
            ys[u, :n] = np.arange(sample, sample + n) % 100
            if n:
                oracles[u].stage(xs[u, :n], ys[u, :n])
            oracles[u].commit()
            sample += n
        sbuf.stage(xs, ys, counts)
        sbuf.commit()
        if rnd == 0:
            assert list(sbuf.sizes) == [4, 5, 6, 6]   # full at capacity
        for u, oracle in enumerate(oracles):
            ox, oy = oracle.dataset()
            sx, sy = sbuf.dataset(u)
            assert np.array_equal(ox, sx), (rnd, u)
            assert np.array_equal(oy, sy), (rnd, u)
            assert oracle.size == sbuf.sizes[u]
            assert oracle.head == sbuf.heads[u], (rnd, u)


def test_empty_commit_is_noop():
    buf = OnlineBuffer.create(4, (1,), 10)
    buf.stage(np.zeros((2, 1), np.float32), np.array([7, 8]))
    buf.commit()
    size, head = buf.size, buf.head
    assert buf.commit() == 0                    # nothing staged
    assert (buf.size, buf.head) == (size, head)
    assert list(buf.dataset()[1]) == [7, 8]


def test_staged_arrivals_apply_only_on_commit():
    buf = OnlineBuffer.create(4, (1,), 5)
    buf.stage(np.zeros((2, 1), np.float32), np.array([1, 2]))
    assert buf.size == 0                    # paper: temp buffer within round
    n = buf.commit()
    assert n == 2 and buf.size == 2


def test_label_histogram_normalized():
    buf = OnlineBuffer.create(10, (1,), 5)
    buf.stage(np.zeros((6, 1), np.float32), np.array([0, 0, 1, 2, 3, 4]))
    buf.commit()
    h = buf.label_histogram()
    np.testing.assert_allclose(h.sum(), 1.0)
    assert h[0] == pytest.approx(2 / 6)


def test_distribution_shift_zero_when_static():
    buf = OnlineBuffer.create(8, (1,), 4)
    buf.stage(np.zeros((4, 1), np.float32), np.array([0, 1, 2, 3]))
    buf.commit()
    buf.distribution_shift()                # initializes last_hist
    assert buf.distribution_shift() == 0.0  # Definition 1: Phi^0 = 0 shift


@given(st.integers(0, 30), st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_binomial_arrivals_bounded(e_u, p):
    rng = np.random.default_rng(0)
    n = binomial_arrivals(rng, e_u, p)
    assert 0 <= n <= e_u


def test_zipf_mandelbrot_pmf():
    pmf = zipf_mandelbrot_pmf(20, gamma=1.2, q=2.0)
    np.testing.assert_allclose(pmf.sum(), 1.0)
    assert np.all(np.diff(pmf) <= 1e-12)    # decreasing in rank


def test_video_caching_dataset_shapes_and_labels():
    cat, streams = make_population(0, 3)
    x, y = streams[0].draw_dataset1(50)
    assert x.shape == (50, D1_DIM)
    assert np.all((y >= 0) & (y < F_FILES))
    x2, y2 = streams[1].draw_dataset2(40)
    assert x2.shape == (40, 10)
    assert np.all((x2 >= 0) & (x2 < F_FILES))
    # sliding window: next window starts with the previous window shifted
    assert list(x2[1][:-1]) != list(x2[1][1:])  # non-degenerate


def test_request_model_respects_genre_structure():
    cat, streams = make_population(1, 1)
    s = streams[0]
    reqs = [s.user.next_request(s.rng, cat) for _ in range(200)]
    genres = np.array(reqs) // FILES_PER_GENRE
    assert set(genres) <= set(range(G_GENRES))
    # exploitation makes consecutive same-genre requests common
    same = np.mean(genres[1:] == genres[:-1])
    assert same > 0.3
