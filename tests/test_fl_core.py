"""FL core: scores, servers, client training, convergence bound — including
the paper's structural claims (hypothesis property tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import FLConfig
from repro.core.baselines import (AFACDServer, FedAvgServer, FedDiscoServer,
                                  FedNovaServer, make_server)
from repro.core.convergence import (BoundHypers, a_term, b_term, fedavg_bound,
                                    lr_condition, optimal_delta, round_bound)
from repro.core.osafl import ClientUpdate, OSAFLServer
from repro.core.scores import (cosine, lambda_scores, lambda_scores_sketched,
                               sketch_tree, tree_dot, tree_norm)


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    return {"a": scale * jax.random.normal(k1, (13,)),
            "b": scale * jax.random.normal(k2, (4, 5))}


# --------------------------------------------------------------------------
# scores (paper eqs. 19-21)
# --------------------------------------------------------------------------

@given(st.integers(2, 12), st.floats(1.0, 10.0))
@settings(max_examples=20, deadline=None)
def test_lambda_in_unit_interval(u, chi):
    updates = [_tree(i) for i in range(u)]
    lam = lambda_scores(updates, chi=chi)
    assert np.all(lam >= 0.0) and np.all(lam <= 1.0)


def test_identical_updates_give_lambda_one():
    d = _tree(0)
    lam = lambda_scores([d, d, d], chi=1.0)
    np.testing.assert_allclose(lam, 1.0, atol=1e-6)


def test_opposed_update_scores_lower():
    d = _tree(0)
    neg = jax.tree.map(lambda x: -x, d)
    lam = lambda_scores([d, d, d, neg], chi=1.0)
    assert lam[3] < lam[0]
    assert np.argmin(lam) == 3


def test_sketched_scores_approximate_exact():
    # count-sketch inner products concentrate; k >> 1 gives a close estimate
    updates = [_tree(i, scale=1 + 0.1 * i) for i in range(6)]
    lam = lambda_scores(updates, chi=1.0)
    key = jax.random.PRNGKey(0)
    sk = jnp.stack([sketch_tree(d, key, 64) for d in updates])
    lam_sk = lambda_scores_sketched(sk, chi=1.0)
    # identical-direction structure is preserved
    assert np.corrcoef(lam, lam_sk)[0, 1] > 0.5 or np.allclose(lam, lam_sk,
                                                               atol=0.15)


def test_scores_match_pallas_kernel():
    from repro.kernels.ops import osafl_scores
    updates = [_tree(i) for i in range(5)]
    lam = lambda_scores(updates, chi=1.0)
    flat = jnp.stack([jnp.concatenate([l.reshape(-1) for l in
                                       jax.tree.leaves(d)])
                      for d in updates])
    lam_k = np.asarray(osafl_scores(flat, chi=1.0))
    np.testing.assert_allclose(lam, lam_k, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# OSAFL server (Algorithm 2)
# --------------------------------------------------------------------------

def _updates(u, key=0):
    return [ClientUpdate(i, _tree(100 * key + i), kappa=1, data_size=10)
            for i in range(u)]


def test_osafl_round_moves_params_against_mean():
    params = _tree(42)
    fl = FLConfig(num_clients=4, local_lr=0.1, global_lr=1.0)
    srv = OSAFLServer(params, fl, 4)
    ups = _updates(4)
    new = srv.round(ups)
    # with all Delta=lambda in (0,1], the step is a positive combination of
    # the client updates: moving along -mean reduces <w_new - w, mean>
    mean = jax.tree.map(
        lambda *xs: sum(xs) / 4, *[u.d for u in ups])
    delta = jax.tree.map(lambda a, b: a - b, new, params)
    assert float(tree_dot(delta, mean)) < 0.0


def test_osafl_identical_updates_equal_afacd():
    """With identical client updates lambda=1 for all => OSAFL == AFA-CD."""
    params = _tree(7)
    fl = FLConfig(num_clients=3, local_lr=0.1, global_lr=2.0)
    d = _tree(3)
    ups = [ClientUpdate(i, d, 1) for i in range(3)]
    a = OSAFLServer(params, fl, 3).round(ups)
    b = AFACDServer(params, fl, 3).round(ups)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(x, y, rtol=1e-6)


def test_osafl_sketched_round_runs():
    params = _tree(9)
    fl = FLConfig(num_clients=4, local_lr=0.1, score_sketch_dim=32)
    srv = OSAFLServer(params, fl, 4)
    srv.round(_updates(4))
    assert np.all(srv.last_scores >= 0) and np.all(srv.last_scores <= 1)


# --------------------------------------------------------------------------
# baselines (Algorithms 6-10)
# --------------------------------------------------------------------------

def test_fedavg_averages_weights():
    params = _tree(0)
    fl = FLConfig(num_clients=2)
    srv = FedAvgServer(params, fl, 2)
    w1, w2 = _tree(1), _tree(2)
    new = srv.round([ClientUpdate(0, w1, 1), ClientUpdate(1, w2, 1)])
    expect = jax.tree.map(lambda a, b: 0.5 * (a + b), w1, w2)
    for x, y in zip(jax.tree.leaves(new), jax.tree.leaves(expect)):
        np.testing.assert_allclose(x, y, rtol=1e-6)


def test_fedavg_stale_buffer_for_nonparticipant():
    params = _tree(0)
    fl = FLConfig(num_clients=3)
    srv = FedAvgServer(params, fl, 3)
    w1 = _tree(1)
    new = srv.round([ClientUpdate(0, w1, 1)])   # clients 1,2 never participated
    expect = jax.tree.map(lambda a, b: (a + 2 * b) / 3.0, w1, params)
    for x, y in zip(jax.tree.leaves(new), jax.tree.leaves(expect)):
        np.testing.assert_allclose(x, y, rtol=1e-5)


def test_feddisco_weights_sum_to_one_and_penalize_discrepancy():
    params = _tree(0)
    fl = FLConfig(num_clients=2, feddisco_a=0.5, feddisco_b=0.1)
    srv = FedDiscoServer(params, fl, 2)
    hist_uniform = np.full(10, 0.1)
    hist_skewed = np.zeros(10)
    hist_skewed[0] = 1.0
    srv.round([
        ClientUpdate(0, _tree(1), 1, data_size=10, label_hist=hist_uniform),
        ClientUpdate(1, _tree(2), 1, data_size=10, label_hist=hist_skewed),
    ])
    # skewed client got a lower aggregation weight (via its higher disco)
    # reconstruct: alpha = relu(p - a*d + b)
    p = np.array([0.5, 0.5])
    d = np.array([0.0, np.linalg.norm(hist_skewed - hist_uniform)])
    alpha = np.maximum(p - 0.5 * d + 0.1, 0)
    alpha /= alpha.sum()
    assert alpha[1] < alpha[0]


def test_make_server_registry():
    params = _tree(0)
    for alg in ("osafl", "fedavg", "fedprox", "fednova", "afa_cd",
                "feddisco"):
        srv = make_server(params, FLConfig(algorithm=alg), 2)
        assert srv is not None


# --------------------------------------------------------------------------
# convergence bound (Theorem 1)
# --------------------------------------------------------------------------

@given(st.floats(0.0, 3.0), st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_b_term_nonnegative(delta, lam):
    assert b_term(np.array([delta]), np.array([lam]))[0] >= 0.0


def test_round_bound_error_terms_scale_with_kappa():
    h = BoundHypers(beta=1.0, sigma2=0.5, rho2=1.0, eta=0.01)
    alpha = np.full(4, 0.25)
    lam = np.full(4, 0.8)
    delta = lam.copy()
    phi = np.full(4, 0.1)
    ds = np.full(4, 0.2)
    b1 = round_bound(h, 1.0, 0.9, alpha, np.full(4, 1.0), delta, lam, phi, ds)
    b5 = round_bound(h, 1.0, 0.9, alpha, np.full(4, 5.0), delta, lam, phi, ds)
    assert b5["shift_err"] > b1["shift_err"]
    assert b5["hetero_err"] > b1["hetero_err"]


def test_fedavg_special_case_consistency():
    """Delta=1, lambda=1, IID (rho2=0, phi arbitrary): eq. 24 bracket reduces
    to the FedAvg bound eq. 26."""
    h = BoundHypers(beta=1.0, sigma2=0.3, rho1=1.0, rho2=0.0, eta=0.01,
                    eta_g=1.0)
    alpha = np.full(3, 1 / 3)
    kappa = np.full(3, 2.0)
    lam = np.ones(3)
    delta = np.ones(3)
    phi = np.full(3, 0.05)
    r = round_bound(h, 1.0, 0.95, alpha, kappa, delta, lam, phi,
                    np.zeros(3))
    # B_u = (1-1)^2 + 1 = 1; eq. 26 uses the same terms with B=1 and the
    # sgd-noise kappa term matching
    fa = fedavg_bound(h, 1.0, 0.95, alpha, 2, phi)
    np.testing.assert_allclose(r["total"] * r["A"], fa, rtol=1e-9)


def test_lr_condition():
    assert lr_condition(BoundHypers(beta=1.0, eta=0.05, eta_g=1.0), 5)
    assert not lr_condition(BoundHypers(beta=1.0, eta=0.2, eta_g=1.0), 5)


@given(st.floats(0.01, 0.99), st.floats(0.0, 0.5), st.floats(0.0, 0.5))
@settings(max_examples=30, deadline=None)
def test_optimal_delta_tracks_lambda(lam, phi, ds):
    """Eq. 35: with gamma=0, Delta* <= lam and -> lam as sigma2 -> 0."""
    h = BoundHypers(sigma2=0.0)
    d = optimal_delta(h, 0.25, 3.0, lam, phi, ds, gamma_u=0.0)
    np.testing.assert_allclose(d, lam, rtol=1e-9)
    h2 = BoundHypers(sigma2=5.0)
    d2 = optimal_delta(h2, 0.25, 3.0, lam, phi, ds, gamma_u=0.0)
    assert d2 <= lam + 1e-12
