"""Checkpoint/restore of full online-run state: resume determinism.

The headline invariant (acceptance bar of the checkpoint PR): for both FL
engines, "run T rounds" and "run T/2 rounds -> save -> restore into freshly
built objects -> run T/2 more" produce BIT-IDENTICAL params, scores, buffer
contents (incl. FIFO pointers and staged arrivals), Generator stream
positions and per-round eval metrics. Verified by comparing the end-of-run
RunState snapshots of both trajectories leaf by leaf with rtol=0 atol=0.

Also here: hypothesis property tests (tests/_hyp.py shim) for snapshot
round-trips of arbitrary buffer wrap/over-capacity/staged states, and the
failure paths of the checkpoint package (structure/dtype mismatch, missing
sidecar, future snapshot-format versions).
"""
import json
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import dataclasses

from benchmarks.common import (ALL_ALGS, checkpoint_path,
                               resume_smoke_config, run_experiment,
                               run_vectorized_experiment)
from repro import checkpoint
from repro.checkpoint import CheckpointError, diff_snapshots
from repro.core.buffer import OnlineBuffer
from repro.core.buffer_stacked import StackedOnlineBuffer
from repro.core.cohort import SlotPool
from repro.models.small import init_small

from _hyp import given, settings, st

_RUNNERS = {"loop": run_experiment, "stacked": run_vectorized_experiment}
_cfg = resume_smoke_config       # one run shape, shared with the CI smoke


def _assert_tree_equal(a, b, skip=("round_s", "request_gen_s")):
    """Bit-exact equality of two snapshot trees (wall-clock timings excluded
    by default — they are the only legitimately divergent leaves)."""
    diffs = diff_snapshots(a, b, skip=skip)
    assert not diffs, diffs


def _assert_resume_bit_exact(tmp_path, engine, alg, rounds=6,
                             request_backend="python", mutate=None):
    runner = _RUNNERS[engine]

    def cfg(r):
        xc = dataclasses.replace(_cfg(r), request_backend=request_backend)
        return mutate(xc) if mutate else xc

    da, db = tmp_path / "full", tmp_path / "split"
    half = rounds // 2
    full = runner(alg, cfg(rounds), eval_samples=64,
                  save_every_k=rounds, checkpoint_dir=da)
    runner(alg, cfg(half), eval_samples=64,
           save_every_k=half, checkpoint_dir=db)
    resumed = runner(alg, cfg(rounds), eval_samples=64,
                     save_every_k=half, checkpoint_dir=db,
                     resume_from=checkpoint_path(db, half))
    # per-round eval metrics: exact equality, full history present
    assert [h["round"] for h in resumed] == list(range(rounds))
    for h_full, h_res in zip(full, resumed):
        for k in ("round", "test_loss", "test_acc", "participants"):
            assert h_full[k] == h_res[k], (engine, alg, k, h_full, h_res)
    sa = checkpoint.load_run_state(checkpoint_path(da, rounds))
    sb = checkpoint.load_run_state(checkpoint_path(db, rounds))
    # acceptance bar stated explicitly: params and scores at rtol=0 atol=0
    # (sparse-cohort snapshots keep them one level down, in the width-C
    # inner server)
    srv_a = sa["server"].get("inner", sa["server"])
    srv_b = sb["server"].get("inner", sb["server"])
    if "w" in srv_a:
        np.testing.assert_allclose(srv_b["w"], srv_a["w"], rtol=0, atol=0)
    else:
        for la, lb in zip(jax.tree.leaves(srv_a["params"]),
                          jax.tree.leaves(srv_b["params"])):
            np.testing.assert_allclose(lb, la, rtol=0, atol=0)
    if "last_scores" in srv_a:
        np.testing.assert_allclose(srv_b["last_scores"],
                                   srv_a["last_scores"], rtol=0, atol=0)
    # ... and then everything — buffers, pointers, staged arrivals, RNG
    # stream positions, staleness flags, metric history — bit-exact
    _assert_tree_equal(sa, sb)


@pytest.mark.parametrize("engine,alg", [("loop", "osafl"),
                                        ("stacked", "osafl"),
                                        ("stacked", "fednova")])
def test_resume_determinism(tmp_path, engine, alg):
    """Mid-stream save/restore reproduces the uninterrupted trajectory
    bit-exactly for both engines (default-suite acceptance criterion)."""
    _assert_resume_bit_exact(tmp_path, engine, alg)


def test_resume_determinism_stacked_request_backend(tmp_path):
    """The batched Gumbel request model checkpoints its device-array state
    (PRNG key, Markov state, window carries) through the same RunState path
    and resumes bit-exactly too."""
    _assert_resume_bit_exact(tmp_path, "stacked", "osafl",
                             request_backend="stacked")


def _sparse(xc):
    """C < U with participation sampling on a 16-user pool — admissions,
    FIFO evictions and buffer resets all land inside the saved window."""
    return dataclasses.replace(xc, num_clients=16, cohort_size=4,
                               participation=0.75)


@pytest.mark.parametrize("alg,backend", [("osafl", "python"),
                                         ("osafl", "stacked"),
                                         ("fednova", "python")])
def test_resume_determinism_sparse_cohort(tmp_path, alg, backend):
    """The sparse-cohort engine resumes bit-exactly through churn: the
    snapshot carries the slot map (user<->slot + FIFO clocks), the width-C
    inner server, the per-user tables and the cohort-sampling RNG position
    — and the restored run replays the identical admission/eviction
    sequence."""
    _assert_resume_bit_exact(tmp_path, "stacked", alg,
                             request_backend=backend, mutate=_sparse)


def test_sparse_snapshot_has_no_dense_ghost(tmp_path):
    """A C < U snapshot stores slot-resident state at width C and carries
    at width U — never a dense (U, N) contribution buffer."""
    xc = _sparse(_cfg(2, num_clients=16))
    run_vectorized_experiment("osafl", xc, eval_samples=16,
                              save_every_k=2, checkpoint_dir=tmp_path)
    sv = checkpoint.load_run_state(checkpoint_path(tmp_path, 2))["server"]
    assert sorted(sv) == ["inner", "pool", "tables"]
    assert sv["inner"]["d_buffer"].shape[0] == 4
    assert sv["pool"]["user_slot"].shape == (16,)
    assert sv["tables"]["scores"].shape == (16,)


def test_resume_rejects_mismatched_cohort_shape(tmp_path):
    """cohort_size/participation are part of the run shape: a sparse
    snapshot refuses both a dense resume and a different pool capacity."""
    xc = _sparse(_cfg(2, num_clients=16))
    run_vectorized_experiment("osafl", xc, eval_samples=16,
                              save_every_k=2, checkpoint_dir=tmp_path)
    ck = checkpoint_path(tmp_path, 2)
    with pytest.raises(CheckpointError, match="cohort_size"):
        run_vectorized_experiment(
            "osafl", dataclasses.replace(xc, cohort_size=8),
            eval_samples=16, resume_from=ck)
    with pytest.raises(CheckpointError, match="participation"):
        run_vectorized_experiment(
            "osafl", dataclasses.replace(xc, participation=1.0),
            eval_samples=16, resume_from=ck)
    with pytest.raises(CheckpointError, match="cohort_size"):
        run_vectorized_experiment(
            "osafl",
            dataclasses.replace(xc, cohort_size=0, participation=1.0),
            eval_samples=16, resume_from=ck)


@pytest.mark.slow
@pytest.mark.parametrize("engine", ["loop", "stacked"])
@pytest.mark.parametrize("alg", ALL_ALGS)
def test_resume_determinism_full_matrix(tmp_path, engine, alg):
    """Full cross-engine x algorithm resume matrix (slow tier)."""
    _assert_resume_bit_exact(tmp_path, engine, alg)


def test_resume_after_multiple_interruptions(tmp_path):
    """Chained resumes (2 interruptions) still match the uninterrupted run."""
    da, db = tmp_path / "full", tmp_path / "split"
    full = run_vectorized_experiment("osafl", _cfg(6), eval_samples=64,
                                     save_every_k=6, checkpoint_dir=da)
    run_vectorized_experiment("osafl", _cfg(2), eval_samples=64,
                              save_every_k=2, checkpoint_dir=db)
    run_vectorized_experiment("osafl", _cfg(4), eval_samples=64,
                              save_every_k=2, checkpoint_dir=db,
                              resume_from=checkpoint_path(db, 2))
    resumed = run_vectorized_experiment("osafl", _cfg(6), eval_samples=64,
                                        save_every_k=2, checkpoint_dir=db,
                                        resume_from=checkpoint_path(db, 4))
    for h_full, h_res in zip(full, resumed):
        assert h_full["test_loss"] == h_res["test_loss"]
        assert h_full["test_acc"] == h_res["test_acc"]
        assert h_full["participants"] == h_res["participants"]
    _assert_tree_equal(checkpoint.load_run_state(checkpoint_path(da, 6)),
                       checkpoint.load_run_state(checkpoint_path(db, 6)))


# ---------------------------------------------------------------------------
# snapshot round-trips of arbitrary buffer states (property tests)
# ---------------------------------------------------------------------------

def _fill(oracles, sbuf, counts_list, num_classes, counter=0):
    """Stage one burst per entry of counts_list into oracle + stacked buffers
    (committing after each), returning the running unique-sample counter."""
    U = len(oracles)
    for counts in counts_list:
        A = int(max(max(counts), 1))
        feat = oracles[0].x.shape[1:]
        xs = np.zeros((U, A) + feat, np.float32)
        ys = np.zeros((U, A), np.int64)
        for u, n in enumerate(counts):
            if n == 0:
                continue
            x = np.zeros((n,) + feat, np.float32)
            x[:, 0] = np.arange(counter, counter + n)
            y = (np.arange(counter, counter + n) % num_classes)
            counter += n
            oracles[u].stage(x, y)
            xs[u, :n], ys[u, :n] = x, y
        sbuf.stage(xs, ys, np.asarray(counts))
        for b in oracles:
            b.commit()
        sbuf.commit()
    return counter


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 9), st.integers(2, 9),
       st.lists(st.integers(0, 12), min_size=1, max_size=6),
       st.integers(0, 6))
def test_buffer_snapshot_roundtrip_arbitrary_states(cap0, cap1, bursts,
                                                    tail):
    """Snapshot -> save -> load -> restore round-trips arbitrary buffer
    states bit-exactly: wrapped heads, size == capacity, over-capacity
    commits, and staged-but-uncommitted arrivals — and the restored buffers
    continue in exact lockstep with the originals."""
    C = 7
    caps = np.array([cap0, cap1])
    oracles = [OnlineBuffer.create(int(c), (2,), C) for c in caps]
    sbuf = StackedOnlineBuffer.create(caps, (2,), C, stage_capacity=14)
    counts_list = [(n, (2 * n + 1) % 13) for n in bursts]
    counter = _fill(oracles, sbuf, counts_list, C)
    # a staged-but-uncommitted tail burst, asymmetric across clients
    tail_counts = (tail, (tail + 3) % 7)
    A = int(max(max(tail_counts), 1))
    xs = np.zeros((2, A, 2), np.float32)
    ys = np.zeros((2, A), np.int64)
    for u, n in enumerate(tail_counts):
        if n:
            xs[u, :n, 0] = np.arange(counter, counter + n)
            ys[u, :n] = np.arange(counter, counter + n) % C
            oracles[u].stage(xs[u, :n], ys[u, :n])
            counter += n
    sbuf.stage(xs, ys, np.asarray(tail_counts))

    with tempfile.TemporaryDirectory() as d:
        state = {"stacked": sbuf.state_dict(),
                 "oracles": [b.state_dict() for b in oracles]}
        checkpoint.save_run_state(d + "/snap", state)
        loaded = checkpoint.load_run_state(d + "/snap")

    sbuf2 = StackedOnlineBuffer.create(caps, (2,), C, stage_capacity=14)
    sbuf2.load_state_dict(loaded["stacked"])
    oracles2 = [OnlineBuffer.create(int(c), (2,), C) for c in caps]
    for b, sd in zip(oracles2, loaded["oracles"]):
        b.load_state_dict(sd)

    # round-trip is bit-exact, including the uncommitted staging area
    _assert_tree_equal(sbuf.state_dict(), sbuf2.state_dict(), skip=())
    for b, b2 in zip(oracles, oracles2):
        _assert_tree_equal(b.state_dict(), b2.state_dict(), skip=())

    # the staged tail commits identically on originals and restored copies
    for bufs in (oracles, oracles2):
        for b in bufs:
            b.commit()
    sbuf.commit()
    sbuf2.commit()
    for u in range(2):
        ox, oy = oracles[u].dataset()
        for restored in (sbuf, sbuf2):
            rx, ry = restored.dataset(u)
            assert np.array_equal(ox, rx) and np.array_equal(oy, ry)
        r2x, r2y = oracles2[u].dataset()
        assert np.array_equal(ox, r2x) and np.array_equal(oy, r2y)
        assert oracles[u].size == oracles2[u].size == sbuf2.sizes[u]
        assert oracles[u].head == oracles2[u].head == sbuf2.heads[u]


def test_slot_pool_runstate_roundtrip_half_full(tmp_path):
    """A half-full slot pool (free slots, an eviction hole, live FIFO
    clocks) survives the npz RunState round-trip bit-exactly and the
    restored pool continues identically to the original."""
    pool = SlotPool(10, 4)
    pool.admit([7, 2, 5])
    pool.evict([2])                       # a freed hole mid-pool
    assert pool.occupancy == 2 < pool.C
    checkpoint.save_run_state(tmp_path / "s", {"pool": pool.state_dict()})
    loaded = checkpoint.load_run_state(tmp_path / "s")["pool"]
    clone = SlotPool(10, 4)
    clone.load_state_dict(loaded)
    for k, v in clone.state_dict().items():
        np.testing.assert_array_equal(v, pool.state_dict()[k])
    # identical continuations: refill past capacity on both copies
    for p in (pool, clone):
        res = p.admit([1, 2, 3, 4])       # forces FIFO evictions, in
        assert res.evicted.tolist() == [7, 5]   # seating order
        p.check()
    np.testing.assert_array_equal(clone.user_slot, pool.user_slot)
    np.testing.assert_array_equal(clone.slot_user, pool.slot_user)


# ---------------------------------------------------------------------------
# RunState codec + Generator streams
# ---------------------------------------------------------------------------

def test_run_state_roundtrip_mixed_tree(tmp_path):
    state = {"i": 3, "f": 0.25, "b": True, "none": None, "s": "osafl",
             "big": 2 ** 97 + 13,          # PCG64 state words are 128-bit
             "f16": np.arange(6, dtype=np.float16).reshape(2, 3),
             "bools": np.array([True, False]),
             "nested": [{"k": np.int64(5)}, [1.5, None, "x"]],
             "dev": jnp.ones((3,), jnp.float32)}
    checkpoint.save_run_state(tmp_path / "s", state,
                              metadata={"note": "mixed"})
    out = checkpoint.load_run_state(tmp_path / "s")
    assert out["i"] == 3 and out["f"] == 0.25 and out["b"] is True
    assert out["none"] is None and out["s"] == "osafl"
    assert out["big"] == 2 ** 97 + 13
    assert out["f16"].dtype == np.float16
    np.testing.assert_array_equal(out["f16"], state["f16"])
    assert out["bools"].dtype == np.bool_
    assert out["nested"][0]["k"] == 5
    assert out["nested"][1] == [1.5, None, "x"]
    assert out["dev"].dtype == np.float32
    np.testing.assert_array_equal(out["dev"], np.ones(3))


def test_run_state_overwrite_is_atomic_and_clean(tmp_path):
    """Re-saving at the same path replaces the snapshot and leaves no temp
    files behind (saves go through temp + os.replace so an interrupted save
    can never tear a previously valid snapshot)."""
    checkpoint.save_run_state(tmp_path / "s", {"x": np.arange(3)})
    checkpoint.save_run_state(tmp_path / "s", {"x": np.arange(5)})
    out = checkpoint.load_run_state(tmp_path / "s")
    np.testing.assert_array_equal(out["x"], np.arange(5))
    leftovers = [p.name for p in tmp_path.iterdir()
                 if p.name.startswith(".tmp.")]
    assert leftovers == []


def test_torn_snapshot_pair_detected(tmp_path):
    """An overwrite interrupted between the two atomic replaces leaves the
    new npz next to the old sidecar; because consecutive snapshots of one
    run share identical tree paths this used to decode silently — the
    shared save id now rejects the mixed pair."""
    checkpoint.save_run_state(tmp_path / "s", {"x": np.arange(3)})
    stale_sidecar = (tmp_path / "s.meta.json").read_text()
    checkpoint.save_run_state(tmp_path / "s", {"x": np.arange(3) + 7})
    (tmp_path / "s.meta.json").write_text(stale_sidecar)
    with pytest.raises(CheckpointError, match="different saves"):
        checkpoint.load_run_state(tmp_path / "s")
    # one-sided case: a *pre-save_id* stale sidecar next to a new npz is
    # the same tear and must not slip through the legacy allowance ...
    meta = json.loads(stale_sidecar)
    del meta["save_id"]
    (tmp_path / "s.meta.json").write_text(json.dumps(meta))
    with pytest.raises(CheckpointError, match="different saves"):
        checkpoint.load_run_state(tmp_path / "s")
    # ... while a fully legacy snapshot (id on neither side) still loads
    checkpoint.save_run_state(tmp_path / "legacy", {"x": np.arange(4)})
    mp = tmp_path / "legacy.meta.json"
    meta = json.loads(mp.read_text())
    del meta["save_id"]
    mp.write_text(json.dumps(meta))
    with np.load(tmp_path / "legacy.npz") as data:
        arrays = {k: v for k, v in data.items() if k != "__save_id__"}
    np.savez(tmp_path / "legacy.npz", **arrays)
    out = checkpoint.load_run_state(tmp_path / "legacy")
    np.testing.assert_array_equal(out["x"], np.arange(4))


def test_run_state_missing_array_key_raises_checkpoint_error(tmp_path):
    """A sidecar/npz mismatch (torn or mixed-up save) surfaces as
    CheckpointError naming the key, not a bare KeyError."""
    checkpoint.save_run_state(tmp_path / "s", {"x": np.arange(3)})
    mp = tmp_path / "s.meta.json"
    meta = json.loads(mp.read_text())
    meta["tree"]["x"] = {"__array__": "s/gone"}
    mp.write_text(json.dumps(meta))
    with pytest.raises(CheckpointError, match="s/gone"):
        checkpoint.load_run_state(tmp_path / "s")


def test_run_state_rejects_unserializable(tmp_path):
    with pytest.raises(CheckpointError, match="cannot serialize"):
        checkpoint.save_run_state(tmp_path / "s", {"bad": object()})
    with pytest.raises(CheckpointError, match="reserved"):
        checkpoint.save_run_state(tmp_path / "s", {"__array__": 1})
    with pytest.raises(CheckpointError, match="strings"):
        checkpoint.save_run_state(tmp_path / "s", {"d": {1: 2}})


def test_generator_state_roundtrip_mid_stream():
    rng = np.random.default_rng(7)
    rng.normal(size=5)                      # advance mid-stream
    snap = checkpoint.generator_state(rng)
    expect = rng.normal(size=8)
    fresh = np.random.default_rng(0)
    checkpoint.set_generator_state(fresh, snap)
    np.testing.assert_array_equal(expect, fresh.normal(size=8))
    # the snapshot survives a JSON round-trip (that's how it is persisted)
    fresh2 = np.random.default_rng(0)
    checkpoint.set_generator_state(fresh2, json.loads(json.dumps(snap)))
    np.testing.assert_array_equal(expect, fresh2.normal(size=8))


# ---------------------------------------------------------------------------
# failure paths: structure/dtype mismatch, sidecar, format versions
# ---------------------------------------------------------------------------

def test_restore_reports_missing_and_extra_keys(tmp_path):
    params = {"a": np.zeros(3, np.float32), "b": np.ones(2, np.float32)}
    checkpoint.save(tmp_path / "p", params)
    like = {"a": np.zeros(3, np.float32), "c": np.zeros(2, np.float32)}
    with pytest.raises(CheckpointError) as ei:
        checkpoint.restore(tmp_path / "p", like)
    msg = str(ei.value)
    assert "missing" in msg and "c" in msg
    assert "extra" in msg and "b" in msg


def test_restore_reports_dtype_mismatch(tmp_path):
    params = {"a": np.zeros(3, np.float32)}
    checkpoint.save(tmp_path / "p", params)
    like = {"a": np.zeros(3, np.float64)}
    with pytest.raises(CheckpointError, match="dtype"):
        checkpoint.restore(tmp_path / "p", like)


def test_restore_missing_file(tmp_path):
    with pytest.raises(CheckpointError, match="not found"):
        checkpoint.restore(tmp_path / "nope", {"a": np.zeros(1)})


def test_load_metadata_missing_sidecar(tmp_path):
    with pytest.raises(CheckpointError, match="sidecar"):
        checkpoint.load_metadata(tmp_path / "nope")


def test_params_checkpoint_still_roundtrips_without_sidecar(tmp_path):
    """Legacy checkpoints (bare npz, no sidecar) keep loading."""
    params = init_small(jax.random.PRNGKey(0), "mlp")
    checkpoint.save(tmp_path / "p", params, step=3)
    (tmp_path / "p.meta.json").unlink()
    restored = checkpoint.restore(tmp_path / "p", params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _bump_version(meta_file, version):
    meta = json.loads(meta_file.read_text())
    meta["format_version"] = version
    meta_file.write_text(json.dumps(meta))


def test_future_params_version_fails_loudly(tmp_path):
    params = {"a": np.zeros(3, np.float32)}
    checkpoint.save(tmp_path / "p", params)
    _bump_version(tmp_path / "p.meta.json", checkpoint.FORMAT_VERSION + 1)
    with pytest.raises(CheckpointError, match="format_version"):
        checkpoint.restore(tmp_path / "p", params)
    # the '.npz'-suffixed path form resolves to the same sidecar and is
    # version-checked too
    with pytest.raises(CheckpointError, match="format_version"):
        checkpoint.restore(str(tmp_path / "p") + ".npz", params)
    with pytest.raises(CheckpointError, match="format_version"):
        checkpoint.load_metadata(tmp_path / "p")


def test_future_run_state_version_fails_loudly(tmp_path):
    checkpoint.save_run_state(tmp_path / "s", {"x": np.arange(3)})
    _bump_version(tmp_path / "s.meta.json", checkpoint.FORMAT_VERSION + 1)
    with pytest.raises(CheckpointError, match="format_version"):
        checkpoint.load_run_state(tmp_path / "s")


def test_legacy_npz_suffixed_sidecar_still_found(tmp_path):
    """Pre-RunState saves appended '.meta.json' to the caller's path
    verbatim, so '.npz'-suffixed saves left the sidecar at
    '<file>.npz.meta.json' — both locations must keep loading, with the
    version check applied there too."""
    params = {"a": np.zeros(3, np.float32)}
    checkpoint.save(tmp_path / "p.npz", params, step=4)
    (tmp_path / "p.meta.json").rename(tmp_path / "p.npz.meta.json")
    assert checkpoint.load_metadata(tmp_path / "p.npz")["step"] == 4
    assert checkpoint.load_metadata(tmp_path / "p")["step"] == 4
    _bump_version(tmp_path / "p.npz.meta.json",
                  checkpoint.FORMAT_VERSION + 1)
    with pytest.raises(CheckpointError, match="format_version"):
        checkpoint.restore(tmp_path / "p.npz", params)


def test_run_state_rejects_params_checkpoint(tmp_path):
    """A params-only checkpoint is not silently reinterpreted as RunState."""
    checkpoint.save(tmp_path / "p", {"a": np.zeros(3, np.float32)})
    with pytest.raises(CheckpointError, match="params"):
        checkpoint.load_run_state(tmp_path / "p")


# ---------------------------------------------------------------------------
# harness guard rails
# ---------------------------------------------------------------------------

def test_resume_rejects_mismatched_run_shape(tmp_path):
    xc = _cfg(1, num_clients=4)
    run_vectorized_experiment("osafl", xc, eval_samples=16,
                              save_every_k=1, checkpoint_dir=tmp_path)
    ck = checkpoint_path(tmp_path, 1)
    with pytest.raises(CheckpointError, match="resume"):   # engine mismatch
        run_experiment("osafl", _cfg(2, num_clients=4), eval_samples=16,
                       resume_from=ck)
    with pytest.raises(CheckpointError, match="resume"):   # alg mismatch
        run_vectorized_experiment("fedavg", _cfg(2, num_clients=4),
                                  eval_samples=16, resume_from=ck)
    with pytest.raises(CheckpointError, match="resume"):   # cohort mismatch
        run_vectorized_experiment("osafl", _cfg(2, num_clients=5),
                                  eval_samples=16, resume_from=ck)
    with pytest.raises(CheckpointError, match="seed"):     # seed mismatch
        run_vectorized_experiment(
            "osafl", dataclasses.replace(_cfg(2, num_clients=4), seed=99),
            eval_samples=16, resume_from=ck)
    with pytest.raises(CheckpointError, match="model"):    # model mismatch
        run_vectorized_experiment(
            "osafl",
            dataclasses.replace(_cfg(2, num_clients=4), model="lstm"),
            eval_samples=16, resume_from=ck)
    with pytest.raises(CheckpointError, match="eval_samples"):
        run_vectorized_experiment("osafl", _cfg(2, num_clients=4),
                                  eval_samples=32, resume_from=ck)


def test_resume_accepts_snapshot_predating_new_config_fields(tmp_path):
    """Config fields added after a snapshot was written (e.g. PR 4's
    request_backend) are absent from its saved config; the run that wrote
    it behaved like the default, so resume must treat it as the default
    instead of refusing every pre-existing checkpoint."""
    xc = _cfg(2, num_clients=4)
    # checkpoint_async=False: this test edits the v1 sidecar in place, so it
    # needs the blocking v1 writer (and doubles as harness-level coverage of
    # the v1 write path now that the default is the streaming v2 writer)
    run_vectorized_experiment("osafl", xc, eval_samples=16,
                              save_every_k=1, checkpoint_dir=tmp_path,
                              checkpoint_async=False)
    ck = checkpoint_path(tmp_path, 1)
    mp = checkpoint.meta_path(ck)
    meta = json.loads(mp.read_text())
    removed = meta["tree"]["config"].pop("request_backend")
    assert removed == "python"
    mp.write_text(json.dumps(meta))
    resumed = run_vectorized_experiment("osafl", xc, eval_samples=16,
                                        resume_from=ck)
    assert [h["round"] for h in resumed] == [0, 1]
    # a non-default run still refuses the legacy snapshot
    with pytest.raises(CheckpointError, match="request_backend"):
        run_vectorized_experiment(
            "osafl", dataclasses.replace(xc, request_backend="stacked"),
            eval_samples=16, resume_from=ck)


def test_save_every_k_and_checkpoint_dir_must_pair(tmp_path):
    with pytest.raises(ValueError, match="checkpoint_dir"):
        run_vectorized_experiment("osafl", _cfg(1, num_clients=4),
                                  eval_samples=16, save_every_k=1)
    # the inverse — a checkpoint_dir that would silently never be written —
    # is rejected too
    with pytest.raises(ValueError, match="save_every_k"):
        run_vectorized_experiment("osafl", _cfg(1, num_clients=4),
                                  eval_samples=16, checkpoint_dir=tmp_path)
