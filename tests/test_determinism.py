"""Same-seed rerun determinism: every engine, run twice from scratch with
the same config, must produce bit-identical histories AND a bit-identical
final RunState snapshot (params, contribution buffers, FIFO buffers, RNG
streams). This is the foundation the parity anchors, checkpoint resume,
the scenario null-parity guarantee and the golden-curve pins all stand on
— a single unordered set, wall-clock-dependent draw, or device
nondeterminism shows up here first.

Covered engines: loop oracle, vectorized dispatch, pod (1-device mesh),
fused single-dispatch, sparse cohort (slot pool + participation sampling),
and a composed-scenario run (the scenario streams must be as deterministic
as the host RNG they sit beside).
"""
from __future__ import annotations

import numpy as np
import pytest

from benchmarks.common import (ExperimentConfig, checkpoint_path,
                               run_experiment, run_pod_online_experiment,
                               run_vectorized_experiment)
from repro import checkpoint

ROUNDS = 3

_BASE = dict(model="mlp", dataset=2, num_clients=6, rounds=ROUNDS,
             capacity=(12, 24), arrivals=4, batch=8, seed=11)

ENGINES = {
    "loop": (run_experiment, {}),
    "vectorized": (run_vectorized_experiment, {}),
    "pod": (run_pod_online_experiment, {}),
    "fused": (run_vectorized_experiment,
              dict(request_backend="stacked", round_backend="fused")),
    "cohort": (run_vectorized_experiment,
               dict(cohort_size=4, participation=0.75)),
    "scenario": (run_vectorized_experiment,
                 dict(cohort_size=4, participation=0.75,
                      scenario="churn(p_away=0.5,period=3,away=1)"
                               "+flash_crowd(period=2,duty=1,scale=2)"
                               "+pareto_select()")),
}

# wall-clock fields are the only legitimate rerun difference
_TIMING = ("round_s", "request_gen_s")


def _metrics(history):
    return [{k: v for k, v in h.items() if k not in _TIMING}
            for h in history]


def _flat(prefix, obj, out):
    if isinstance(obj, dict):
        for k in sorted(obj):
            _flat(f"{prefix}/{k}", obj[k], out)
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            _flat(f"{prefix}/{i}", v, out)
    else:
        out[prefix] = np.asarray(obj)


def _run_once(name, tmp_path, tag):
    fn, overrides = ENGINES[name]
    xc = ExperimentConfig(**dict(_BASE, **overrides))
    ckpt_dir = tmp_path / f"{name}-{tag}"
    hist = fn("osafl", xc, save_every_k=ROUNDS, checkpoint_dir=ckpt_dir)
    snap = checkpoint.load_run_state(checkpoint_path(ckpt_dir, ROUNDS))
    state = {}
    for key in ("server", "buffer", "buffers", "streams", "rng"):
        if key in snap:
            _flat(key, snap[key], state)
    return _metrics(hist), state


@pytest.mark.parametrize("name", sorted(ENGINES))
def test_same_seed_rerun_is_bit_identical(name, tmp_path):
    h1, s1 = _run_once(name, tmp_path, "a")
    h2, s2 = _run_once(name, tmp_path, "b")
    assert h1 == h2, f"{name}: histories diverged between identical reruns"
    assert len(h1) == ROUNDS
    assert sorted(s1) == sorted(s2)
    diverged = [k for k in s1 if not np.array_equal(s1[k], s2[k])]
    assert not diverged, (
        f"{name}: final state diverged between identical reruns at "
        f"{diverged[:10]}")
