"""Stacked-client engine: exact parity against the loop reference servers,
fused-kernel edge cases, vmapped local training, and the 256-client smoke."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs.base import FLConfig
from repro.core.baselines import (SERVERS, STACKED_SERVERS, make_server)
from repro.core.client import _sgd_step, make_vmapped_local_train
from repro.core.flatten import make_codec
from repro.core.osafl import ClientUpdate, OSAFLServer, StackedOSAFLServer
from repro.core.scores import lambda_scores, lambda_scores_sketched
from repro.kernels.ref import osafl_scores_reference
from repro.kernels.scored_reduce import osafl_scores_fused, scored_reduce


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    return {"a": scale * jax.random.normal(k1, (13,)),
            "b": scale * jax.random.normal(k2, (4, 5))}


def _assert_trees_close(a, b, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


# --------------------------------------------------------------------------
# flatten codec
# --------------------------------------------------------------------------

def test_codec_roundtrip_preserves_structure_and_dtype():
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.float32(3.5), "d": jnp.ones((4,), jnp.float32)}}
    codec = make_codec(tree)
    assert codec.n == 11
    back = codec.unflatten(codec.flatten(tree))
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for x, y in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32))


def test_codec_stacked_flatten_matches_per_row():
    codec = make_codec(_tree(0))
    trees = [_tree(i) for i in range(4)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    mat = codec.flatten_stacked(stacked)
    for u, t in enumerate(trees):
        np.testing.assert_allclose(np.asarray(mat[u]),
                                   np.asarray(codec.flatten(t)), atol=1e-7)


# --------------------------------------------------------------------------
# score parity: loop lambda_scores vs fused kernel vs sketched
# --------------------------------------------------------------------------

def test_loop_vs_fused_vs_reference_scores():
    updates = [_tree(i, scale=1 + 0.3 * i) for i in range(7)]
    codec = make_codec(updates[0])
    stacked = jnp.stack([codec.flatten(d) for d in updates])
    lam_loop = lambda_scores(updates, chi=1.0)
    lam_fused = np.asarray(osafl_scores_fused(stacked, chi=1.0))
    lam_ref = np.asarray(osafl_scores_reference(stacked, chi=1.0))
    np.testing.assert_allclose(lam_loop, lam_fused, atol=1e-5)
    np.testing.assert_allclose(lam_fused, lam_ref, atol=1e-6)


def test_sketched_scores_track_exact_on_stacked_rows():
    from repro.core.scores import sketch_stacked
    updates = [_tree(i, scale=1 + 0.2 * i) for i in range(6)]
    codec = make_codec(updates[0])
    stacked = jnp.stack([codec.flatten(d) for d in updates])
    lam = np.asarray(osafl_scores_fused(stacked, chi=1.0))
    sk = sketch_stacked(stacked, jax.random.PRNGKey(0), 64)
    lam_sk = lambda_scores_sketched(sk, chi=1.0)
    assert np.corrcoef(lam, lam_sk)[0, 1] > 0.5 or np.allclose(
        lam, lam_sk, atol=0.15)


# --------------------------------------------------------------------------
# fused kernel edge cases
# --------------------------------------------------------------------------

@pytest.mark.parametrize("U,N,block,block_u", [
    (3, 1000, 384, None),   # N not divisible by block_n
    (1, 257, 64, None),     # single client
    (5, 7, 2048, None),     # block larger than N
    (7, 500, 128, 3),       # U not divisible by block_u (TPU cohort tiling)
    (9, 300, 64, 2),        # both dimensions ragged
])
def test_scored_reduce_edge_shapes(U, N, block, block_u):
    d = jax.random.normal(jax.random.PRNGKey(0), (U, N))
    mean = jnp.mean(d, axis=0)
    dots, norms, msq = scored_reduce(d, mean, block_n=block, block_u=block_u)
    np.testing.assert_allclose(np.asarray(dots), np.asarray(d @ mean),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(norms),
                               np.asarray(jnp.sum(d * d, axis=1)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(msq), float(jnp.sum(mean * mean)),
                               rtol=1e-4)


def test_single_client_scores_one():
    d = jax.random.normal(jax.random.PRNGKey(1), (1, 513))
    lam = np.asarray(osafl_scores_fused(d, chi=1.0))
    np.testing.assert_allclose(lam, 1.0, atol=1e-6)


def test_zero_updates_hit_eps_guard():
    """All-zero buffer: cos must resolve to 0 (not nan), lambda = chi/(chi+1),
    matching the loop lambda_scores guard."""
    d = jnp.zeros((4, 100))
    lam = np.asarray(osafl_scores_fused(d, chi=1.0))
    assert np.all(np.isfinite(lam))
    np.testing.assert_allclose(lam, 0.5, atol=1e-6)
    zeros = [jax.tree.map(jnp.zeros_like, _tree(0)) for _ in range(4)]
    np.testing.assert_allclose(lambda_scores(zeros, chi=1.0), lam, atol=1e-6)


@given(st.integers(1, 9), st.floats(0.5, 8.0))
@settings(max_examples=15, deadline=None)
def test_fused_lambda_in_unit_interval(u, chi):
    d = jax.random.normal(jax.random.PRNGKey(u), (u, 301 + 7 * u))
    lam = np.asarray(osafl_scores_fused(d, chi=chi))
    assert np.all(lam >= 0.0) and np.all(lam <= 1.0)


# --------------------------------------------------------------------------
# round parity: loop servers vs stacked servers (<= 1e-5), sparse updates,
# partial participation, multiple rounds
# --------------------------------------------------------------------------

def _random_rounds(loop_srv, stacked_srv, num_clients, rounds=4, seed=0,
                   with_meta=False):
    rng = np.random.default_rng(seed)
    for r in range(rounds):
        ups = []
        for u in rng.choice(num_clients, size=rng.integers(1, num_clients),
                            replace=False):
            h = None
            if with_meta:
                h = np.zeros(10)
                h[int(u) % 10] = 1.0
            ups.append(ClientUpdate(
                int(u), _tree(1000 * r + int(u)),
                kappa=int(rng.integers(1, 5)),
                data_size=int(rng.integers(5, 50)), label_hist=h))
        a, b = loop_srv.round(ups), stacked_srv.round(ups)
        _assert_trees_close(a, b, atol=1e-5)


@pytest.mark.parametrize("variant", [
    {}, {"stale_scores": True}, {"literal_init_buffer": True},
    {"score_backend": "reference"}, {"chi": 3.0},
])
def test_osafl_stacked_round_matches_loop(variant):
    params = _tree(42)
    fl = FLConfig(num_clients=5, local_lr=0.1, global_lr=2.0, **variant)
    loop = OSAFLServer(params, fl, 5)
    stacked = StackedOSAFLServer(params, fl, 5)
    _random_rounds(loop, stacked, 5)
    np.testing.assert_allclose(loop.last_scores, stacked.last_scores,
                               atol=1e-5)


def test_osafl_stacked_sketched_round_is_valid():
    """Sketched scores differ between tree- and row-layout (leaf split), so
    the contract is lambda validity, not bitwise parity."""
    params = _tree(3)
    fl = FLConfig(num_clients=4, local_lr=0.1, score_sketch_dim=32)
    srv = StackedOSAFLServer(params, fl, 4)
    srv.round([ClientUpdate(i, _tree(i), 1) for i in range(4)])
    assert np.all(srv.last_scores >= 0) and np.all(srv.last_scores <= 1)


@pytest.mark.parametrize("alg", sorted(STACKED_SERVERS))
def test_stacked_baselines_match_loop(alg):
    params = _tree(7)
    fl = FLConfig(num_clients=4, local_lr=0.1, global_lr=2.0, algorithm=alg)
    loop = SERVERS[alg](params, fl, 4)
    stacked = STACKED_SERVERS[alg](params, fl, 4)
    _random_rounds(loop, stacked, 4, with_meta=(alg == "feddisco"))


def test_make_server_engine_selection():
    params = _tree(0)
    assert isinstance(
        make_server(params, FLConfig(engine="stacked"), 2), StackedOSAFLServer)
    assert isinstance(
        make_server(params, FLConfig(engine="stacked", algorithm="fedavg"), 2),
        STACKED_SERVERS["fedavg"])
    assert isinstance(make_server(params, FLConfig(), 2), OSAFLServer)


def test_stacked_accepts_preflattened_rows():
    params = _tree(11)
    fl = FLConfig(num_clients=3, local_lr=0.1)
    srv = StackedOSAFLServer(params, fl, 3)
    row = np.asarray(srv.codec.flatten(_tree(5)))
    srv.round([ClientUpdate(0, row, 1), ClientUpdate(1, _tree(5), 1)])
    np.testing.assert_allclose(np.asarray(srv.d_buffer[0]),
                               np.asarray(srv.d_buffer[1]), atol=1e-7)


# --------------------------------------------------------------------------
# vmapped local training == loop local SGD on the same batch sequence
# --------------------------------------------------------------------------

@pytest.mark.parametrize("prox_mu", [0.0, 0.9])
def test_vmapped_local_train_matches_loop_steps(prox_mu):
    from repro.models.small import init_small, small_loss
    rng = np.random.default_rng(2)
    grad_fn = jax.grad(lambda p, b: small_loss(p, b, "mlp")[0])
    gp = init_small(jax.random.PRNGKey(0), "mlp")
    U, K, B = 3, 4, 8
    bx = rng.integers(0, 100, (U, K, B, 10))
    by = rng.integers(0, 100, (U, K, B))
    kappas = [4, 2, 0]
    fn = make_vmapped_local_train(grad_fn, 0.1, K, prox_mu=prox_mu)
    d, w = fn(gp, {"x": jnp.asarray(bx), "y": jnp.asarray(by)},
              jnp.asarray(kappas))
    for u, ku in enumerate(kappas):
        p = gp
        for t in range(ku):
            p = _sgd_step(p, {"x": jnp.asarray(bx[u, t]),
                              "y": jnp.asarray(by[u, t])}, 0.1, grad_fn,
                          prox_mu=prox_mu,
                          global_params=gp if prox_mu else None)
        d_ref = jax.tree.map(lambda a, b_: (a - b_) / (0.1 * max(ku, 1)),
                             gp, p)
        _assert_trees_close(jax.tree.map(lambda l: l[u], d), d_ref, atol=2e-5)
        _assert_trees_close(jax.tree.map(lambda l: l[u], w), p, atol=2e-5)


def test_straggler_contributes_zero_update():
    from repro.models.small import init_small, small_loss
    grad_fn = jax.grad(lambda p, b: small_loss(p, b, "mlp")[0])
    gp = init_small(jax.random.PRNGKey(0), "mlp")
    fn = make_vmapped_local_train(grad_fn, 0.1, 3)
    bx = jnp.zeros((2, 3, 4, 10), jnp.int32)
    by = jnp.zeros((2, 3, 4), jnp.int32)
    d, _ = fn(gp, {"x": bx, "y": by}, jnp.asarray([0, 3]))
    for leaf in jax.tree.leaves(jax.tree.map(lambda l: l[0], d)):
        np.testing.assert_allclose(np.asarray(leaf), 0.0, atol=1e-7)


# --------------------------------------------------------------------------
# end-to-end: 256-client vectorized simulation completes in seconds
# --------------------------------------------------------------------------

def test_vectorized_simulation_256_clients_smoke():
    from benchmarks.common import ExperimentConfig, run_vectorized_experiment
    xc = ExperimentConfig(model="mlp", dataset=2, num_clients=256, rounds=2,
                          capacity=(64, 64), batch=8)
    t0 = time.time()
    hist = run_vectorized_experiment("osafl", xc, eval_samples=256)
    elapsed = time.time() - t0
    assert len(hist) == 2
    assert all(np.isfinite(h["test_loss"]) for h in hist)
    assert hist[-1]["participants"] > 0
    # generous bound: cold CI runners pay jit compilation; the sharp >=10x
    # perf claim lives in the slow-marked benchmark test below
    assert elapsed < 180, f"256-client vectorized run took {elapsed:.1f}s"


@pytest.mark.slow
def test_stacked_round_is_10x_faster_than_loop():
    from benchmarks.bench_stacked import bench
    r = bench(U=256, rounds=3)
    assert r["max_param_drift"] < 1e-5
    assert r["speedup"] >= 10.0, r
