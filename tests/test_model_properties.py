"""Property tests on model-component invariants (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import apply_rope, rmsnorm, init_rmsnorm
from repro.models.moe import init_moe, moe_fwd, _capacity
from repro.models import ssm as ssm_lib


# --- RoPE ---------------------------------------------------------------

@given(st.integers(1, 3), st.integers(2, 16), st.sampled_from([32, 64]))
@settings(max_examples=15, deadline=None)
def test_rope_preserves_norm(b, s, d):
    """Rotations preserve the per-pair L2 norm of q/k vectors."""
    x = jax.random.normal(jax.random.PRNGKey(0), (b, s, 2, d))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_rope_relative_position_property():
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    d = 64
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, d))

    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]))
        kj = apply_rope(k, jnp.array([[j]]))
        return float(jnp.vdot(qi, kj))
    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(0, 0) == pytest.approx(dot_at(100, 100), rel=1e-4)


# --- RMSNorm ------------------------------------------------------------

@given(st.floats(0.25, 4.0))
@settings(max_examples=15, deadline=None)
def test_rmsnorm_scale_invariance(scale):
    """rmsnorm(c*x) ~= rmsnorm(x) for positive scalar c (up to the eps
    regularizer, which breaks exact invariance by design)."""
    p = init_rmsnorm(16)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    a = rmsnorm(p, x)
    b = rmsnorm(p, scale * x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


# --- MoE ----------------------------------------------------------------

def _moe_cfg(E=4, k=2, cap=50.0):
    base = get_config("arctic-480b").reduced()
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, num_experts=E, top_k=k,
                                      capacity_factor=cap,
                                      dense_residual_d_ff=0))


def test_moe_no_drop_matches_dense_mixture():
    """With huge capacity, the sort-dispatch MoE must equal the naive
    'compute every expert, mix by gates' reference."""
    cfg = _moe_cfg()
    m = cfg.moe
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_fwd(params, x, cfg)

    # naive reference
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top, idx = jax.lax.top_k(probs, m.top_k)
    top = top / top.sum(-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for e in range(m.num_experts):
        h = jax.nn.silu(xt @ params["w_gate"][e]) * (xt @ params["w_up"][e])
        ye = h @ params["w_down"][e]
        w = jnp.where(idx == e, top, 0.0).sum(-1)
        out = out + w[:, None] * ye
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(out), atol=2e-4, rtol=2e-3)
    assert float(aux) >= 0.0


@given(st.integers(4, 64), st.integers(1, 4), st.integers(2, 8),
       st.floats(1.0, 2.0))
@settings(max_examples=25, deadline=None)
def test_moe_capacity_bounds(T, k, E, factor):
    c = _capacity(T, k, E, factor)
    assert 1 <= c <= T


def test_moe_aux_loss_penalizes_imbalance():
    """Forcing all tokens to one expert must raise the aux loss vs uniform."""
    cfg = _moe_cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    _, aux_uniform = moe_fwd(params, x, cfg)
    # bias the logits through the input: all-positive tokens + a large
    # positive router column make expert 0 the top-1 for every token
    biased = dict(params)
    bias = jnp.zeros_like(params["router"]).at[:, 0].set(50.0)
    biased["router"] = params["router"] + bias
    x_pos = jnp.abs(x) + 1.0
    _, aux_biased = moe_fwd(biased, x_pos, cfg)
    _, aux_pos_uniform = moe_fwd(params, x_pos, cfg)
    assert float(aux_biased) > float(aux_pos_uniform)


# --- Mamba2 chunked == different chunk sizes -------------------------------

@pytest.mark.parametrize("chunks", [(8, 16), (16, 32)])
def test_mamba_chunk_size_invariance(chunks):
    """The chunked SSD result must not depend on the chunk size."""
    base = get_config("zamba2-2.7b").reduced()
    cfg1 = dataclasses.replace(base, ssm=dataclasses.replace(
        base.ssm, chunk_size=chunks[0]), dtype="float32")
    cfg2 = dataclasses.replace(base, ssm=dataclasses.replace(
        base.ssm, chunk_size=chunks[1]), dtype="float32")
    params = ssm_lib.init_mamba(jax.random.PRNGKey(0), cfg1)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg1.d_model))
    y1 = ssm_lib.mamba_fwd(params, x, cfg1)
    y2 = ssm_lib.mamba_fwd(params, x, cfg2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-3)


# --- mLSTM chunked == quadratic ---------------------------------------------

@pytest.mark.parametrize("L,Q", [(64, 16), (96, 32), (128, 64)])
def test_mlstm_chunked_matches_quadratic(L, Q):
    """The chunkwise-stabilized mLSTM must equal the quadratic parallel form
    (it replaces it for long prefill, §Perf)."""
    base = get_config("xlstm-350m").reduced()
    cfg = dataclasses.replace(base, dtype="float32",
                              ssm=dataclasses.replace(base.ssm, chunk_size=Q))
    params = ssm_lib.init_mlstm(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, L, cfg.d_model))
    yq = ssm_lib._mlstm_fwd_quadratic(params, x, cfg)
    yc = ssm_lib.mlstm_fwd_chunked(params, x, cfg)
    np.testing.assert_allclose(np.asarray(yq), np.asarray(yc),
                               atol=1e-5, rtol=1e-4)
