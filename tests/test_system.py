"""End-to-end system tests: full OSAFL rounds (resource optimization ->
time-varying buffers -> local training -> scored aggregation) on the paper's
video-caching task, plus checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs.base import FLConfig
from repro.core.baselines import make_server
from repro.core.buffer import OnlineBuffer, binomial_arrivals
from repro.core.client import local_train
from repro.core.osafl import ClientUpdate
from repro.core.resource import NetworkConfig, make_clients, optimize_round
from repro.data.video_caching import D1_DIM, make_population
from repro.models.small import init_small, small_loss


def _setup(u=4, cap=60, seed=0):
    cat, streams = make_population(seed, u)
    bufs = []
    for s in streams:
        buf = OnlineBuffer.create(cap, (D1_DIM,), 100)
        x, y = s.draw_dataset1(cap)
        buf.stage(x, y)
        buf.commit()
        bufs.append(buf)
    return streams, bufs


def _run_fl(alg, rounds=8, u=4, seed=0):
    streams, bufs = _setup(u=u, seed=seed)
    rng = np.random.default_rng(seed)
    grad_fn = jax.grad(lambda p, b: small_loss(p, b, "fcn")[0])
    params = init_small(jax.random.PRNGKey(seed), "fcn")
    fl = FLConfig(num_clients=u, local_lr=0.05, global_lr=2.0, algorithm=alg)
    server = make_server(params, fl, u)
    for t in range(rounds):
        updates = []
        for c in range(u):
            n = binomial_arrivals(rng, 8, streams[c].user.p_ac)
            if n:
                x, y = streams[c].draw_dataset1(n)
                bufs[c].stage(x, y)
            bufs[c].commit()
            kappa = int(rng.integers(1, 5))
            d, w = local_train(
                server.params, grad_fn, bufs[c], kappa, fl.local_lr, 16, rng,
                prox_mu=fl.fedprox_mu if alg == "fedprox" else 0.0)
            upd = d if alg in ("osafl", "fednova", "afa_cd") else w
            updates.append(ClientUpdate(c, upd, kappa,
                                        data_size=bufs[c].size,
                                        label_hist=bufs[c].label_histogram()))
        server.round(updates)
    # evaluate on pooled client data
    xs, ys = zip(*[b.dataset() for b in bufs])
    batch = {"x": jnp.asarray(np.concatenate(xs)),
             "y": jnp.asarray(np.concatenate(ys))}
    loss, m = small_loss(server.params, batch, "fcn")
    return float(loss), float(m["accuracy"]), server


def test_osafl_end_to_end_learns():
    loss, acc, server = _run_fl("osafl", rounds=10)
    assert np.isfinite(loss)
    assert loss < 4.6                     # started at ~ln(100)=4.6
    assert np.all(server.last_scores >= 0) and np.all(
        server.last_scores <= 1)


@pytest.mark.parametrize("alg", ["fedavg", "fedprox", "fednova", "afa_cd",
                                 "feddisco"])
def test_baselines_end_to_end_run(alg):
    loss, acc, _ = _run_fl(alg, rounds=3)
    assert np.isfinite(loss)


def test_resource_optimizer_feeds_fl_round():
    """Full paper pipeline: stragglers get kappa=0 and keep stale buffers."""
    rng = np.random.default_rng(0)
    net = NetworkConfig()
    clients = make_clients(rng, 8)
    decisions = optimize_round(rng, net, clients, n_params=3_900_000)
    kappas = [d.kappa for d in decisions]
    assert all(0 <= k <= net.kappa_max for k in kappas)


def test_checkpoint_roundtrip(tmp_path):
    params = init_small(jax.random.PRNGKey(0), "fcn")
    path = tmp_path / "ckpt.npz"
    checkpoint.save(path, params, step=7, metadata={"alg": "osafl"})
    like = init_small(jax.random.PRNGKey(1), "fcn")
    restored = checkpoint.restore(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint.load_metadata(path)["step"] == 7


def test_buffer_distribution_shifts_under_arrivals():
    """Sanity on the paper's premise: with arrivals, the online buffer's
    label histogram shifts round to round (Phi_u^t > 0)."""
    streams, bufs = _setup(u=1, cap=40)
    shifts = []
    for _ in range(6):
        x, y = streams[0].draw_dataset1(10)
        bufs[0].stage(x, y)
        bufs[0].commit()
        shifts.append(bufs[0].distribution_shift())
    assert max(shifts[1:]) > 0.0
