"""Per-architecture smoke tests (reduced configs: 2 layers, d_model<=256,
<=4 experts) + decode/forward consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TRANSFORMER_ARCHS, get_config
from repro.models import (decode_step, forward, init_cache, init_model,
                          loss_fn, param_count)
from repro.models.transformer import whisper_encode


def _batch(cfg, B=2, S=64, key=0):
    k = jax.random.PRNGKey(key)
    toks = jax.random.randint(k, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.encoder is not None:
        batch["frames"] = 0.02 * jax.random.normal(
            k, (B, cfg.encoder.n_frames, cfg.d_model))
    if cfg.vision is not None:
        batch["patches"] = 0.02 * jax.random.normal(
            k, (B, cfg.vision.n_patches, cfg.vision.d_vision))
    return batch


def _memory(cfg, params, batch):
    if cfg.encoder is not None:
        return whisper_encode(params, batch["frames"], cfg)
    if cfg.vision is not None:
        return (batch["patches"].astype(jnp.bfloat16)
                @ params["vision_proj"].astype(jnp.bfloat16))
    return None


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one SGD train step on CPU: shapes right, loss finite,
    params move."""
    cfg = get_config(arch).reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, metrics = loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: loss_fn(p, batch, cfg)[0])(params)
    new = jax.tree.map(lambda w, gg: w - 0.1 * gg, params, g)
    moved = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new)))
    assert moved > 0.0
    # loss should decrease after the step on the same batch
    loss2, _ = loss_fn(new, batch, cfg)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the training forward's logits.
    This pins: MLA absorbed decode == naive, mamba chunked == recurrent,
    mLSTM parallel == recurrent, ring-buffer SWA, cross-attn caches."""
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # capacity-based token dropping is batch-composition dependent by
        # design; disable drops so decode and prefill see identical routing
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=50.0))
    # this test checks the *math* (absorbed MLA, chunked SSD, parallel vs
    # recurrent mLSTM); run compute in fp32 so bf16 accumulation-order noise
    # doesn't mask real errors
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_model(jax.random.PRNGKey(1), cfg)
    B, S = 2, 32
    batch = _batch(cfg, B=B, S=S, key=2)
    logits_full, _ = forward(params, batch, cfg)

    memory = _memory(cfg, params, batch)
    cache = init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t, i, m: decode_step(p, c, t, i, cfg,
                                                     memory=m))
    outs = []
    for i in range(S):
        lg, cache = step(params, cache, batch["tokens"][:, i:i + 1],
                         jnp.int32(i), memory)
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32), np.asarray(logits_full,
                                                       np.float32),
        atol=6e-3, rtol=1e-2)


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_param_counts_positive(arch):
    cfg = get_config(arch).reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    n = param_count(params)
    assert n > 10_000


def test_full_config_dims():
    """The full (non-reduced) configs carry the exact assigned dimensions."""
    expect = {
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129_280),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32_000),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10_240, 32_000),
        "nemotron-4-15b": (32, 6144, 48, 8, 24_576, 256_000),
        "zamba2-2.7b": (54, 2560, 32, 32, 10_240, 32_000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51_865),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151_936),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14_336, 128_256),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50_304),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19_200, 32_256),
    }
    for arch, (L, d, H, Hkv, ff, V) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, Hkv, ff, V), arch
    assert get_config("deepseek-v3-671b").moe.num_experts == 256
    assert get_config("deepseek-v3-671b").moe.top_k == 8
    assert get_config("arctic-480b").moe.num_experts == 128
    assert get_config("arctic-480b").moe.top_k == 2
    assert get_config("zamba2-2.7b").ssm.d_state == 64


def test_small_models():
    from repro.models import init_small, small_forward, small_loss
    from repro.data.video_caching import D1_DIM
    key = jax.random.PRNGKey(0)
    x1 = jax.random.normal(key, (4, D1_DIM))
    for name in ("fcn", "cnn", "squeezenet"):
        p = init_small(key, name)
        logits = small_forward(p, x1, name)
        assert logits.shape == (4, 100)
        assert bool(jnp.all(jnp.isfinite(logits)))
    p = init_small(key, "lstm")
    x2 = jax.random.randint(key, (4, 10), 0, 100)
    logits = small_forward(p, x2, "lstm")
    assert logits.shape == (4, 100)
    loss, m = small_loss(p, {"x": x2, "y": jnp.zeros(4, jnp.int32)}, "lstm")
    assert bool(jnp.isfinite(loss))
