"""Golden-curve regression: every ported figure/table reproduction re-runs
at smoke scale and must match its pinned document in ``tests/golden/``.

Integer series (rounds, participants) must match exactly — they encode the
RNG consumption order and the straggler/participation masks, the things a
harness regression silently changes. Float series and summary metrics
compare under tolerance (same-platform runs are bit-identical; the slack
absorbs BLAS/codegen drift across CI image updates without letting a real
trajectory change through). Heavy runs (``benchmarks.golden.SLOW``) carry
the ``slow`` marker and run in the scheduled/CI lanes only.

Regenerate pins after an intentional change:
``PYTHONPATH=src:. python tools/gen_golden.py``.
"""
from __future__ import annotations

import numpy as np
import pytest

from benchmarks import curves
from benchmarks.golden import GOLDEN_RUNS, SLOW, golden_path

RTOL, ATOL = 1e-2, 5e-3

_params = [pytest.param(name, marks=pytest.mark.slow)
           if name in SLOW else name for name in sorted(GOLDEN_RUNS)]


def test_all_runs_pinned():
    missing = [n for n in GOLDEN_RUNS if not golden_path(n).exists()]
    assert not missing, (
        f"golden pins missing for {missing}; run tools/gen_golden.py")


@pytest.mark.parametrize("name", _params)
def test_golden_curves(name):
    pinned = curves.load_doc(golden_path(name))
    doc = curves.validate_doc(GOLDEN_RUNS[name]())
    assert doc["name"] == pinned["name"]
    assert doc["preset"] == pinned["preset"]
    assert doc["config"] == pinned["config"]
    got = {c["name"]: c for c in doc["curves"]}
    want = {c["name"]: c for c in pinned["curves"]}
    assert sorted(got) == sorted(want), "curve set changed"
    for cname, w in want.items():
        g = got[cname]
        assert g["algorithm"] == w["algorithm"]
        assert g["scenario"] == w["scenario"]
        assert sorted(g) == sorted(w), f"{cname}: series set changed"
        for k in w:
            if not isinstance(w[k], list):
                continue
            if all(isinstance(x, int) for x in w[k]):
                assert g[k] == w[k], f"{cname}.{k} (exact series) diverged"
            else:
                np.testing.assert_allclose(
                    g[k], w[k], rtol=RTOL, atol=ATOL,
                    err_msg=f"{cname}.{k} left golden tolerance")
    assert sorted(doc["summary"]) == sorted(pinned["summary"])
    for k, v in pinned["summary"].items():
        np.testing.assert_allclose(
            doc["summary"][k], v, rtol=RTOL, atol=ATOL,
            err_msg=f"summary metric {k} left golden tolerance")
