"""Docs baseline: required documents exist and no tracked markdown or module
docstring references a repo file that does not exist (the CI docs job runs
the same checker — tools/check_doc_refs.py)."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_core_documents_exist():
    for name in ("README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md"):
        assert (ROOT / name).is_file(), f"{name} missing"


def test_no_dangling_doc_references():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_doc_refs.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_design_md_resolves_known_referencers():
    """The two modules that cite DESIGN.md point at sections that exist."""
    design = (ROOT / "DESIGN.md").read_text()
    assert "## 3. Pod engines" in design           # core/pod.py §3
    assert "long_500k applicability table" in design   # launch/dryrun.py
