"""Vectorized online wireless pipeline vs the per-client oracles.

Parity bars (the acceptance criteria of the online-pipeline PR):
  * batched resource optimizer == per-client NumPy optimizer: kappa and
    feasibility exactly, f and p within 1e-6 relative, across >= 100
    randomized client/channel configurations;
  * stacked FIFO commits == ``core/buffer.py`` oracle state exactly over
    multi-round runs with wrap-around.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.buffer import OnlineBuffer
from repro.core.buffer_stacked import StackedOnlineBuffer
from repro.core.resource import (ChannelState, NetworkConfig, make_clients,
                                 optimize_client, sample_channel)
from repro.core.resource_stacked import (ResourceSolveError, _check_finite,
                                         make_solver_core,
                                         optimize_clients_batched,
                                         sample_channels, stack_clients)

NET = NetworkConfig()


# ---------------------------------------------------------------------------
# batched resource optimizer vs scalar oracle
# ---------------------------------------------------------------------------

def test_sample_channels_matches_scalar_stream():
    """One array draw consumes the Generator stream exactly like U scalar
    draws, so loop and batched rounds see identical channels per seed."""
    rng = np.random.default_rng(11)
    clients = make_clients(rng, 16)
    r1, r2 = np.random.default_rng(5), np.random.default_rng(5)
    scalar = [sample_channel(r1, s) for s in clients]
    batch = sample_channels(r2, stack_clients(clients))
    np.testing.assert_allclose([c.xi for c in scalar], batch.xi, rtol=1e-12)
    np.testing.assert_allclose([c.gamma for c in scalar], batch.gamma,
                               rtol=1e-12)


@pytest.mark.parametrize("seed,n_params", [(0, 18_000), (0, 1_000_000),
                                           (1, 18_000), (1, 3_900_000)])
def test_batched_optimizer_matches_scalar(seed, n_params):
    """64 clients x 4 (seed, payload) combos = 256 randomized configs."""
    rng = np.random.default_rng(seed)
    clients = make_clients(rng, 64)
    sysb = stack_clients(clients)
    chb = sample_channels(rng, sysb)
    scalar = [optimize_client(NET, s, ChannelState(xi, gm), n_params)
              for s, xi, gm in zip(clients, chb.xi, chb.gamma)]
    batch = optimize_clients_batched(NET, sysb, chb, n_params)
    np.testing.assert_array_equal([d.kappa for d in scalar], batch.kappa)
    np.testing.assert_array_equal([d.feasible for d in scalar],
                                  batch.feasible)
    m = batch.feasible
    assert m.any()                      # the comparison must bite
    sf = np.array([d.f for d in scalar])
    sp = np.array([d.p for d in scalar])
    np.testing.assert_allclose(batch.f[m], sf[m], rtol=1e-6)
    np.testing.assert_allclose(batch.p[m], sp[m], rtol=1e-6)
    st = np.array([d.t_total for d in scalar])
    se = np.array([d.e_total for d in scalar])
    np.testing.assert_allclose(batch.t_total[m], st[m], rtol=1e-6)
    np.testing.assert_allclose(batch.e_total[m], se[m], rtol=1e-6)


def test_batched_decisions_satisfy_constraints():
    rng = np.random.default_rng(2)
    sysb = stack_clients(make_clients(rng, 64))
    chb = sample_channels(rng, sysb)
    dec = optimize_clients_batched(NET, sysb, chb, 1_000_000)
    m = dec.feasible
    assert np.all(dec.kappa[~m] == 0)
    assert np.all((dec.kappa[m] >= 1) & (dec.kappa[m] <= NET.kappa_max))
    assert np.all(dec.f[m] <= sysb.f_max[m] * (1 + 1e-9))
    assert np.all(dec.p[m] <= sysb.p_max[m] * (1 + 1e-9))
    assert np.all(dec.t_total[m] <= NET.t_th * (1 + 1e-5))
    assert np.all(dec.e_total[m] <= sysb.e_bd[m] * (1 + 1e-5))


# ---------------------------------------------------------------------------
# f32 (log-domain) resource backend vs the x64 parity oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_params", [18_000, 3_900_000])
def test_f32_backend_matches_x64(n_params):
    """The documented DESIGN.md tolerance of the f32 log-domain solve vs the
    x64 oracle. The solve makes discrete choices (Lemma 1's floor, the SCA
    interval-endpoint step, the 5-point init sweep), so f32 rounding can
    legitimately flip a few lanes to a *different valid optimum* — the
    contract is therefore statistical + feasibility-exact, not bitwise:
      * feasibility classification EXACT; kappa flips on <= 10% of lanes,
        and a flipped lane still carries a valid in-range kappa (a
        different init point of Algorithm 1's sweep won the tie);
      * MEDIAN relative diff on (f, p, e_total) <= 1e-3 and t_total
        essentially exact (it is pinned at the deadline);
      * every f32 decision satisfies the problem's constraints;
      * both backends return host float64 / int64 columns (the x64
        scope-boundary contract)."""
    rng = np.random.default_rng(3)
    sysb = stack_clients(make_clients(rng, 64))
    chb = sample_channels(rng, sysb)
    dx = optimize_clients_batched(NET, sysb, chb, n_params, backend="x64")
    df = optimize_clients_batched(NET, sysb, chb, n_params, backend="f32")
    for d in (dx, df):
        assert d.kappa.dtype == np.int64
        assert d.f.dtype == np.float64 and d.p.dtype == np.float64
        assert isinstance(d.f, np.ndarray)
    np.testing.assert_array_equal(df.feasible, dx.feasible)
    flips = df.kappa != dx.kappa
    assert flips.mean() <= 0.10, np.flatnonzero(flips)
    assert np.all((df.kappa[flips] >= 1)
                  & (df.kappa[flips] <= NET.kappa_max))
    m = dx.feasible & ~flips
    assert m.any()

    def med_rel(a, b):
        return float(np.median(np.abs(a[m] - b[m])
                               / np.maximum(np.abs(b[m]), 1e-30)))

    assert med_rel(df.f, dx.f) <= 1e-3
    assert med_rel(df.p, dx.p) <= 1e-3
    assert med_rel(df.e_total, dx.e_total) <= 1e-3
    np.testing.assert_allclose(df.t_total[m], dx.t_total[m], rtol=1e-6)
    mm = df.feasible
    assert np.all(df.t_total[mm] <= NET.t_th * (1 + 1e-4))
    assert np.all(df.e_total[mm] <= sysb.e_bd[mm] * (1 + 1e-4))
    assert np.all(df.f[mm] <= sysb.f_max[mm] * (1 + 1e-5))
    assert np.all(df.p[mm] <= sysb.p_max[mm] * (1 + 1e-5))


@pytest.mark.parametrize("t_th", [0.5, 1.5])
def test_f32_backend_tight_deadline_no_overflow(t_th):
    """Deadlines tight enough that the direct minimum-SNR form
    2^(Nb/(omega*t)) overflows float32 outright: the log-domain f32 solve
    must still return finite columns and the same straggler classification
    as the x64 oracle (overflowing lanes are exactly the infeasible ones —
    log p_lo >> log p_max)."""
    net = dataclasses.replace(NET, t_th=t_th)
    n_params = 3_900_000
    nb = n_params * 33.0
    # the boundary state this test pins down: the direct form is inf in f32
    assert np.isinf(np.float32(2.0) ** np.float32(nb / (net.omega * t_th)))
    rng = np.random.default_rng(7)
    sysb = stack_clients(make_clients(rng, 64))
    chb = sample_channels(rng, sysb)
    dx = optimize_clients_batched(net, sysb, chb, n_params, backend="x64")
    df = optimize_clients_batched(net, sysb, chb, n_params, backend="f32")
    for col in (df.kappa, df.f, df.p, df.t_total, df.e_total):
        assert np.isfinite(col).all()
    np.testing.assert_array_equal(df.feasible, dx.feasible)
    assert np.abs(df.kappa - dx.kappa).max(initial=0) <= 1


def test_unknown_resource_backend_raises():
    rng = np.random.default_rng(0)
    sysb = stack_clients(make_clients(rng, 4))
    chb = sample_channels(rng, sysb)
    with pytest.raises(ValueError, match="unknown resource backend"):
        optimize_clients_batched(NET, sysb, chb, 18_000, backend="f64")
    with pytest.raises(ValueError, match="unknown resource backend"):
        make_solver_core(NET, backend="bf16")


def test_nonfinite_feasible_lane_raises():
    """The scope-boundary guard: a feasible lane carrying NaN/inf must raise
    ``ResourceSolveError`` naming the lanes, never flow into the round."""
    kappa = np.array([2.0, np.nan, 1.0, 3.0])
    f = np.array([1e9, 1e9, np.inf, 1e9])
    p = np.ones(4)
    feas = np.array([True, True, True, False])
    with pytest.raises(ResourceSolveError, match=r"\[1, 2\]"):
        _check_finite(kappa, f, p, feas, "f32")
    # non-finite on an INfeasible lane is fine (masked lanes carry junk)
    _check_finite(kappa, f, p, np.array([True, False, False, False]), "f32")


# ---------------------------------------------------------------------------
# stacked FIFO buffer vs oracle
# ---------------------------------------------------------------------------

def _assert_state_matches(oracles, sbuf, rnd):
    for u, oracle in enumerate(oracles):
        ox, oy = oracle.dataset()
        sx, sy = sbuf.dataset(u)
        assert np.array_equal(ox, sx), (rnd, u)
        assert np.array_equal(oy, sy), (rnd, u)
        assert oracle.size == sbuf.sizes[u]
        assert oracle.head == sbuf.heads[u]


def test_stacked_buffer_matches_oracle_multiround():
    """Random arrival bursts (incl. empty and > capacity) over 15 rounds:
    dataset contents, sizes, head pointers, histograms and shift proxies all
    match the sequential oracle exactly, through wrap-around."""
    rng = np.random.default_rng(0)
    U, C, feat = 8, 10, (2,)
    caps = rng.integers(3, 13, size=U)
    oracles = [OnlineBuffer.create(int(c), feat, C) for c in caps]
    sbuf = StackedOnlineBuffer.create(caps, feat, C, stage_capacity=40)
    counter = 0
    for rnd in range(15):
        counts = rng.integers(0, 2 * caps.max(), size=U)
        counts[rng.random(U) < 0.25] = 0
        A = int(max(counts.max(), 1))
        xs = np.zeros((U, A) + feat, np.float32)
        ys = np.zeros((U, A), np.int64)
        for u in range(U):
            n = int(counts[u])
            if n == 0:
                continue
            x = np.zeros((n,) + feat, np.float32)
            x[:, 0] = np.arange(counter, counter + n)   # unique sample ids
            y = rng.integers(0, C, size=n)
            counter += n
            oracles[u].stage(x, y)
            xs[u, :n], ys[u, :n] = x, y
        sbuf.stage(xs, ys, counts)
        assert sum(b.commit() for b in oracles) == sbuf.commit()
        _assert_state_matches(oracles, sbuf, rnd)
        np.testing.assert_allclose(
            np.stack([b.label_histogram() for b in oracles]),
            sbuf.label_histograms(), atol=1e-6)
        np.testing.assert_allclose(
            [b.distribution_shift() for b in oracles],
            sbuf.distribution_shifts(), atol=1e-6)
    assert np.any(sbuf.heads > 0)       # wrap-around actually happened


def test_stacked_buffer_empty_commit_is_noop():
    sbuf = StackedOnlineBuffer.create(np.array([4, 6]), (1,), 5)
    sbuf.stage(np.ones((2, 3, 1), np.float32), np.ones((2, 3), np.int64),
               np.array([3, 2]))
    sbuf.commit()
    sizes, heads = sbuf.sizes.copy(), sbuf.heads.copy()
    assert sbuf.commit() == 0
    assert np.array_equal(sbuf.sizes, sizes)
    assert np.array_equal(sbuf.heads, heads)


def test_stacked_buffer_overflow_commit_keeps_last_capacity():
    """A single commit of more staged samples than capacity retains exactly
    the last cap samples in arrival order (oracle overwrite semantics)."""
    caps = np.array([3, 5])
    oracle = [OnlineBuffer.create(int(c), (1,), 100) for c in caps]
    sbuf = StackedOnlineBuffer.create(caps, (1,), 100, stage_capacity=9)
    xs = np.arange(18, dtype=np.float32).reshape(2, 9, 1)
    ys = np.arange(18, dtype=np.int64).reshape(2, 9)
    for u in range(2):
        oracle[u].stage(xs[u], ys[u])
        oracle[u].commit()
    sbuf.stage(xs, ys, np.array([9, 9]))
    sbuf.commit()
    _assert_state_matches(oracle, sbuf, 0)
    assert list(sbuf.dataset(0)[1]) == [6, 7, 8]
    assert list(sbuf.dataset(1)[1]) == [13, 14, 15, 16, 17]


def test_stacked_buffer_stage_capacity_guard():
    sbuf = StackedOnlineBuffer.create(np.array([4]), (1,), 5,
                                      stage_capacity=2)
    with pytest.raises(ValueError):
        sbuf.stage(np.zeros((1, 3, 1), np.float32),
                   np.zeros((1, 3), np.int64), np.array([3]))


def test_stacked_buffer_oracle_parity_after_restore(tmp_path):
    """Save/restore at adversarial states — head wrapped past zero, size ==
    capacity, staged-but-uncommitted arrivals — then keep streaming: the
    restored stacked buffer stays in exact lockstep with restored oracles
    (checkpointing must not perturb FIFO semantics)."""
    from repro import checkpoint

    rng = np.random.default_rng(21)
    U, C, feat = 3, 6, (2,)
    caps = np.array([3, 5, 8])
    oracles = [OnlineBuffer.create(int(c), feat, C) for c in caps]
    sbuf = StackedOnlineBuffer.create(caps, feat, C, stage_capacity=16)

    def burst(counts, commit=True, counter=[0]):
        A = int(max(max(counts), 1))
        xs = np.zeros((U, A) + feat, np.float32)
        ys = np.zeros((U, A), np.int64)
        for u, n in enumerate(counts):
            if n == 0:
                continue
            x = np.zeros((n,) + feat, np.float32)
            x[:, 0] = np.arange(counter[0], counter[0] + n)
            y = rng.integers(0, C, size=n)
            counter[0] += n
            oracles[u].stage(x, y)
            xs[u, :n], ys[u, :n] = x, y
        sbuf.stage(xs, ys, np.asarray(counts))
        if commit:
            for b in oracles:
                b.commit()
            sbuf.commit()

    burst((7, 3, 8))     # client 0 over-capacity (head wraps), client 2 full
    burst((1, 2, 0))     # client 0 wraps again, client 1 exactly at capacity
    burst((1, 4, 2), commit=False)   # staged-but-uncommitted arrivals
    assert sbuf.heads[0] > 0                      # wrapped
    assert sbuf.sizes[1] == caps[1] == 5          # size == capacity
    assert np.asarray(sbuf.state.staged_n).sum() == 7   # staged, uncommitted

    ck = tmp_path / "adversarial"
    checkpoint.save_run_state(ck, {
        "stacked": sbuf.state_dict(),
        "oracles": [b.state_dict() for b in oracles]})
    loaded = checkpoint.load_run_state(ck)
    sbuf = StackedOnlineBuffer.create(caps, feat, C, stage_capacity=16)
    sbuf.load_state_dict(loaded["stacked"])
    oracles = [OnlineBuffer.create(int(c), feat, C) for c in caps]
    for b, sd in zip(oracles, loaded["oracles"]):
        b.load_state_dict(sd)

    # the staged tail commits on the restored copies, then 5 more rounds
    for b in oracles:
        b.commit()
    sbuf.commit()
    _assert_state_matches(oracles, sbuf, "post-restore")
    for rnd in range(5):
        counts = tuple(int(n) for n in rng.integers(0, 2 * caps.max(),
                                                    size=U))
        burst(counts)
        _assert_state_matches(oracles, sbuf, rnd)
        np.testing.assert_allclose(
            np.stack([b.label_histogram() for b in oracles]),
            sbuf.label_histograms(), atol=1e-6)


def test_stacked_buffer_sampling_hits_live_window_only():
    rng = np.random.default_rng(3)
    caps = np.array([5, 9, 7])
    sbuf = StackedOnlineBuffer.create(caps, (1,), 5, stage_capacity=9)
    counts = np.array([2, 9, 5])
    xs = np.zeros((3, 9, 1), np.float32)
    ys = rng.integers(0, 5, (3, 9))
    sbuf.stage(xs, ys, counts)
    sbuf.commit()
    slots = sbuf.sample_slots(rng, (4, 6))
    assert slots.shape == (3, 4, 6)
    for u in range(3):
        live = set((sbuf.heads[u] + np.arange(sbuf.sizes[u])) % caps[u])
        assert set(slots[u].ravel()) <= live
    batch = sbuf.gather(slots)
    assert batch["x"].shape == (3, 4, 6, 1)
    assert batch["y"].shape == (3, 4, 6)


# ---------------------------------------------------------------------------
# online vectorized harness
# ---------------------------------------------------------------------------

def test_online_vectorized_harness_smoke():
    from benchmarks.common import ExperimentConfig, run_vectorized_experiment
    xc = ExperimentConfig(model="mlp", dataset=2, num_clients=16, rounds=2,
                          seed=3)
    hist = run_vectorized_experiment("osafl", xc, eval_samples=64)
    assert len(hist) == 2
    for h in hist:
        assert np.isfinite(h["test_loss"])
        assert 0 <= h["participants"] <= 16
    assert hist[-1]["participants"] > 0


@pytest.mark.slow
def test_online_pipeline_speedup_at_256():
    from benchmarks.bench_online import bench_pipeline
    r = bench_pipeline(U=256, rounds=3)
    assert r["speedup"] >= 10, r
