"""Every benchmark entry point must run as a plain script from the repo root
(``python benchmarks/<x>.py``) with NO PYTHONPATH set — regression for the
``ModuleNotFoundError: No module named 'benchmarks'`` crash: scripts executed
by path get ``benchmarks/`` (not the repo root) as ``sys.path[0]``, so each
entry point carries a repo-root + ``src/`` sys.path shim.

``--help`` exercises exactly the crash surface (module import + argparse
wiring) without paying for a benchmark run; the subprocesses are spawned
concurrently (interpreter + jax import dominate the wall clock).
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

# libraries, not entry points: shared harness (common), the curve-JSON
# schema (curves), and the golden-run registry (golden)
_LIBS = {"common.py", "curves.py", "golden.py"}

ENTRY_POINTS = sorted(
    p.relative_to(ROOT) for p in (ROOT / "benchmarks").glob("*.py")
    if p.name not in _LIBS)

# the ported figure/table reproductions: executed end-to-end at smoke scale
# below, each must write a well-formed curve JSON document
CURVE_SCRIPTS = ("fig1_static_vs_timevarying.py", "fig2_label_drift.py",
                 "fig3_stragglers.py", "table2_dataset1.py",
                 "table4_dataset2.py")


def test_all_entry_points_enumerated():
    # every benchmarks/*.py except the library modules is an entry point; a
    # new script missing its __main__ block would silently drop out of the
    # CLI sweep below, so pin the count
    assert len(ENTRY_POINTS) == 12
    for p in ENTRY_POINTS:
        text = (ROOT / p).read_text()
        assert "__main__" in text, f"{p} has no __main__ block"
    for lib in _LIBS:
        assert (ROOT / "benchmarks" / lib).exists(), lib


def test_benchmark_cli_help_from_repo_root():
    """All entry points' ``--help`` exits 0 from the repo root without
    PYTHONPATH (concurrent Popen — serial startup would take ~1 min)."""
    env = {k: v for k, v in os.environ.items() if k != "PYTHONPATH"}
    env["JAX_PLATFORMS"] = "cpu"
    procs = [(p, subprocess.Popen(
        [sys.executable, str(p), "--help"], cwd=ROOT, env=env,
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
        for p in ENTRY_POINTS]
    failures = []
    for p, proc in procs:
        out, err = proc.communicate(timeout=120)
        if proc.returncode != 0:
            failures.append(f"{p}: rc={proc.returncode}\n{err}")
        elif "usage:" not in out.lower():
            failures.append(f"{p}: no usage text in --help output:\n{out}")
    assert not failures, "\n---\n".join(failures)


def test_curve_scripts_execute_and_write_wellformed_json(tmp_path):
    """Every ported figure/table script runs end-to-end at smoke scale as a
    plain subprocess from the repo root and writes a curve document that
    passes the schema contract (``benchmarks.curves.validate_doc``: pinned
    schema tag, complete curve keys, equal series lengths, finite metrics)
    and prints the legacy ``key,us,value`` CSV rows. Spawned concurrently —
    the two algorithm-sweep tables dominate the wall clock."""
    from benchmarks import curves

    env = {k: v for k, v in os.environ.items() if k != "PYTHONPATH"}
    env["JAX_PLATFORMS"] = "cpu"
    procs = []
    for name in CURVE_SCRIPTS:
        out = tmp_path / f"{Path(name).stem}.json"
        procs.append((name, out, subprocess.Popen(
            [sys.executable, str(Path("benchmarks") / name),
             "--preset", "smoke", "--out", str(out)],
            cwd=ROOT, env=env, stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True)))
    failures = []
    for name, out, proc in procs:
        stdout, stderr = proc.communicate(timeout=540)
        if proc.returncode != 0:
            failures.append(f"{name}: rc={proc.returncode}\n{stderr}")
            continue
        rows = [ln for ln in stdout.strip().splitlines() if "," in ln]
        if not rows or any(len(ln.split(",")) != 3 for ln in rows):
            failures.append(f"{name}: malformed CSV rows:\n{stdout}")
        try:
            doc = curves.load_doc(out)
        except Exception as e:                    # missing file or bad doc
            failures.append(f"{name}: bad curve doc: {e}")
            continue
        if doc["preset"] != "smoke" or not doc["curves"]:
            failures.append(f"{name}: unexpected doc shape")
    assert not failures, "\n---\n".join(failures)


@pytest.mark.parametrize("script", ["run.py", "bench_online.py"])
def test_benchmark_cli_help_from_other_cwd(tmp_path, script):
    """The shim resolves paths from ``__file__``, not CWD — entry points must
    also work when invoked by absolute path from an unrelated directory."""
    env = {k: v for k, v in os.environ.items() if k != "PYTHONPATH"}
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, str(ROOT / "benchmarks" / script), "--help"],
        cwd=tmp_path, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
