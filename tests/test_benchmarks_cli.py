"""Every benchmark entry point must run as a plain script from the repo root
(``python benchmarks/<x>.py``) with NO PYTHONPATH set — regression for the
``ModuleNotFoundError: No module named 'benchmarks'`` crash: scripts executed
by path get ``benchmarks/`` (not the repo root) as ``sys.path[0]``, so each
entry point carries a repo-root + ``src/`` sys.path shim.

``--help`` exercises exactly the crash surface (module import + argparse
wiring) without paying for a benchmark run; the subprocesses are spawned
concurrently (interpreter + jax import dominate the wall clock).
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

ENTRY_POINTS = sorted(
    p.relative_to(ROOT) for p in (ROOT / "benchmarks").glob("*.py")
    if p.name != "common.py")


def test_all_entry_points_enumerated():
    # every benchmarks/*.py except the common library is an entry point; a
    # new script missing its __main__ block would silently drop out of the
    # CLI sweep below, so pin the count
    assert len(ENTRY_POINTS) == 11
    for p in ENTRY_POINTS:
        text = (ROOT / p).read_text()
        assert "__main__" in text, f"{p} has no __main__ block"


def test_benchmark_cli_help_from_repo_root():
    """All entry points' ``--help`` exits 0 from the repo root without
    PYTHONPATH (concurrent Popen — serial startup would take ~1 min)."""
    env = {k: v for k, v in os.environ.items() if k != "PYTHONPATH"}
    env["JAX_PLATFORMS"] = "cpu"
    procs = [(p, subprocess.Popen(
        [sys.executable, str(p), "--help"], cwd=ROOT, env=env,
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
        for p in ENTRY_POINTS]
    failures = []
    for p, proc in procs:
        out, err = proc.communicate(timeout=120)
        if proc.returncode != 0:
            failures.append(f"{p}: rc={proc.returncode}\n{err}")
        elif "usage:" not in out.lower():
            failures.append(f"{p}: no usage text in --help output:\n{out}")
    assert not failures, "\n---\n".join(failures)


@pytest.mark.parametrize("script", ["run.py", "bench_online.py"])
def test_benchmark_cli_help_from_other_cwd(tmp_path, script):
    """The shim resolves paths from ``__file__``, not CWD — entry points must
    also work when invoked by absolute path from an unrelated directory."""
    env = {k: v for k, v in os.environ.items() if k != "PYTHONPATH"}
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, str(ROOT / "benchmarks" / script), "--help"],
        cwd=tmp_path, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
