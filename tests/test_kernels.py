"""Pallas kernel validation: shape/dtype sweeps, assert_allclose vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.scored_reduce import osafl_scores_fused, scored_reduce


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (2, 4, 4, 128, 64),       # MHA
    (1, 8, 2, 256, 64),       # GQA 4:1
    (2, 4, 1, 128, 128),      # MQA
    (1, 2, 2, 512, 32),       # long-ish seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_reference(B, H, Hkv, S, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    out = flash_attention_bhsd(q, k, v, causal=True, block_q=64, block_k=64)
    expect = ref.mha_reference(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("block_q,block_k", [(32, 32), (64, 32), (128, 128)])
def test_flash_attention_block_shapes(block_q, block_k):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = flash_attention_bhsd(q, k, v, block_q=block_q, block_k=block_k)
    expect = ref.mha_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    out = flash_attention_bhsd(q, k, v, causal=False, block_q=64, block_k=64)
    expect = ref.mha_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("U,N,block", [
    (4, 1000, 256), (16, 4096, 1024), (8, 131, 64), (2, 17, 2048),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scored_reduce_matches_reference(U, N, block, dtype):
    d = jax.random.normal(jax.random.PRNGKey(0), (U, N), dtype)
    mean = jnp.mean(d.astype(jnp.float32), axis=0)
    dots, norms, msq = scored_reduce(d, mean, block_n=block)
    rd, rn, rm = ref.scored_reduce_reference(d, mean)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(dots, rd, rtol=tol, atol=tol)
    np.testing.assert_allclose(norms, rn, rtol=tol, atol=tol)
    np.testing.assert_allclose(msq, rm, rtol=tol, atol=tol)


def test_fused_scores_match_reference_and_paper_bounds():
    d = jax.random.normal(jax.random.PRNGKey(3), (8, 5000))
    lam = np.asarray(osafl_scores_fused(d, chi=1.0))
    lam_ref = np.asarray(ref.osafl_scores_reference(d, chi=1.0))
    np.testing.assert_allclose(lam, lam_ref, rtol=1e-5, atol=1e-6)
    assert np.all(lam >= 0.0) and np.all(lam <= 1.0)   # eq. 21 with chi=1
