"""Dry-run machinery: HLO analyzer unit tests + a small-mesh lower/compile in
a subprocess (jax device count is locked at first init, so the 512-device
production dry-run runs via the module's own entrypoint)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch.hlo_analysis import (analyze_hlo, parse_module, shape_bytes,
                                       shape_elems)


def test_shape_parsing():
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[2], s32[3])") == 20
    assert shape_elems("f32[3,5]") == 15
    assert shape_bytes("pred[7]") == 7


HLO = textwrap.dedent("""
    HloModule test

    %body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
      %p = (s32[], f32[8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8]{0} get-tuple-element(%p), index=1
      %ar = f32[8]{0} all-reduce(%x), replica_groups={}, to_apply=%add
      ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
    }

    %cond (p: (s32[], f32[8])) -> pred[] {
      %p = (s32[], f32[8]) parameter(0)
      ROOT %lt = pred[] constant(true)
    }

    ENTRY %main (a: f32[4,6], b: f32[6,8]) -> f32[8] {
      %a = f32[4,6]{1,0} parameter(0)
      %b = f32[6,8]{1,0} parameter(1)
      %d = f32[4,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %init = (s32[], f32[8]) tuple()
      %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %out = f32[8]{0} get-tuple-element(%w), index=1
    }
""")


def test_analyzer_counts_dot_and_loop_collectives():
    a = analyze_hlo(HLO)
    assert a.flops == 2 * 4 * 8 * 6                     # one dot
    assert a.collective_bytes["all-reduce"] == 8 * 4 * 5  # trip count 5
    assert a.collective_counts["all-reduce"] == 5


def _dryrun_subprocess(code: str) -> dict:
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.splitlines()[-1])


SMALL_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import json
    import repro.launch.dryrun as dr
    rec = dr.run_one("xlstm-350m", "decode_32k", out_dir="/tmp/dryrun_test",
                     verbose=False)
    rec2 = dr.run_one("xlstm-350m", "long_500k", out_dir="/tmp/dryrun_test",
                      verbose=False)
    print(json.dumps({
        "dominant": rec["roofline"]["dominant"],
        "flops": rec["per_device"]["flops"],
        "coll": rec["per_device"]["collective_bytes"],
        "long_ok": "roofline" in rec2,
    }))
""")


@pytest.mark.slow
def test_production_mesh_dryrun_decode():
    res = _dryrun_subprocess(SMALL_DRYRUN)
    assert res["flops"] > 0
    assert res["long_ok"]                       # ssm runs long_500k


SKIP_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import json
    import repro.launch.dryrun as dr
    rec = dr.run_one("qwen1.5-4b", "long_500k", out_dir="/tmp/dryrun_test",
                     verbose=False)
    print(json.dumps({"skipped": "skipped" in rec}))
""")


@pytest.mark.slow
def test_long_context_skipped_for_full_attention():
    res = _dryrun_subprocess(SKIP_DRYRUN)
    assert res["skipped"]
