"""Hierarchical edge-cluster aggregation (``core/hierarchy.py``).

Covers the tentpole contracts: ``num_clusters=1`` is bit-exact against the
flat parameter server for all six algorithms on both request backends (the
parity anchor — the two-tier round body at K=1 is the flat op sequence);
K>1 produces a different (finite) trajectory with per-cluster scores;
cluster-membership churn under the scenario RNG contract is deterministic
and resumes bit-exactly from a streaming v2 snapshot with a live cluster
map; and the ``ClusterSlotPool`` unit semantics (per-cluster routing,
reassign-with-migration, checkpoint round-trip)."""
import dataclasses

import numpy as np
import pytest

from repro.checkpoint import CheckpointError
from repro.core.cohort import SlotPool, sample_participants
from repro.core.hierarchy import (ClusterSlotPool, contiguous_clusters,
                                  sample_participants_clustered)
from repro.harness import (ALL_ALGS, ExperimentConfig, checkpoint_path,
                           run)

BASE = dict(model="mlp", dataset=2, num_clients=8, rounds=3,
            capacity=(12, 24), arrivals=4, batch=8, seed=5)
METRICS = ("round", "test_loss", "test_acc", "participants")


def _key(history):
    return [tuple(h[k] for k in METRICS) for h in history]


# ---------------------------------------------------------------------------
# K=1 parity anchor: the two-tier round at one cluster IS the flat round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("request_backend", ["python", "stacked"])
@pytest.mark.parametrize("alg", ALL_ALGS)
def test_k1_bit_exact_vs_flat(alg, request_backend):
    xc = ExperimentConfig(request_backend=request_backend, **BASE)
    flat = run(alg, xc, eval_samples=64)
    hier = run(alg, dataclasses.replace(xc, num_clusters=1),
               eval_samples=64)
    assert _key(hier) == _key(flat)     # rtol=0, atol=0


def test_k1_bit_exact_on_sparse_cohort():
    xc = ExperimentConfig(request_backend="stacked", cohort_size=4,
                          participation=0.75, **BASE)
    flat = run("osafl", xc, eval_samples=64)
    hier = run("osafl", dataclasses.replace(xc, num_clusters=1),
               eval_samples=64)
    assert _key(hier) == _key(flat)


def test_k4_differs_and_is_finite():
    xc = ExperimentConfig(request_backend="stacked", **BASE)
    flat = run("osafl", xc, eval_samples=64)
    hier = run("osafl", dataclasses.replace(xc, num_clusters=4),
               eval_samples=64)
    assert all(np.isfinite(h["test_loss"]) for h in hier)
    # the second aggregation tier reweights cluster aggregates by their own
    # eq. 19-21 scores, so the trajectory must actually move
    assert _key(hier) != _key(flat)


def test_k2_fedavg_matches_flat_numerically():
    # for unscored baselines the two tiers compose to the same weighted sum,
    # just re-associated into per-cluster partials — equal up to float
    # summation order
    xc = ExperimentConfig(request_backend="stacked", **BASE)
    flat = run("fedavg", xc, eval_samples=64)
    hier = run("fedavg", dataclasses.replace(xc, num_clusters=2),
               eval_samples=64)
    np.testing.assert_allclose([h["test_loss"] for h in hier],
                               [h["test_loss"] for h in flat],
                               rtol=1e-3, atol=1e-5)


# ---------------------------------------------------------------------------
# cluster churn: scenario-driven membership moves, deterministic + resumable
# ---------------------------------------------------------------------------

def _churn_xc(rounds):
    return ExperimentConfig(**dict(
        BASE, rounds=rounds, request_backend="stacked", cohort_size=4,
        participation=0.75, num_clusters=2,
        scenario="cluster_churn(rate=0.4)"))


def test_cluster_churn_deterministic():
    a = run("osafl", _churn_xc(4), eval_samples=64)
    b = run("osafl", _churn_xc(4), eval_samples=64)
    assert _key(a) == _key(b)


def test_cluster_churn_perturbs():
    base = ExperimentConfig(**dict(
        BASE, rounds=4, request_backend="stacked", cohort_size=4,
        participation=0.75, num_clusters=2))
    quiet = run("osafl", base, eval_samples=64)
    churned = run("osafl", dataclasses.replace(
        base, scenario="cluster_churn(rate=0.9)"), eval_samples=64)
    assert _key(quiet) != _key(churned)


def test_hier_churn_snapshot_resume_bit_exact(tmp_path):
    full = run("osafl", _churn_xc(6), eval_samples=64)
    run("osafl", _churn_xc(4), eval_samples=64, save_every_k=2,
        checkpoint_dir=tmp_path)
    resumed = run("osafl", _churn_xc(6), eval_samples=64,
                  resume_from=checkpoint_path(tmp_path, 4))
    # the resumed history carries the pre-snapshot rounds too; the live
    # cluster map + per-cluster score carries must restore bit-exactly
    assert _key(resumed) == _key(full)


def test_flat_snapshot_refuses_hier_run(tmp_path):
    xc = ExperimentConfig(**dict(BASE, request_backend="stacked",
                                 cohort_size=4))
    run("osafl", xc, eval_samples=64, save_every_k=BASE["rounds"],
        checkpoint_dir=tmp_path)
    with pytest.raises(CheckpointError, match="num_clusters"):
        run("osafl", dataclasses.replace(xc, num_clusters=2),
            eval_samples=64,
            resume_from=checkpoint_path(tmp_path, BASE["rounds"]))


# ---------------------------------------------------------------------------
# cluster map + slot pool units
# ---------------------------------------------------------------------------

def test_contiguous_clusters():
    np.testing.assert_array_equal(contiguous_clusters(8, 2),
                                  [0, 0, 0, 0, 1, 1, 1, 1])
    np.testing.assert_array_equal(contiguous_clusters(6, 1), np.zeros(6))
    with pytest.raises(ValueError, match="divide the population"):
        contiguous_clusters(8, 3)


def test_cluster_pool_routes_admissions_per_block():
    assign = contiguous_clusters(8, 2)
    pool = ClusterSlotPool(8, 4, assign, 2)
    res = pool.admit(np.array([0, 5, 1, 7]))
    assert res.newly.all()
    # cluster 0 users land in slots [0, 2), cluster 1 users in [2, 4)
    assert set(res.slots[[0, 2]]) == {0, 1}
    assert set(res.slots[[1, 3]]) == {2, 3}
    assert sorted(pool.cohort.tolist()) == [0, 1, 5, 7]
    pool.check()
    # a full block FIFO-evicts within the block only
    res2 = pool.admit(np.array([2]))
    assert res2.evicted.size == 1 and res2.evicted[0] in (0, 1)
    assert res2.slots[0] < 2
    pool.check()


def test_cluster_pool_reassign_migrates_residents():
    assign = contiguous_clusters(8, 2)
    pool = ClusterSlotPool(8, 4, assign, 2)
    pool.admit(np.array([0, 1, 4, 5]))
    moved = pool.reassign(np.array([1, 6]), np.array([1, 0]))
    # user 6 was not resident: only the map changes; resident user 1 is
    # evicted from block 0 and must be re-admitted by the caller
    np.testing.assert_array_equal(moved, [1])
    assert pool.assign[1] == 1 and pool.assign[6] == 0
    assert 1 not in pool.cohort
    res = pool.admit(moved)
    assert res.newly.all() and res.slots[0] >= 2   # seated in block 1 now
    pool.check()


def test_cluster_pool_state_roundtrip():
    assign = contiguous_clusters(8, 2)
    pool = ClusterSlotPool(8, 4, assign, 2)
    pool.admit(np.array([0, 5, 1, 7]))
    pool.reassign(np.array([0]), np.array([1]))
    sd = pool.state_dict()
    fresh = ClusterSlotPool(8, 4, contiguous_clusters(8, 2), 2)
    fresh.load_state_dict(sd)
    np.testing.assert_array_equal(fresh.assign, pool.assign)
    np.testing.assert_array_equal(fresh.user_slot, pool.user_slot)
    np.testing.assert_array_equal(fresh.slot_user, pool.slot_user)
    fresh.check()
    wrong_k = ClusterSlotPool(8, 4, contiguous_clusters(8, 4), 4)
    with pytest.raises(CheckpointError, match="num_clusters"):
        wrong_k.load_state_dict(sd)
    flat_sd = SlotPool(8, 4).state_dict()
    with pytest.raises(CheckpointError):
        pool.load_state_dict(flat_sd)


def test_clustered_sampling_delegates_at_k1():
    assign = contiguous_clusters(16, 1)
    weights = np.arange(16, dtype=float) + 1.0
    avail = np.ones(16, bool)
    avail[3] = False
    a = sample_participants_clustered(
        np.random.default_rng(7), assign, 1, 5, 16, weights=weights,
        available=avail)
    b = sample_participants(np.random.default_rng(7), 16, 5,
                            weights=weights, available=avail)
    np.testing.assert_array_equal(a, b)   # same RNG stream, same draw


def test_clustered_sampling_respects_block_budget():
    assign = contiguous_clusters(16, 4)
    rng = np.random.default_rng(3)
    for _ in range(10):
        sel = sample_participants_clustered(rng, assign, 4, 12, block=2)
        assert sel.size <= 8                     # 4 clusters x block=2
        counts = np.bincount(assign[sel], minlength=4)
        assert (counts <= 2).all()
        assert np.array_equal(sel, np.unique(sel))
