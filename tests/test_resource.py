"""Resource optimizer (paper Section II-C, Lemmas 1-2, SCA): feasibility and
optimality properties."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.resource import (FPP, ChannelState, ClientSystem,
                                 NetworkConfig, _comp_coeff, _rate,
                                 _upload_energy, _upload_time, make_clients,
                                 optimal_frequency, optimal_kappa,
                                 optimize_client, optimize_round,
                                 pathloss_linear, sample_channel)

NET = NetworkConfig()


def _sys(rng=None, **kw):
    base = dict(c=30.0, s=101_376.0, f_max=1.5e9, p_max=0.5, e_bd=2.0,
                distance=400.0)
    base.update(kw)
    return ClientSystem(**base)


def _ch(xi_db=-100.0, gamma=1.0):
    return ChannelState(xi=10 ** (xi_db / 10), gamma=gamma)


def test_feasible_decision_satisfies_constraints():
    rng = np.random.default_rng(1)
    clients = make_clients(rng, 30)
    n_params = 1_000_000
    decisions = optimize_round(rng, NET, clients, n_params)
    for sys, dec in zip(clients, decisions):
        if not dec.feasible:
            continue
        assert 1 <= dec.kappa <= NET.kappa_max
        assert 0 < dec.f <= sys.f_max * (1 + 1e-9)
        assert 0 < dec.p <= sys.p_max * (1 + 1e-9)
        assert dec.t_total <= NET.t_th * (1 + 1e-5)
        assert dec.e_total <= sys.e_bd * (1 + 1e-5)


def test_lemma1_kappa_is_maximal():
    """kappa* from Lemma 1: kappa*+1 must violate energy or deadline."""
    sys = _sys()
    ch = _ch()
    n_params = 2_000_000
    f, p = 1.2e9, 0.05
    k = optimal_kappa(NET, sys, ch, f, p, n_params)
    if 1 <= k < NET.kappa_max:
        cc = _comp_coeff(NET, sys)
        e = 0.5 * NET.v * cc * (k + 1) * f ** 2 + \
            _upload_energy(NET, ch, p, n_params)
        t = cc * (k + 1) / f + _upload_time(NET, ch, p, n_params)
        assert e > sys.e_bd or t > NET.t_th


def test_lemma2_frequency_meets_deadline_exactly():
    """f* (eq. 44) makes compute time + upload time == t_th."""
    sys = _sys()
    ch = _ch(-95.0)
    kappa, p, n_params = 3, 0.05, 2_000_000
    f = optimal_frequency(NET, sys, ch, kappa, p, n_params)
    if np.isfinite(f):
        t = _comp_coeff(NET, sys) * kappa / f + _upload_time(NET, ch, p,
                                                             n_params)
        np.testing.assert_allclose(t, NET.t_th, rtol=1e-9)


@given(st.floats(-115.0, -85.0), st.floats(0.5, 2.0))
@settings(max_examples=25, deadline=None)
def test_better_channel_never_reduces_kappa(xi_db, gamma):
    """Monotonicity: improving the channel can only help."""
    sys = _sys()
    n_params = 3_000_000
    d1 = optimize_client(NET, sys, _ch(xi_db, gamma), n_params)
    d2 = optimize_client(NET, sys, _ch(xi_db + 6.0, gamma), n_params)
    if d1.feasible:
        assert d2.feasible
        assert d2.kappa >= d1.kappa - 1     # alternation tolerance


def test_larger_payload_increases_stragglers():
    rng = np.random.default_rng(0)
    clients = make_clients(rng, 60)
    strag = []
    for n_params in (500_000, 2_000_000, 8_000_000):
        rng2 = np.random.default_rng(7)
        dec = optimize_round(rng2, NET, clients, n_params)
        strag.append(sum(1 for d in dec if not d.feasible))
    assert strag[0] <= strag[1] <= strag[2]


def test_pathloss_monotonic_in_distance():
    assert pathloss_linear(100) > pathloss_linear(500) > pathloss_linear(2000)


def test_infeasible_when_upload_alone_exceeds_deadline():
    sys = _sys(p_max=0.001, e_bd=0.5)
    ch = _ch(-135.0)                       # terrible channel
    dec = optimize_client(NET, sys, ch, 50_000_000)
    assert not dec.feasible and dec.kappa == 0


def test_rate_monotone_in_power():
    ch = _ch()
    assert _rate(NET, ch, 0.5) > _rate(NET, ch, 0.05) > 0
