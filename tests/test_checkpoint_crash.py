"""Crash/corruption hardening of the streaming (v2) checkpoint layer.

The contract under test (checkpoint/streaming.py): a snapshot is atomically
either complete or invisible. No writer death — SIGKILL at an arbitrary
byte offset — and no on-disk corruption may ever produce a snapshot that
*loads* but holds wrong data; the failure mode is always "invisible to
``latest_checkpoint``" or "``CheckpointError`` naming the bad artifact",
never a silent partial restore.

Three attack surfaces:

  * a real writer subprocess SIGKILLed at randomized offsets mid-save
    (the ``_POST_SHARD_HOOK`` test seam widens the kill window so the
    signal lands between shard-file writes with high probability);
  * a deterministic torn write stopped after *every* possible shard-file
    offset in turn (covers the offsets the randomized kill may miss);
  * byte-level corruption of every artifact of a committed snapshot —
    truncated / bit-flipped / missing / cross-save-swapped shard files,
    garbled manifest, garbled / missing / mismatched commit marker — plus
    the v1 equivalent (truncated ``.npz``).

This file doubles as the crash child: ``python test_checkpoint_crash.py
--child DIR`` writes snapshots in a tight loop until killed.
"""
from __future__ import annotations

import json
import os
import random
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

ROOT = Path(__file__).resolve().parent.parent
if __name__ == "__main__":      # child mode: repro comes from PYTHONPATH=src
    sys.path.insert(0, str(ROOT / "src"))

from repro import checkpoint
from repro.checkpoint import (CheckpointError, committed_snapshots,
                              diff_snapshots, latest_checkpoint,
                              load_run_state, save_run_state,
                              save_run_state_v2, snapshot_round)
from repro.checkpoint import streaming


def _round_state(r: int) -> dict:
    """Deterministic per-round RunState-shaped tree (seeded by the round
    number) so the parent can regenerate what the killed child wrote."""
    rng = np.random.default_rng(1000 + r)
    return {
        "config": {"model": "mlp", "dataset": 2},
        "server": {"w": rng.standard_normal(257).astype(np.float32),
                   "step": np.array(r, dtype=np.int64)},     # 0-d shard
        "buffer": {"x": rng.standard_normal((8, 16)).astype(np.float32),
                   "count": np.array(r % 5, dtype=np.int32),

                   "mask": rng.integers(0, 2, 24).astype(bool),
                   "ids": rng.integers(-4, 4, 10).astype(np.int8)},
        "next_round": int(r),
    }


def _child_main(out_dir: str) -> int:
    """Write committed snapshots round 1, 2, ... until killed. Each shard
    write is followed by a short sleep (the test seam) so the parent's
    SIGKILL lands mid-snapshot with high probability."""
    d = Path(out_dir)
    d.mkdir(parents=True, exist_ok=True)
    streaming._POST_SHARD_HOOK = lambda: time.sleep(0.004)
    (d / "BEGIN").touch()       # imports done: the parent's kill clock starts
    for r in range(1, 400):
        save_run_state_v2(d / f"round_{r:05d}", _round_state(r),
                          metadata={"round": r})
    return 0


@pytest.mark.parametrize("seed", range(4))
def test_sigkill_mid_save_commits_are_exact_partials_invisible(
        tmp_path, seed):
    """SIGKILL a real writer subprocess at a randomized offset: every
    snapshot that survived with a commit marker loads bit-exactly to what
    the child deterministically wrote; everything else is invisible to the
    scan and refuses to load."""
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src"),
           "JAX_PLATFORMS": "cpu"}
    proc = subprocess.Popen(
        [sys.executable, str(Path(__file__).resolve()), "--child",
         str(tmp_path)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
    try:
        # wait until round 1 is committed so every run kills a *mid-stream*
        # writer (never one that had produced nothing at all)
        first = tmp_path / "round_00001" / streaming.COMMIT_NAME
        deadline = time.monotonic() + 120
        while not first.exists():
            assert proc.poll() is None, proc.communicate()
            assert time.monotonic() < deadline, "child never committed"
            time.sleep(0.005)
        time.sleep(random.Random(seed).uniform(0.0, 0.35))
    finally:
        proc.kill()             # SIGKILL: no atexit, no flush, no cleanup
        proc.wait(timeout=60)

    snaps = committed_snapshots(tmp_path)
    assert snaps, "round 1 was committed before the kill"
    for s in snaps:
        r = snapshot_round(s)
        got = load_run_state(s)
        diffs = diff_snapshots(_round_state(r), got, skip=())
        assert not diffs, (s, diffs)
    # uncommitted leftovers (the snapshot the kill interrupted): invisible
    # to the committed scan, and a direct load refuses loudly
    latest = latest_checkpoint(tmp_path)
    assert snapshot_round(latest) == max(snapshot_round(s) for s in snaps)
    partial = [p for p in tmp_path.glob("round_*") if p.is_dir()
               and not (p / streaming.COMMIT_NAME).exists()]
    assert len(partial) <= 1    # the writer has at most one in flight
    for p in partial:
        assert p not in snaps
        with pytest.raises(CheckpointError, match="commit marker"):
            load_run_state(p)


def test_torn_write_at_every_shard_offset_is_invisible(tmp_path, monkeypatch):
    """Deterministic sweep of the randomized test above: abort the writer
    after shard file 0, 1, ..., n-1 in turn. At every offset the partial
    directory has no commit marker, is invisible to ``latest_checkpoint``,
    and refuses a direct load."""
    state = _round_state(7)
    save_run_state_v2(tmp_path / "ref" / "round_00007", state)
    nshards = len(list((tmp_path / "ref" / "round_00007").glob("*.npy")))
    assert nshards >= 5         # the sweep actually covers distinct offsets
    for k in range(nshards):
        d = tmp_path / f"torn{k:02d}"
        calls = {"n": 0}

        def hook():
            calls["n"] += 1
            if calls["n"] > k:
                raise KeyboardInterrupt   # die after k+1 shard files

        monkeypatch.setattr(streaming, "_POST_SHARD_HOOK", hook)
        with pytest.raises(KeyboardInterrupt):
            save_run_state_v2(d / "round_00001", state)
        monkeypatch.setattr(streaming, "_POST_SHARD_HOOK", None)
        assert len(list((d / "round_00001").glob("*.npy"))) == k + 1
        assert not checkpoint.is_committed(d / "round_00001")
        assert latest_checkpoint(d) is None
        assert committed_snapshots(d) == []
        with pytest.raises(CheckpointError, match="commit marker"):
            load_run_state(d / "round_00001")


# ---------------------------------------------------------------------------
# byte-level corruption of a committed snapshot
# ---------------------------------------------------------------------------

def _committed(tmp_path, r=3) -> Path:
    d = tmp_path / f"round_{r:05d}"
    save_run_state_v2(d, _round_state(r), metadata={"round": r})
    return d


def _a_shard(d: Path) -> str:
    """Some multi-byte shard file name, from the manifest."""
    man = json.loads((d / streaming.MANIFEST_NAME).read_text())
    for ent in man["arrays"].values():
        for sh in ent["shards"]:
            if sh["nbytes"] > 128:
                return sh["file"]
    raise AssertionError("no big shard in manifest")


def _truncate_shard(d):
    f = d / _a_shard(d)
    f.write_bytes(f.read_bytes()[:-7])
    return f.name, "truncated"


def _flip_byte(d):
    f = d / _a_shard(d)
    raw = bytearray(f.read_bytes())
    raw[-3] ^= 0x40             # payload byte: crc fails before np.load
    f.write_bytes(bytes(raw))
    return f.name, "crc32"


def _delete_shard(d):
    f = d / _a_shard(d)
    f.unlink()
    return f.name, "missing"


def _swap_shard_across_saves(d):
    """Same tree shape, different save: byte lengths match, contents do
    not — only the crc catches the mix-up."""
    other = _committed(d.parent / "other", r=4)
    name = _a_shard(d)
    (d / name).write_bytes((other / name).read_bytes())
    return name, "crc32"


def _garble_manifest(d):
    f = d / streaming.MANIFEST_NAME
    f.write_text(f.read_text()[:-40] + "}")
    return f.name, "does not hash"


def _garble_commit(d):
    f = d / streaming.COMMIT_NAME
    f.write_text("{\"format_version\": 2, \"save_")     # torn json
    return f.name, "corrupt commit marker"


def _mismatched_save_id(d):
    """A commit marker whose sha matches the manifest but that names a
    different save (a stale marker next to rewritten shards)."""
    import hashlib
    f = d / streaming.COMMIT_NAME
    commit = json.loads(f.read_text())
    commit["save_id"] = "0" * 32
    assert commit["manifest_sha256"] == hashlib.sha256(
        (d / streaming.MANIFEST_NAME).read_bytes()).hexdigest()
    f.write_text(json.dumps(commit))
    return Path(d).name, "different saves"   # message names the snapshot


@pytest.mark.parametrize("mutate", [
    _truncate_shard, _flip_byte, _delete_shard, _swap_shard_across_saves,
    _garble_manifest, _garble_commit, _mismatched_save_id,
], ids=lambda m: m.__name__.lstrip("_"))
def test_corrupt_artifact_raises_checkpoint_error_naming_it(
        tmp_path, mutate):
    d = _committed(tmp_path)
    load_run_state(d)           # pristine snapshot loads
    name, reason = mutate(d)
    with pytest.raises(CheckpointError) as exc:
        load_run_state(d)
    msg = str(exc.value)
    assert name in msg, (name, msg)
    assert reason in msg, (reason, msg)


def test_missing_commit_marker_is_invisible_not_an_error(tmp_path):
    """Deleting the marker (the first step of ``delete_snapshot``) makes
    the snapshot vanish from the scan; only a *direct* load of the stem
    raises."""
    d = _committed(tmp_path)
    (d / streaming.COMMIT_NAME).unlink()
    assert latest_checkpoint(tmp_path) is None
    assert committed_snapshots(tmp_path) == []
    with pytest.raises(CheckpointError, match="commit marker"):
        load_run_state(d)


def test_v1_truncated_npz_raises_checkpoint_error(tmp_path):
    """The v1 single-archive path gets the same loud failure: a truncated
    ``.npz`` (killed mid-``os.replace``-free write, torn copy) raises
    ``CheckpointError`` naming the file instead of numpy's raw zip error."""
    stem = tmp_path / "round_00002"
    save_run_state(stem, _round_state(2), metadata={"round": 2})
    npz = stem.with_suffix(".npz")
    npz.write_bytes(npz.read_bytes()[:200])
    with pytest.raises(CheckpointError, match="corrupt or truncated") as exc:
        load_run_state(stem)
    assert npz.name in str(exc.value)


def test_corrupt_snapshot_never_silently_restores_wrong_data(tmp_path):
    """The meta-assertion behind the whole suite: whatever we do to the
    bytes of one shard, the load either raises or returns data bit-equal
    to the original — sweep a byte-flip across every shard file."""
    d = _committed(tmp_path, r=5)
    want = _round_state(5)
    for f in sorted(d.glob("*.npy")):
        raw = bytearray(f.read_bytes())
        for pos in (0, len(raw) // 2, len(raw) - 1):
            orig = raw[pos]
            raw[pos] ^= 0xFF
            f.write_bytes(bytes(raw))
            try:
                got = load_run_state(d)
            except CheckpointError:
                pass            # loud failure: the acceptable outcome
            else:
                assert not diff_snapshots(want, got, skip=()), (f.name, pos)
            raw[pos] = orig
        f.write_bytes(bytes(raw))
    assert not diff_snapshots(want, load_run_state(d), skip=())


if __name__ == "__main__":
    if len(sys.argv) == 3 and sys.argv[1] == "--child":
        sys.exit(_child_main(sys.argv[2]))
    sys.exit(2)
