"""Pod-scale engines: exact_tp == paper server semantics == recompute, and
sketch approximates exact. Multi-device cases run in subprocesses (jax locks
the device count at first init)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FLConfig
from repro.core.pod import (make_fedavg_train_step, make_recompute_train_step,
                            make_serve_step, make_tp_train_step)
from repro.data.synthetic import make_train_batch


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.splitlines()[-1])


def test_single_device_engines_agree():
    """On one device (U=1): lambda == 1, so exact_tp == plain SGD step."""
    cfg = get_config("qwen1.5-4b").reduced()
    fl = FLConfig(kappa_max=1, local_lr=0.1, global_lr=1.0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.models.transformer import init_model
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = make_train_batch(jax.random.PRNGKey(1), cfg, 4, 32)
    with mesh:
        p1, m1 = jax.jit(make_tp_train_step(cfg, fl, mesh))(params, batch)
        p2, m2 = jax.jit(make_fedavg_train_step(cfg, fl, mesh))(params, batch)
    assert m1["lambda_mean"] == pytest.approx(1.0, abs=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_recompute_engine_matches_reference_scoring():
    """exact_recompute on 1 device with U=4 scanned clients must equal the
    hand-computed OSAFL aggregation over per-client grads."""
    cfg = get_config("h2o-danube-3-4b").reduced()
    fl = FLConfig(kappa_max=1, local_lr=0.05, global_lr=1.0, num_clients=4)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.core.scores import lambda_scores
    from repro.models.transformer import init_model, loss_fn
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = make_train_batch(jax.random.PRNGKey(1), cfg, 8, 32)
    batch_u = jax.tree.map(lambda x: x.reshape((4, 2) + x.shape[1:]), batch)
    with mesh:
        step = make_recompute_train_step(cfg, fl, mesh, 4)
        new_params, metrics = jax.jit(step)(params, batch_u)
    # reference
    grads = [jax.grad(lambda p: loss_fn(p, jax.tree.map(lambda x: x[u],
                                                        batch_u), cfg)[0])(
        params) for u in range(4)]
    lam = lambda_scores(grads, chi=fl.chi)
    np.testing.assert_allclose(float(metrics["lambda_mean"]), lam.mean(),
                               rtol=3e-3)   # bf16 accumulation-order noise
    upd = jax.tree.map(
        lambda *gs: sum(float(l) * g for l, g in zip(lam, gs)) / 4.0, *grads)
    expect = jax.tree.map(lambda w, u: w - 0.05 * u.astype(w.dtype),
                          params, upd)
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-3, atol=3e-4)


_SUBPROCESS_TP = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.configs.base import FLConfig
    from repro.core.pod import make_tp_train_step, make_recompute_train_step
    from repro.core.scores import lambda_scores
    from repro.data.synthetic import make_train_batch
    from repro.models.transformer import init_model, loss_fn

    cfg = get_config("qwen1.5-4b").reduced()
    fl = FLConfig(kappa_max=1, local_lr=0.05, global_lr=1.0, num_clients=4)
    mesh = jax.make_mesh((4, 1), ("data", "model"))
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = make_train_batch(jax.random.PRNGKey(1), cfg, 8, 32)
    with mesh:
        p_tp, m_tp = jax.jit(make_tp_train_step(cfg, fl, mesh))(params, batch)
        bu = jax.tree.map(lambda x: x.reshape((4, 2) + x.shape[1:]), batch)
        p_rc, m_rc = jax.jit(make_recompute_train_step(cfg, fl, mesh, 4))(
            params, bu)
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(p_tp), jax.tree.leaves(p_rc))]
    print(json.dumps({
        "lambda_tp": float(m_tp["lambda_mean"]),
        "lambda_rc": float(m_rc["lambda_mean"]),
        "max_param_diff": max(diffs),
    }))
""")


def test_tp_and_recompute_agree_on_4_devices():
    """The shard_map scored-all-reduce engine and the scanned recompute
    engine implement the same math: 4 clients, same batch split."""
    res = _run_sub(_SUBPROCESS_TP)
    assert abs(res["lambda_tp"] - res["lambda_rc"]) < 1e-3, res
    assert res["max_param_diff"] < 5e-3, res
    assert 0.0 <= res["lambda_tp"] <= 1.0


_SUBPROCESS_SKETCH = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import FLConfig
    from repro.core.pod import make_tp_train_step
    from repro.data.synthetic import make_train_batch
    from repro.models.transformer import init_model

    cfg = get_config("h2o-danube-3-4b").reduced()
    fl = FLConfig(kappa_max=1, local_lr=0.05, global_lr=1.0)
    mesh = jax.make_mesh((4, 1), ("data", "model"))
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = make_train_batch(jax.random.PRNGKey(1), cfg, 8, 32)
    with mesh:
        _, m_exact = jax.jit(make_tp_train_step(cfg, fl, mesh))(params, batch)
        _, m_sk = jax.jit(make_tp_train_step(cfg, fl, mesh,
                                             sketch_dim=4096))(params, batch)
    print(json.dumps({"exact": float(m_exact["lambda_mean"]),
                      "sketch": float(m_sk["lambda_mean"])}))
""")


def test_sketched_scores_approximate_exact_on_4_devices():
    res = _run_sub(_SUBPROCESS_SKETCH)
    assert abs(res["exact"] - res["sketch"]) < 0.1, res


def test_serve_step_emits_tokens():
    cfg = get_config("h2o-danube-3-4b").reduced()
    from repro.models.transformer import init_cache, init_model
    params = init_model(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, 2, 64)
    serve = jax.jit(make_serve_step(cfg))
    tok = jnp.zeros((2, 1), jnp.int32)
    for i in range(4):
        tok, cache = serve(params, cache, tok, jnp.int32(i), None)
    assert tok.shape == (2, 1)
    assert bool(jnp.all((tok >= 0) & (tok < cfg.vocab_size)))


def test_stale_engine_two_rounds_tracks_exact():
    """Single-pass stale-score engine: lambda_next from round t equals the
    exact engine's lambda for the same batch (up to sketch noise), and
    weighting uses the previous round's scores."""
    import jax.numpy as jnp
    from repro.core.pod import make_stale_score_train_step
    cfg = get_config("h2o-danube-3-4b").reduced()
    fl = FLConfig(kappa_max=1, local_lr=0.05, num_clients=4)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.models.transformer import init_model
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = make_train_batch(jax.random.PRNGKey(1), cfg, 8, 32)
    bu = jax.tree.map(lambda x: x.reshape((4, 2) + x.shape[1:]), batch)
    lam0 = jnp.ones((4,), jnp.float32)
    with mesh:
        stale = jax.jit(make_stale_score_train_step(cfg, fl, mesh, 4,
                                                    sketch_dim=4096))
        p1, lam1, m1 = stale(params, lam0, bu)
        # round 1 weighted with lam0=1 => equals plain mean-gradient step
        rc = jax.jit(make_recompute_train_step(cfg, fl, mesh, 4))
        p_exact, m_exact = rc(params, bu)
        # lam_next should approximate the exact engine's lambda on this batch
        assert abs(float(m1["lambda_mean"]) -
                   float(m_exact["lambda_mean"])) < 0.1
        # and a second stale round must consume lam1 without error
        p2, lam2, m2 = stale(p1, lam1, bu)
        assert bool(jnp.all(jnp.isfinite(lam2)))
        assert 0.0 <= float(m2["lambda_mean"]) <= 1.0
