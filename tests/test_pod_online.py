"""Online arrivals for the pod engines (DESIGN.md §3 "Online arrivals").

Three invariants:

  * the mesh-sharded ``StackedOnlineBuffer`` is state-identical to the
    single-host one (which tests/test_online_stacked.py ties to the
    ``core/buffer.py`` oracle) over staged/wrap/over-capacity commits, and
    its snapshots round-trip — including shape checks on restore;
  * ``run_pod_online_experiment`` on a 1-device mesh matches
    ``run_vectorized_experiment`` metric-for-metric (the correctness anchor
    for every pod engine flavor — same host RNG order, same local-SGD math,
    same stacked server);
  * pod RunState snapshots resume bit-exactly and refuse mismatched
    engine/mesh shapes.

Multi-device cases run in subprocesses (jax locks the device count at first
init), on a faked 8-device CPU mesh.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from benchmarks.common import (ExperimentConfig, POD_ENGINES,
                               checkpoint_path, run_pod_online_experiment,
                               run_vectorized_experiment)
from repro.checkpoint import CheckpointError
from repro.core.buffer_stacked import StackedOnlineBuffer

METRICS = ("round", "test_loss", "test_acc", "participants")


def _xc(rounds: int = 3, backend: str = "stacked") -> ExperimentConfig:
    return ExperimentConfig(model="mlp", dataset=2, num_clients=8,
                            rounds=rounds, capacity=(12, 24), arrivals=4,
                            batch=8, seed=5, request_backend=backend)


def _mesh1():
    return jax.make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# 1-device-mesh parity with run_vectorized_experiment (the anchor)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alg,engine", [
    ("osafl", "exact_tp"),            # acceptance anchor
    ("fedavg", "fedavg"),             # acceptance anchor
    ("osafl", "recompute"),
    ("fednova", "exact_tp"),
    ("feddisco", "recompute"),
])
def test_pod_matches_vectorized_on_1_device_mesh(alg, engine):
    xc = _xc()
    hv = run_vectorized_experiment(alg, xc, eval_samples=64)
    hp = run_pod_online_experiment(alg, xc, eval_samples=64, mesh=_mesh1(),
                                   pod_engine=engine)
    assert set(hp[0]) == set(hv[0])   # history schema
    for a, b in zip(hv, hp):
        assert abs(a["test_loss"] - b["test_loss"]) <= 1e-5
        assert abs(a["test_acc"] - b["test_acc"]) <= 1e-5
        assert a["participants"] == b["participants"]


def test_pod_parity_python_request_backend():
    xc = _xc(backend="python")
    hv = run_vectorized_experiment("osafl", xc, eval_samples=64)
    hp = run_pod_online_experiment("osafl", xc, eval_samples=64,
                                   mesh=_mesh1(), pod_engine="exact_tp")
    for a, b in zip(hv, hp):
        assert abs(a["test_loss"] - b["test_loss"]) <= 1e-5


def test_pod_stale_engine_lags_scores():
    """The stale flavor weights round t with round t-1's lambdas: finite,
    schema-complete, and genuinely different from the exact engine."""
    xc = _xc()
    hs = run_pod_online_experiment("osafl", xc, eval_samples=64,
                                   mesh=_mesh1(), pod_engine="stale")
    he = run_pod_online_experiment("osafl", xc, eval_samples=64,
                                   mesh=_mesh1(), pod_engine="exact_tp")
    assert all(np.isfinite(h["test_loss"]) for h in hs)
    assert set(hs[0]) == set(he[0])
    assert any(h1["test_loss"] != h2["test_loss"]
               for h1, h2 in zip(hs, he))


def test_pod_rejects_bad_engine():
    with pytest.raises(ValueError, match="pod_engine"):
        run_pod_online_experiment("osafl", _xc(), eval_samples=64,
                                  mesh=_mesh1(), pod_engine="nope")
    # the clients-divisible-by-mesh-rows check needs a multi-row mesh; it is
    # covered by the 8-device subprocess test below


# ---------------------------------------------------------------------------
# sharded-buffer state parity + snapshots (1-device mesh; the 8-device twin
# runs in a subprocess below)
# ---------------------------------------------------------------------------

def _exercise(buf: StackedOnlineBuffer, rng: np.random.Generator,
              iters: int = 6) -> None:
    """Staged/over-capacity/wrap-heavy commit sequence (reused across both
    copies so they see identical arrivals)."""
    U = buf.capacities.shape[0]
    for it in range(iters):
        counts = rng.integers(0, 7, size=U)
        x = rng.normal(size=(U, 6, 3)).astype(np.float32)
        y = rng.integers(0, 10, size=(U, 6))
        buf.stage(x, y, counts)
        if it % 2:
            buf.commit()


def _assert_state_equal(a: StackedOnlineBuffer, b: StackedOnlineBuffer):
    assert np.array_equal(a.sizes, b.sizes)
    assert np.array_equal(a.heads, b.heads)
    assert np.array_equal(np.asarray(a.state.staged_n),
                          np.asarray(b.state.staged_n))
    for u in range(a.capacities.shape[0]):
        xa, ya = a.dataset(u)
        xb, yb = b.dataset(u)
        assert np.array_equal(xa, xb) and np.array_equal(ya, yb)


def test_sharded_buffer_matches_single_host_oracle_on_1_device():
    rng = np.random.default_rng(3)
    caps = rng.integers(4, 9, size=8)
    plain = StackedOnlineBuffer.create(caps, (3,), 10, stage_capacity=24)
    shard = StackedOnlineBuffer.create(caps, (3,), 10, stage_capacity=24,
                                       mesh=_mesh1())
    _exercise(plain, np.random.default_rng(7))
    _exercise(shard, np.random.default_rng(7))
    _assert_state_equal(plain, shard)
    assert np.allclose(plain.label_histograms(), shard.label_histograms())


def test_sharded_buffer_snapshot_roundtrip_and_shape_check(tmp_path):
    from repro import checkpoint
    rng = np.random.default_rng(3)
    caps = rng.integers(4, 9, size=8)
    buf = StackedOnlineBuffer.create(caps, (3,), 10, stage_capacity=24,
                                     mesh=_mesh1())
    _exercise(buf, np.random.default_rng(7), iters=5)  # staged tail pending
    ck = tmp_path / "buf"
    checkpoint.save_run_state(ck, {"buffer": buf.state_dict()})
    sd = checkpoint.load_run_state(ck)["buffer"]
    # snapshots are host-gathered numpy (the npz format)
    assert isinstance(sd["x"], np.ndarray)

    fresh = StackedOnlineBuffer.create(caps, (3,), 10, stage_capacity=24,
                                       mesh=_mesh1())
    fresh.load_state_dict(sd)
    _assert_state_equal(buf, fresh)
    # restored storage is re-laid-out on the mesh
    assert fresh.state.x.sharding.mesh is not None

    wrong = StackedOnlineBuffer.create(caps[:4], (3,), 10, stage_capacity=24)
    with pytest.raises(CheckpointError, match="shape"):
        wrong.load_state_dict(sd)
    missing = dict(sd)
    missing.pop("head")
    with pytest.raises(CheckpointError, match="missing"):
        fresh.load_state_dict(missing)


def test_unsharded_buffer_shape_check_still_loads_legacy():
    """The shape check applies to the plain buffer too, and a same-shape
    snapshot (the only kind older runs produced) still loads."""
    caps = np.full(4, 6)
    a = StackedOnlineBuffer.create(caps, (3,), 10, stage_capacity=18)
    _exercise(a, np.random.default_rng(1), iters=3)
    b = StackedOnlineBuffer.create(caps, (3,), 10, stage_capacity=18)
    b.load_state_dict(a.state_dict())
    _assert_state_equal(a, b)


# ---------------------------------------------------------------------------
# pod RunState resume (1-device mesh)
# ---------------------------------------------------------------------------

def test_pod_resume_is_bit_exact(tmp_path):
    mesh = _mesh1()
    full = run_pod_online_experiment("osafl", _xc(4), eval_samples=64,
                                     mesh=mesh)
    run_pod_online_experiment("osafl", _xc(2), eval_samples=64, mesh=mesh,
                              save_every_k=2, checkpoint_dir=tmp_path)
    resumed = run_pod_online_experiment(
        "osafl", _xc(4), eval_samples=64, mesh=mesh, save_every_k=2,
        checkpoint_dir=tmp_path, resume_from=checkpoint_path(tmp_path, 2))
    for a, b in zip(full, resumed):
        for k in METRICS:
            assert a[k] == b[k], (k, a, b)


def test_pod_resume_refuses_mismatched_engine(tmp_path):
    mesh = _mesh1()
    run_pod_online_experiment("osafl", _xc(2), eval_samples=64, mesh=mesh,
                              save_every_k=2, checkpoint_dir=tmp_path)
    with pytest.raises(CheckpointError, match="pod_engine"):
        run_pod_online_experiment(
            "osafl", _xc(4), eval_samples=64, mesh=mesh,
            pod_engine="recompute",
            resume_from=checkpoint_path(tmp_path, 2))
    with pytest.raises(CheckpointError, match="engine"):
        run_vectorized_experiment(
            "osafl", _xc(4), eval_samples=64,
            resume_from=checkpoint_path(tmp_path, 2))


# ---------------------------------------------------------------------------
# multi-device: faked 8-device mesh in a subprocess
# ---------------------------------------------------------------------------

def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.splitlines()[-1])


_SUBPROCESS_MESH = textwrap.dedent("""
    import json
    import numpy as np, jax
    from benchmarks.common import (ExperimentConfig,
                                   run_pod_online_experiment,
                                   run_vectorized_experiment)
    from repro.core.buffer_stacked import StackedOnlineBuffer
    mesh = jax.make_mesh((2, 4), ("pod", "data"))

    # sharded buffer == single-host buffer over wrap-heavy commits
    caps = np.random.default_rng(3).integers(4, 9, size=8)
    plain = StackedOnlineBuffer.create(caps, (3,), 10, stage_capacity=24)
    shard = StackedOnlineBuffer.create(caps, (3,), 10, stage_capacity=24,
                                       mesh=mesh)
    for buf in (plain, shard):
        rng = np.random.default_rng(7)
        for it in range(6):
            counts = rng.integers(0, 7, size=8)
            x = rng.normal(size=(8, 6, 3)).astype(np.float32)
            y = rng.integers(0, 10, size=(8, 6))
            buf.stage(x, y, counts)
            if it % 2:
                buf.commit()
    buf_ok = all(
        np.array_equal(plain.dataset(u)[1], shard.dataset(u)[1])
        and np.array_equal(plain.dataset(u)[0], shard.dataset(u)[0])
        for u in range(8)) and np.array_equal(plain.sizes, shard.sizes)
    storage_sharded = len(shard.state.x.sharding.device_set) == 8

    # pod harness on the 2x4 mesh vs the 1-device vectorized run
    xc = ExperimentConfig(model="mlp", dataset=2, num_clients=8, rounds=3,
                          capacity=(12, 24), arrivals=4, batch=8, seed=5,
                          request_backend="stacked")
    hp = run_pod_online_experiment("osafl", xc, eval_samples=64, mesh=mesh,
                                   pod_engine="exact_tp")
    hv = run_vectorized_experiment("osafl", xc, eval_samples=64)
    dloss = max(abs(a["test_loss"] - b["test_loss"])
                for a, b in zip(hv, hp))
    try:
        run_pod_online_experiment(
            "osafl", ExperimentConfig(model="mlp", dataset=2,
                                      num_clients=9, rounds=1),
            eval_samples=64, mesh=mesh)
        divisible_ok = False
    except ValueError:
        divisible_ok = True
    print(json.dumps({"buf_ok": buf_ok, "storage_sharded": storage_sharded,
                      "dloss": dloss, "divisible_ok": divisible_ok,
                      "finite": all(np.isfinite(h["test_loss"])
                                    for h in hp)}))
""")


def test_sharded_buffer_and_pod_run_on_8_device_mesh():
    res = _run_sub(_SUBPROCESS_MESH)
    assert res["buf_ok"], res
    assert res["storage_sharded"], res
    assert res["finite"], res
    assert res["divisible_ok"], res
    # cross-shard reductions may reorder float sums; in practice the mlp run
    # is bit-identical — keep the anchor tolerance
    assert res["dloss"] <= 1e-5, res
