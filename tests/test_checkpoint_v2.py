"""Property tests for the streaming (v2) per-shard snapshot codec.

Invariants (checkpoint/streaming.py), driven by generated adversarial
trees (tests/_hyp.py shim — real hypothesis when installed):

  * v2 round-trips arbitrary state trees bit-exactly — zero-length arrays,
    0-d arrays, mixed dtypes (bool / int8 / uint32 / float16), deeply
    nested dict/list skeletons, python scalars, big ints, None;
  * v1 and v2 are *interchangeable encodings*: the same state saved both
    ways loads to identical trees, and ``load_run_state`` dispatches on
    the on-disk layout (directory -> v2, ``.npz`` -> v1) so every v1
    snapshot written before this layer keeps loading (read-compat);
  * wrap-around FIFO pointer states of the stacked buffer (heads past the
    capacity boundary, staged-but-uncommitted tails) survive v2 and
    restore into a live buffer in exact lockstep;
  * a snapshot written from a mesh-sharded array on a faked 8-device
    (2, 4) mesh really lands as 8 shard files and reassembles bit-exactly
    in a single-device reader (1-shard vs 8-shard mesh topologies);
  * ``keep_last`` retention keeps the newest k committed snapshots, never
    a claimed one, never the writer's in-flight directory, and sweeps
    crashed leftovers.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro import checkpoint
from repro.checkpoint import (committed_snapshots, diff_snapshots,
                              latest_checkpoint, load_run_state,
                              prune_checkpoints, save_run_state,
                              save_run_state_v2, write_claim, clear_claim)
from repro.checkpoint import streaming
from repro.core.buffer_stacked import StackedOnlineBuffer

from _hyp import given, settings, st

ROOT = Path(__file__).resolve().parent.parent

_DTYPES = (np.float32, np.float64, np.float16, np.int64, np.int32,
           np.int8, np.uint32, np.bool_)
_SHAPES = ((), (0,), (1,), (5,), (3, 4), (2, 0, 3))


def _rand_leaf(rng):
    roll = rng.random()
    if roll < 0.65:
        dtype = _DTYPES[rng.integers(len(_DTYPES))]
        shape = _SHAPES[rng.integers(len(_SHAPES))]
        raw = rng.integers(0, 2, shape) if dtype is np.bool_ else \
            rng.integers(-7, 120, shape)
        return raw.astype(dtype)
    if roll < 0.8:
        return [None, "osafl", int(rng.integers(100)),
                float(rng.random()), True, 2 ** 97 + 13][
                    rng.integers(6)]
    return None


def _rand_tree(rng, depth=0):
    out = {}
    for i in range(int(rng.integers(2, 6))):
        key = f"k{i}"
        if depth < 2 and rng.random() < 0.3:
            out[key] = _rand_tree(rng, depth + 1) if rng.random() < 0.6 \
                else [_rand_leaf(rng) for _ in range(int(rng.integers(3)))]
        else:
            out[key] = _rand_leaf(rng)
    return out


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_v2_roundtrip_adversarial_trees(seed):
    """save_run_state_v2 -> load_run_state is the identity on arbitrary
    trees (arrays bit-exact with dtype and shape, skeleton unchanged) —
    and loads through the same generic entry point as v1 (dispatch on the
    directory layout)."""
    import tempfile
    state = _rand_tree(np.random.default_rng(seed))
    with tempfile.TemporaryDirectory(ignore_cleanup_errors=True) as td:
        save_run_state_v2(Path(td) / "round_00001", state,
                          metadata={"seed": seed})
        out = load_run_state(Path(td) / "round_00001")
    diffs = diff_snapshots(state, out, skip=())
    assert not diffs, diffs


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_v1_and_v2_load_to_identical_trees(seed):
    """The two layouts are interchangeable encodings of one tree: the same
    state saved as a v1 npz+sidecar pair and as a v2 shard directory loads
    to identical results (v1 write stays the read-compat anchor)."""
    import tempfile
    state = _rand_tree(np.random.default_rng(seed))
    with tempfile.TemporaryDirectory(ignore_cleanup_errors=True) as td:
        save_run_state(Path(td) / "v1" / "round_00001", state)
        save_run_state_v2(Path(td) / "v2" / "round_00001", state)
        from_v1 = load_run_state(Path(td) / "v1" / "round_00001")
        from_v2 = load_run_state(Path(td) / "v2" / "round_00001")
        # both committed, both visible to the shared scan
        assert latest_checkpoint(Path(td) / "v1") is not None
        assert latest_checkpoint(Path(td) / "v2") is not None
    diffs = diff_snapshots(from_v1, from_v2, skip=())
    assert not diffs, diffs


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 9), st.lists(st.integers(0, 12), min_size=1,
                                   max_size=6), st.integers(0, 6))
def test_v2_roundtrip_fifo_wraparound_buffer_states(cap, bursts, tail):
    """Wrap-around FIFO pointer states — heads past the capacity boundary,
    over-capacity commits, a staged-but-uncommitted tail — survive the
    per-shard layout and restore into a fresh buffer bit-exactly."""
    import tempfile
    C = 7
    caps = np.array([cap, max(cap - 1, 2)])
    sbuf = StackedOnlineBuffer.create(caps, (2,), C, stage_capacity=14)
    counter = 0
    for n in bursts:                       # enough traffic to wrap the FIFO
        counts = (n, (2 * n + 1) % 13)
        A = int(max(max(counts), 1))
        xs = np.zeros((2, A, 2), np.float32)
        ys = np.zeros((2, A), np.int64)
        for u, cnt in enumerate(counts):
            xs[u, :cnt, 0] = np.arange(counter, counter + cnt)
            ys[u, :cnt] = np.arange(counter, counter + cnt) % C
            counter += cnt
        sbuf.stage(xs, ys, np.asarray(counts))
        sbuf.commit()
    if tail:                               # uncommitted staging area
        xs = np.zeros((2, tail, 2), np.float32)
        xs[:, :, 0] = counter
        sbuf.stage(xs, np.zeros((2, tail), np.int64),
                   np.asarray((tail, tail // 2)))
    with tempfile.TemporaryDirectory(ignore_cleanup_errors=True) as td:
        save_run_state_v2(Path(td) / "round_00001",
                          {"buffer": sbuf.state_dict()})
        loaded = load_run_state(Path(td) / "round_00001")
    sbuf2 = StackedOnlineBuffer.create(caps, (2,), C, stage_capacity=14)
    sbuf2.load_state_dict(loaded["buffer"])
    diffs = diff_snapshots(sbuf.state_dict(), sbuf2.state_dict(), skip=())
    assert not diffs, diffs
    # restored copy continues in lockstep: committing the staged tail on
    # both sides yields identical datasets
    sbuf.commit()
    sbuf2.commit()
    for u in range(2):
        ax, ay = sbuf.dataset(u)
        bx, by = sbuf2.dataset(u)
        assert np.array_equal(ax, bx) and np.array_equal(ay, by)


_MESH_CHILD = textwrap.dedent("""
    import os, sys
    sys.path.insert(0, sys.argv[2])
    import numpy as np
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save_run_state_v2
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    x = np.arange(8 * 16, dtype=np.float32).reshape(8, 16)
    xd = jax.device_put(x, NamedSharding(mesh, P("pod", "data")))
    assert len(xd.addressable_shards) == 8
    save_run_state_v2(sys.argv[1] + "/round_00003",
                      {"buffer": {"x": xd},
                       "rep": jax.device_put(
                           np.arange(6, dtype=np.int64),
                           NamedSharding(mesh, P()))})
    print("OK")
""")


def test_v2_mesh_sharded_write_reassembles_on_single_device(tmp_path):
    """A snapshot written from a NamedSharding-split array on a faked
    (2, 4) 8-device mesh lands as 8 per-shard files (no host gather: the
    manifest records 8 distinct index extents), a fully replicated array
    dedupes to one shard, and this 1-device process reassembles both
    bit-exactly — re-sharding onto a different topology is the loader's
    ``device_put`` downstream."""
    env = {**os.environ, "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_CHILD, str(tmp_path),
         str(ROOT / "src")],
        env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    d = tmp_path / "round_00003"
    man = json.loads((d / streaming.MANIFEST_NAME).read_text())
    shard_counts = {k: len(e["shards"]) for k, e in man["arrays"].items()}
    assert shard_counts["s/buffer/x"] == 8, shard_counts
    assert shard_counts["s/rep"] == 1, shard_counts   # replicated dedupes
    out = load_run_state(d)
    np.testing.assert_array_equal(
        out["buffer"]["x"],
        np.arange(8 * 16, dtype=np.float32).reshape(8, 16))
    assert out["buffer"]["x"].dtype == np.float32
    np.testing.assert_array_equal(out["rep"], np.arange(6, dtype=np.int64))


# ---------------------------------------------------------------------------
# keep_last retention
# ---------------------------------------------------------------------------

def _snap(d: Path, r: int) -> Path:
    p = d / f"round_{r:05d}"
    save_run_state_v2(p, {"r": np.array(r)}, metadata={"round": r})
    return p


def test_prune_keeps_newest_k_committed(tmp_path):
    for r in range(1, 6):
        _snap(tmp_path, r)
    removed = prune_checkpoints(tmp_path, keep_last=2)
    assert sorted(p.name for p in removed) == [
        "round_00001", "round_00002", "round_00003"]
    assert [p.name for p in committed_snapshots(tmp_path)] == [
        "round_00004", "round_00005"]
    # idempotent: a second prune removes nothing
    assert prune_checkpoints(tmp_path, keep_last=2) == []
    with pytest.raises(ValueError):
        prune_checkpoints(tmp_path, keep_last=0)


def test_prune_never_deletes_claimed_snapshot(tmp_path):
    """The prune-vs-reload race, retention side: a snapshot named by a
    live ``SERVING-*`` claim survives any ``keep_last``; once the claim
    moves on, the next prune collects it."""
    snaps = [_snap(tmp_path, r) for r in range(1, 5)]
    write_claim(tmp_path, "srv1", [snaps[1]])        # server maps round 2
    prune_checkpoints(tmp_path, keep_last=1)
    names = [p.name for p in committed_snapshots(tmp_path)]
    assert names == ["round_00002", "round_00004"]   # claimed + newest
    assert load_run_state(snaps[1])["r"] == 2        # still fully loadable
    # the server re-polls to the newest snapshot; its claim narrows
    write_claim(tmp_path, "srv1", [snaps[3]])
    prune_checkpoints(tmp_path, keep_last=1)
    assert [p.name for p in committed_snapshots(tmp_path)] == [
        "round_00004"]
    clear_claim(tmp_path, "srv1")
    assert not list(tmp_path.glob("SERVING-*"))


def test_prune_spares_in_flight_write_sweeps_crashed_leftovers(tmp_path):
    """An uncommitted directory at/after the newest committed round is the
    async writer's in-flight snapshot (spared); an uncommitted directory
    *behind* it is a crashed write (swept)."""
    for r in (3, 4):
        _snap(tmp_path, r)
    stale = tmp_path / "round_00001"                 # crashed leftover
    stale.mkdir()
    (stale / "a00000.s00.npy").write_bytes(b"partial")
    inflight = tmp_path / "round_00005"              # being written now
    inflight.mkdir()
    (inflight / "a00000.s00.npy").write_bytes(b"partial")
    prune_checkpoints(tmp_path, keep_last=1)
    left = sorted(p.name for p in tmp_path.glob("round_*"))
    assert left == ["round_00004", "round_00005"]
    assert latest_checkpoint(tmp_path).name == "round_00004"
