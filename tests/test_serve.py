"""Train-while-serve hot-reload (launch/serve.py) against the streaming
checkpoint layer.

The serving contract:

  * only *committed* snapshots are ever mapped — an uncommitted (in-flight
    or crashed) v2 directory newer than the mapped round is invisible;
  * staleness is honest and monotone: the mapped round never goes
    backwards, ``rounds_behind`` reflects the newest committed round, and
    each reload logs how far behind the server swapped;
  * a hot reload mid-request-batch cannot change in-flight outputs:
    ``pin()`` holds the mapped params by reference across the swap;
  * load failures (raced prunes, bad artifacts) are counted and retried,
    never fatal, never a partial map;
  * the full loop: a trainer subprocess publishes snapshots every round
    while a ``serve_loop`` in this process polls, scores and hot-reloads
    to the final round.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.checkpoint import (CheckpointError, save_run_state_v2,
                              prune_checkpoints)
from repro.checkpoint import streaming
from repro.core.flatten import make_codec
from repro.launch.serve import (ModelServer, extract_global_model,
                                make_request_batch, serve_loop)
from repro.models.small import init_small

ROOT = Path(__file__).resolve().parent.parent

_CODEC = make_codec(init_small(jax.random.PRNGKey(0), "mlp"))


def _snap(d: Path, r: int, scale: float = 1.0) -> Path:
    """A committed RunState-shaped snapshot whose flat weights are
    deterministic in (round, scale) — distinguishable across rounds."""
    w = scale * (r + 1) * np.asarray(
        _CODEC.flatten(init_small(jax.random.PRNGKey(1), "mlp")))
    p = d / f"round_{r:05d}"
    save_run_state_v2(p, {"config": {"model": "mlp", "dataset": 2},
                          "server": {"w": w.astype(np.float32)},
                          "next_round": r})
    return p


def test_extract_global_model_layouts(tmp_path):
    """All three engine layouts produce a scorable model: flat ``w``
    (stacked/pod), a ``params`` pytree (loop), and the sparse-cohort
    ``inner`` nesting; non-RunState trees are loud failures."""
    params = init_small(jax.random.PRNGKey(3), "mlp")
    w = np.asarray(_CODEC.flatten(params))
    base = {"config": {"model": "mlp"}, "next_round": 4}
    for sv in ({"w": w}, {"params": params},
               {"inner": {"w": w}, "slots": np.arange(3)}):
        model, got, rnd = extract_global_model({**base, "server": sv})
        assert model == "mlp" and rnd == 4
        np.testing.assert_allclose(np.asarray(_CODEC.flatten(got)), w,
                                   rtol=0, atol=0)
    with pytest.raises(CheckpointError, match="neither"):
        extract_global_model({**base, "server": {"weights": w}})
    with pytest.raises(CheckpointError, match="RunState"):
        extract_global_model({"x": 1})
    with pytest.raises(CheckpointError, match="unknown model"):
        extract_global_model({**base, "config": {"model": "nope"},
                              "server": {"w": w}})


def test_server_maps_only_committed_snapshots(tmp_path):
    _snap(tmp_path, 1)
    # a *newer* but uncommitted directory: in-flight write or crash debris
    partial = tmp_path / "round_00002"
    partial.mkdir()
    (partial / "a00000.s00.npy").write_bytes(b"garbage")
    with ModelServer(tmp_path) as server:
        assert server.poll()
        assert server.mapped_round == 1
        assert not server.poll()          # the partial does not exist to it
        assert server.mapped_round == 1 and server.failed_loads == 0
        # the write completes (committed) -> next poll maps it
        _snap(tmp_path, 2)
        assert server.poll()
        assert server.mapped_round == 2
    assert not list(tmp_path.glob("SERVING-*"))   # close() drops the claim


def test_staleness_monotone_and_logged(tmp_path):
    for r in (1, 2, 3):
        _snap(tmp_path, r)
    with ModelServer(tmp_path) as server:
        server.poll()                     # jumps straight to the newest
        assert server.mapped_round == 3 and server.rounds_behind == 0
        for r in (4, 5):
            _snap(tmp_path, r)
        server.poll()
        assert server.mapped_round == 5
        log = server.stats()["reloads"]
        assert [e["round"] for e in log] == [3, 5]   # never went backwards
        assert log[0]["behind"] == 0      # first map: nothing was behind
        assert log[1]["behind"] == 2      # was at 3 when 5 appeared


def test_hot_reload_does_not_change_inflight_outputs(tmp_path):
    """The tentpole serving invariant: a handle pinned before a reload
    keeps scoring with the old params bit-exactly; only newly pinned
    handles (and ``server.score``) see the new model."""
    _snap(tmp_path, 1, scale=1.0)
    rng = np.random.default_rng(0)
    x = make_request_batch(rng, 8, 2)
    with ModelServer(tmp_path) as server:
        server.poll()
        handle = server.pin()
        before = handle.score(x)
        _snap(tmp_path, 2, scale=-3.0)    # very different weights
        assert server.poll()              # hot swap while `handle` is live
        after_inflight = handle.score(x)
        after_server = server.score(x)
    np.testing.assert_array_equal(before, after_inflight)
    assert handle.round == 1
    assert not np.array_equal(before, after_server)


def test_prune_vs_reload_race_is_closed_by_claims(tmp_path):
    """Retention running next to a live server: the claim pins the mapped
    snapshot through a ``keep_last=1`` prune, the server keeps serving
    from it, and after it re-polls to the newest the next prune collects
    the old one."""
    _snap(tmp_path, 1)
    with ModelServer(tmp_path) as server:
        server.poll()
        for r in (2, 3):
            _snap(tmp_path, r)
        prune_checkpoints(tmp_path, keep_last=1)
        # mapped snapshot survived the prune (claimed), still scorable
        assert (tmp_path / "round_00001" / streaming.COMMIT_NAME).exists()
        server.score(make_request_batch(np.random.default_rng(0), 4, 2))
        assert server.poll()
        assert server.mapped_round == 3 and server.failed_loads == 0
        prune_checkpoints(tmp_path, keep_last=1)
        assert not (tmp_path / "round_00001").exists()
        assert (tmp_path / "round_00003" / streaming.COMMIT_NAME).exists()


def test_poll_on_empty_and_pin_before_map(tmp_path):
    with ModelServer(tmp_path / "nothing") as server:
        assert not server.poll()
        with pytest.raises(RuntimeError, match="no model mapped"):
            server.pin()


_TRAINER = textwrap.dedent("""
    import sys
    sys.path.insert(0, sys.argv[2])
    sys.path.insert(0, sys.argv[3])
    from benchmarks.common import ExperimentConfig, run_vectorized_experiment
    xc = ExperimentConfig(model="mlp", dataset=2, num_clients=8, rounds=3,
                          capacity=(12, 24), arrivals=4, batch=8, seed=5)
    run_vectorized_experiment("osafl", xc, eval_samples=32,
                              save_every_k=1, checkpoint_dir=sys.argv[1])
""")


def test_serve_loop_follows_live_trainer_subprocess(tmp_path):
    """End-to-end: a real trainer subprocess publishes async-v2 snapshots
    every round while this process serves — the loop maps committed
    snapshots only, reaches the final round, and never fails a load."""
    env = {**os.environ, "JAX_PLATFORMS": "cpu"}
    env.pop("PYTHONPATH", None)
    proc = subprocess.Popen(
        [sys.executable, "-c", _TRAINER, str(tmp_path / "ckpt"),
         str(ROOT / "src"), str(ROOT)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
    try:
        stats = serve_loop(tmp_path / "ckpt", until_round=3, poll_s=0.05,
                           batch=8, dataset=2, timeout_s=600.0)
    finally:
        out, err = proc.communicate(timeout=600)
    assert proc.returncode == 0, err
    assert stats["mapped_round"] == 3
    assert stats["failed_loads"] == 0, stats["last_error"]
    assert stats["mapped_rounds"] == sorted(set(stats["mapped_rounds"]))
    assert stats["batches"] > 0 and stats["requests_scored"] > 0
