"""Sparse-cohort server state (core/cohort.py, DESIGN.md "Sparse cohorts").

Four layers of proof:

  * **Dense parity** — the acceptance anchor: ``cohort_size = num_clients``
    makes the slot pool the identity map and the harness consumes the host
    RNG in exactly the dense order, so the sparse engine is BIT-EXACT
    against the dense stacked engine for every algorithm and both request
    backends.
  * **C < U semantics** — inactive users' carried tables (scores, stale-score
    carry, participation flags) are untouched by rounds they sit out; the
    OSAFL aggregation renormalizes its weights over the sampled cohort only
    (the width-C inner round equals a dense width-C server on the same
    inputs — the Dinh et al. 1910.13067 partial-participation rule);
    admission resets the slot's contribution row and eviction drops it.
  * **SlotPool properties** (tests/_hyp.py shim) — random
    admit/evict/readmit sequences hold the bijection invariants against a
    model-dict mirror (no aliasing, no leaked slots, FIFO eviction order),
    slot reuse wraps around the pool indefinitely, and snapshots taken
    mid-sequence round-trip and continue in lockstep.
  * **Mesh behavior** — on a faked 8-device mesh (subprocess; jax locks the
    device count at first init) the per-user tables carry explicit
    NamedSharding over the client axes, the 2x4 sparse pod run matches the
    1-device mesh, cohort_size must divide the mesh's client rows, and a
    sparse pod snapshot refuses to resume onto a different mesh shape.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import (ALL_ALGS, ExperimentConfig, build_fused_engine,
                               run_experiment, run_vectorized_experiment)
from repro.checkpoint import CheckpointError, validate_cohort_shapes
from repro.configs.base import FLConfig
from repro.core.baselines import make_server
from repro.core.cohort import (SlotPool, SparseCohortServer,
                               sample_participants)
from repro.core.osafl import StackedOSAFLServer

from _hyp import given, settings, st

METRICS = ("round", "test_loss", "test_acc", "participants")


def _xc(**kw) -> ExperimentConfig:
    base = dict(model="mlp", dataset=2, num_clients=8, rounds=3,
                capacity=(12, 24), arrivals=4, batch=8, seed=5)
    base.update(kw)
    return ExperimentConfig(**base)


def _params():
    """Tiny two-leaf pytree — the server math is size-agnostic."""
    return {"a": jnp.arange(6, dtype=jnp.float32) / 7.0,
            "b": jnp.ones((2, 3), jnp.float32)}


def _sparse_server(alg="osafl", U=8, C=4, seed=0, mesh=None, **fl_kw):
    fl = FLConfig(num_clients=U, local_lr=0.1, global_lr=1.0,
                  algorithm=alg, engine="stacked", cohort_size=C, **fl_kw)
    srv = make_server(_params(), fl, U, seed=seed, mesh=mesh)
    assert isinstance(srv, SparseCohortServer)
    return srv


# ---------------------------------------------------------------------------
# dense parity: cohort_size = U is bit-exact for every algorithm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alg", ALL_ALGS)
def test_cohort_size_U_bit_exact_vs_dense(alg):
    """The acceptance anchor: the sparse engine at C = U reproduces the
    dense stacked trajectory bit-for-bit — same host RNG draws, identity
    slot map, same inner round math."""
    dense = run_vectorized_experiment(alg, _xc(), eval_samples=64)
    sparse = run_vectorized_experiment(alg, _xc(cohort_size=8),
                                       eval_samples=64)
    for a, b in zip(dense, sparse):
        for k in METRICS:
            assert a[k] == b[k], (alg, k, a, b)


def test_cohort_size_U_bit_exact_stacked_requests():
    """Same anchor on the batched Gumbel request backend (the sparse branch
    draws (U,)-wide counts so the device request stream advances
    identically)."""
    dense = run_vectorized_experiment(
        "osafl", _xc(request_backend="stacked"), eval_samples=64)
    sparse = run_vectorized_experiment(
        "osafl", _xc(request_backend="stacked", cohort_size=8),
        eval_samples=64)
    for a, b in zip(dense, sparse):
        for k in METRICS:
            assert a[k] == b[k], (k, a, b)


# ---------------------------------------------------------------------------
# C < U: untouched carries, cohort renormalization, slot-row lifecycle
# ---------------------------------------------------------------------------

def test_inactive_users_tables_untouched():
    """Users outside the cohort keep their initial scores / stale-score
    carry / participation flags through rounds they sit out."""
    srv = _sparse_server("osafl", U=8, C=4)
    srv.admit([0, 2, 4, 6])
    N = int(srv.w.shape[0])
    rng = np.random.default_rng(0)
    for _ in range(3):
        d = jnp.asarray(rng.normal(size=(4, N)).astype(np.float32))
        srv.round_stacked(d, jnp.ones(4, bool))
    scores = np.asarray(srv.tables["scores"])
    lam_prev = np.asarray(srv.tables["lam_prev"])
    part = np.asarray(srv.tables["participated"])
    for u in (1, 3, 5, 7):                       # never admitted
        assert scores[u] == 1.0 and lam_prev[u] == 1.0 and not part[u]
    for u in (0, 2, 4, 6):                       # trained every round
        assert part[u]
    # no dense (U, N) ghost anywhere in the engine
    assert srv.inner.d_buffer.shape == (4, N)
    assert srv.state_dict()["inner"]["d_buffer"].shape == (4, N)


def test_osafl_renormalizes_over_sampled_cohort_only():
    """The sparse round on a C-slot cohort equals a *dense* width-C OSAFL
    server on the same inputs: uniform 1/C aggregation weights over the
    sampled cohort, not 1/U over the registration book."""
    srv = _sparse_server("osafl", U=8, C=4, seed=3)
    srv.admit([5, 1, 7, 3])                      # arbitrary user ids
    ref = StackedOSAFLServer(
        _params(), FLConfig(num_clients=4, local_lr=0.1, global_lr=1.0,
                            engine="stacked"), 4, seed=3)
    np.testing.assert_array_equal(np.asarray(srv.alphas),
                                  np.full(4, 0.25, np.float32))
    N = int(srv.w.shape[0])
    rng = np.random.default_rng(1)
    for r in range(2):
        d = jnp.asarray(rng.normal(size=(4, N)).astype(np.float32))
        active = jnp.asarray([True, True, r == 0, True])
        ws = srv.round_stacked(d, active)
        wr = ref.round_stacked(d, active)
        np.testing.assert_array_equal(np.asarray(ws), np.asarray(wr))
    np.testing.assert_array_equal(np.asarray(srv.last_scores)[[5, 1, 7, 3]],
                                  np.asarray(ref.last_scores))


def test_eviction_drops_slot_row_and_readmission_resets_it():
    srv = _sparse_server("osafl", U=6, C=2)
    srv.admit([0, 1])
    N = int(srv.w.shape[0])
    srv.round_stacked(jnp.ones((2, N), jnp.float32), jnp.ones(2, bool))
    score0 = float(np.asarray(srv.tables["scores"])[0])
    row0 = np.asarray(srv.inner.d_buffer[0]).copy()
    assert not np.array_equal(row0, np.asarray(srv.inner.init_row()))
    # admitting user 2 evicts the oldest-seated resident (user 0) and
    # resets that slot's contribution row to the refresh value
    res = srv.admit([2])
    assert res.evicted.tolist() == [0] and res.newly.all()
    s = int(res.slots[0])
    np.testing.assert_array_equal(np.asarray(srv.inner.d_buffer[s]),
                                  np.asarray(srv.inner.init_row()))
    # the evicted user's carried score survived in the table and rides back
    # in on readmission — only the slot-resident contribution row was lost
    res2 = srv.admit([0])
    assert res2.newly.all()
    s0 = int(res2.slots[0])
    assert float(np.asarray(srv.inner.last_scores)[s0]) == score0
    np.testing.assert_array_equal(np.asarray(srv.inner.d_buffer[s0]),
                                  np.asarray(srv.inner.init_row()))


def test_baseline_meta_carries_across_eviction():
    """FedNova/FedDisco per-user metadata (sizes, kappas, histograms) is
    carried in (U,) host tables and restored on readmission."""
    srv = _sparse_server("fednova", U=6, C=2)
    srv.admit([0, 1])
    N = int(srv.w.shape[0])
    srv.round_stacked(jnp.ones((2, N), jnp.float32), jnp.ones(2, bool),
                      sizes=np.array([10.0, 20.0]),
                      kappas=np.array([3.0, 4.0]))
    srv.admit([2, 3])                            # evicts both residents
    assert srv.pool.resident([0, 1]).tolist() == [False, False]
    res = srv.admit([0])                         # readmit user 0
    s = int(res.slots[0])
    assert srv.inner.sizes[s] == 10.0 and srv.inner.kappas[s] == 3.0


@pytest.mark.parametrize("alg,backend", [("osafl", "python"),
                                         ("fednova", "stacked")])
def test_sparse_harness_churn_runs(alg, backend):
    """End-to-end C < U with participation sampling: admissions, evictions
    and buffer resets every round; metrics stay finite and the round-active
    cohort is bounded by participation * C."""
    xc = _xc(num_clients=16, rounds=4, cohort_size=4, participation=0.75,
             request_backend=backend)
    hist = run_vectorized_experiment(alg, xc, eval_samples=64)
    assert [h["round"] for h in hist] == list(range(4))
    assert all(np.isfinite(h["test_loss"]) for h in hist)
    assert all(h["participants"] <= 3 for h in hist)


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_sparse_engine_guard_rails():
    with pytest.raises(ValueError, match="cohort_size"):
        run_vectorized_experiment("osafl", _xc(cohort_size=9),
                                  eval_samples=16)
    with pytest.raises(ValueError, match="participation"):
        run_vectorized_experiment("osafl", _xc(cohort_size=4,
                                               participation=1.5),
                                  eval_samples=16)
    # participation sampling without the slot pool has no defined dense
    # semantics — reject rather than silently ignore
    with pytest.raises(ValueError, match="cohort_size"):
        run_vectorized_experiment("osafl", _xc(participation=0.5),
                                  eval_samples=16)
    # the loop engine and the fused round are dense-only
    with pytest.raises(ValueError, match="slot-pool"):
        run_experiment("osafl", _xc(cohort_size=4), eval_samples=16)
    with pytest.raises(ValueError, match="dense-only"):
        build_fused_engine("osafl", _xc(cohort_size=4,
                                        request_backend="stacked",
                                        round_backend="fused"))
    with pytest.raises(ValueError, match="stacked"):
        make_server(_params(), FLConfig(cohort_size=4), 8)


# ---------------------------------------------------------------------------
# SlotPool properties (hypothesis via the tests/_hyp.py shim)
# ---------------------------------------------------------------------------

def _apply_ops(pool, model, ops, U):
    """Drive pool + a model-dict mirror through an op list; verify every
    AdmitResult against the mirror and the pool invariants after each op.

    ``model`` maps resident user -> seating tick (insertion-ordered FIFO
    mirror of the pool's admit_seq clocks)."""
    tick = [max(model.values(), default=-1) + 1]
    for op in ops:
        u = op % U
        if (op // U) % 3 == 0 and u in model or (op // U) % 3 == 2:
            freed = pool.evict([u])
            if u in model:
                assert freed.size == 1
                del model[u]
            else:
                assert freed.size == 0
        else:
            res = pool.admit([u])
            assert int(pool.user_slot[u]) == int(res.slots[0])
            assert int(pool.slot_user[res.slots[0]]) == u
            if u in model:
                assert not res.newly[0] and res.evicted.size == 0
            else:
                assert res.newly[0]
                if len(model) == pool.C:          # full -> FIFO eviction
                    oldest = min(model, key=model.get)
                    assert res.evicted.tolist() == [oldest]
                    del model[oldest]
                else:
                    assert res.evicted.size == 0
                model[u] = tick[0]
                tick[0] += 1
        pool.check()
        assert sorted(model) == sorted(
            np.flatnonzero(pool.user_slot >= 0).tolist())
        assert pool.occupancy == len(model)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 5), st.integers(1, 8),
       st.lists(st.integers(0, 999), min_size=1, max_size=40))
def test_slot_pool_admit_evict_readmit_roundtrips(C, extra, ops):
    """Random admit/evict/readmit sequences: the user<->slot maps stay a
    bijection (no aliasing, no leaked slots), evictions are FIFO by seating
    order, and every AdmitResult matches an insertion-ordered model dict."""
    U = C + extra
    pool = SlotPool(U, C)
    pool.check()
    _apply_ops(pool, {}, ops, U)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 5), st.integers(1, 8),
       st.lists(st.integers(0, 999), min_size=1, max_size=24),
       st.lists(st.integers(0, 999), min_size=1, max_size=24))
def test_slot_pool_snapshot_roundtrip_mid_sequence(C, extra, ops_a, ops_b):
    """A snapshot taken mid-sequence restores into a fresh pool that then
    evolves in exact lockstep with the original."""
    U = C + extra
    pool = SlotPool(U, C)
    model = {}
    _apply_ops(pool, model, ops_a, U)
    sd = pool.state_dict()
    clone = SlotPool(U, C)
    clone.load_state_dict(sd)
    for k, v in clone.state_dict().items():
        np.testing.assert_array_equal(v, sd[k])
    _apply_ops(pool, dict(model), ops_b, U)
    _apply_ops(clone, dict(model), ops_b, U)
    for k, v in clone.state_dict().items():
        np.testing.assert_array_equal(v, pool.state_dict()[k])


def test_slot_pool_fifo_wraparound():
    """> C admissions cycle slot reuse through the whole pool repeatedly:
    every slot is reused, eviction order stays FIFO, invariants hold."""
    U, C = 12, 4
    pool = SlotPool(U, C)
    seated = []
    used = set()
    for u in range(U):                           # 3 full generations
        res = pool.admit([u])
        assert res.newly[0]
        used.add(int(res.slots[0]))
        seated.append(u)
        if len(seated) > C:
            die = seated.pop(0)
            assert res.evicted.tolist() == [die]
        pool.check()
    assert used == set(range(C))                 # every slot reused
    assert sorted(pool.cohort.tolist()) == list(range(U - C, U))
    # explicit evictions free oldest-freed-first for the next admissions
    pool.evict([U - 2, U - 4])
    ra = pool.admit([0])
    rb = pool.admit([1])
    assert int(ra.slots[0]) == int(pool.user_slot[0])
    assert {int(ra.slots[0]), int(rb.slots[0])} == \
        {int(np.flatnonzero(np.isin(pool.slot_user, [0]))[0]),
         int(np.flatnonzero(np.isin(pool.slot_user, [1]))[0])}
    pool.check()


def test_slot_pool_rejects_bad_admissions():
    pool = SlotPool(8, 3)
    with pytest.raises(ValueError, match="1 <= C <= U"):
        SlotPool(4, 5)
    with pytest.raises(ValueError, match="duplicate"):
        pool.admit([1, 1])
    with pytest.raises(ValueError, match=r"\[0, 8\)"):
        pool.admit([8])
    with pytest.raises(ValueError, match="3 slots"):
        pool.admit([0, 1, 2, 3])
    pool.check()                                 # failed calls left no trace
    assert pool.occupancy == 0


def test_validate_cohort_shapes_checks_U_and_C_independently():
    """The restore path reports *which* of the two scales mismatches — a
    wrong user-table length and a wrong slot capacity are different repair
    stories and used to be one fused shape check."""
    sd = SlotPool(8, 4).state_dict()
    validate_cohort_shapes(sd, 8, 4)             # matching: no raise
    with pytest.raises(CheckpointError, match="capacity C=4"):
        validate_cohort_shapes(sd, 8, 3)
    with pytest.raises(CheckpointError, match="U=8 registered"):
        validate_cohort_shapes(sd, 6, 4)
    with pytest.raises(CheckpointError, match="slot-map keys"):
        validate_cohort_shapes({"user_slot": sd["user_slot"]}, 8, 4)
    with pytest.raises(CheckpointError, match="capacity"):
        SlotPool(8, 3).load_state_dict(sd)
    with pytest.raises(CheckpointError, match="registered"):
        SlotPool(6, 4).load_state_dict(sd)


def test_sparse_server_refuses_dense_snapshot():
    srv = _sparse_server("osafl", U=8, C=4)
    with pytest.raises(CheckpointError, match="dense-engine"):
        srv.load_state_dict({"w": np.zeros(4)})


# ---------------------------------------------------------------------------
# multi-device: faked 8-device mesh in a subprocess (pattern from
# tests/test_pod_online.py — jax locks the device count at first init)
# ---------------------------------------------------------------------------

def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.splitlines()[-1])


_SUBPROCESS_SPARSE_MESH = textwrap.dedent("""
    import dataclasses, json, tempfile
    import numpy as np, jax
    from benchmarks.common import (ExperimentConfig, checkpoint_path,
                                   run_pod_online_experiment)
    from repro.checkpoint import CheckpointError
    from repro.configs.base import FLConfig
    from repro.core.baselines import make_server
    from repro.models.small import init_small

    mesh24 = jax.make_mesh((2, 4, 1), ("pod", "data", "model"))
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))

    # the per-user carry tables take explicit NamedSharding over the
    # ('pod','data') client axes — all 8 devices own rows
    srv = make_server(init_small(jax.random.PRNGKey(0), "mlp"),
                      FLConfig(num_clients=16, engine="stacked",
                               cohort_size=8),
                      16, seed=0, mesh=mesh24)
    tables_sharded = all(
        len(srv.tables[k].sharding.device_set) == 8
        for k in srv.tables.keys())

    xc = ExperimentConfig(model="mlp", dataset=2, num_clients=16, rounds=3,
                          capacity=(12, 24), arrivals=4, batch=8, seed=5,
                          cohort_size=8, participation=0.75,
                          request_backend="stacked")
    with tempfile.TemporaryDirectory() as td:
        h24 = run_pod_online_experiment("osafl", xc, eval_samples=64,
                                        mesh=mesh24, save_every_k=3,
                                        checkpoint_dir=td)
        h1 = run_pod_online_experiment("osafl", xc, eval_samples=64,
                                       mesh=mesh1)
        dloss = max(abs(a["test_loss"] - b["test_loss"])
                    for a, b in zip(h24, h1))
        parts_ok = all(a["participants"] == b["participants"]
                       for a, b in zip(h24, h1))
        # a sparse pod snapshot refuses to resume onto a different mesh
        try:
            run_pod_online_experiment(
                "osafl", dataclasses.replace(xc, rounds=5), eval_samples=64,
                mesh=mesh1, resume_from=checkpoint_path(td, 3))
            mesh_refused = False
        except CheckpointError:
            mesh_refused = True
    # cohort_size must divide the mesh's client rows (whole slots per shard)
    try:
        run_pod_online_experiment(
            "osafl", dataclasses.replace(xc, cohort_size=4),
            eval_samples=64, mesh=mesh24)
        divisible_ok = False
    except ValueError as e:
        divisible_ok = "cohort_size" in str(e)
    print(json.dumps({"tables_sharded": tables_sharded, "dloss": dloss,
                      "parts_ok": parts_ok, "mesh_refused": mesh_refused,
                      "divisible_ok": divisible_ok,
                      "finite": all(np.isfinite(h["test_loss"])
                                    for h in h24)}))
""")


def test_sparse_pod_run_on_8_device_mesh():
    res = _run_sub(_SUBPROCESS_SPARSE_MESH)
    assert res["tables_sharded"], res
    assert res["finite"], res
    assert res["parts_ok"], res
    assert res["mesh_refused"], res
    assert res["divisible_ok"], res
    assert res["dloss"] <= 1e-5, res


# ---------------------------------------------------------------------------
# scenario-churn sequences: participation sampling, clocks, carried state
# ---------------------------------------------------------------------------

def test_sample_participants_contract():
    """The no-bias path is byte-for-byte the historical draw (the null-
    scenario anchor); availability masks exclude users entirely; an
    all-away round trains nobody; malformed weights are rejected."""
    plain = sample_participants(np.random.default_rng(9), 10, 4)
    hist = np.sort(np.random.default_rng(9).choice(10, size=4,
                                                   replace=False))
    np.testing.assert_array_equal(plain, hist)
    avail = np.zeros(10, bool)
    avail[[2, 5]] = True
    sel = sample_participants(np.random.default_rng(0), 10, 4,
                              available=avail)
    assert set(sel.tolist()) == {2, 5}            # shrinks to the eligible
    assert sample_participants(np.random.default_rng(0), 10, 3,
                               available=np.zeros(10, bool)).size == 0
    w = np.zeros(10)
    w[7] = 3.0
    np.testing.assert_array_equal(
        sample_participants(np.random.default_rng(0), 10, 2, weights=w),
        [7])
    with pytest.raises(ValueError, match="shape"):
        sample_participants(np.random.default_rng(0), 10, 2,
                            weights=np.ones(4))
    with pytest.raises(ValueError, match="non-negative"):
        sample_participants(np.random.default_rng(0), 10, 2,
                            weights=-np.ones(10))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(1, 8), st.integers(0, 10 ** 6),
       st.integers(3, 10))
def test_slot_pool_churn_sequences_bijection_and_clock_monotonic(
        C, extra, seed, rounds):
    """Scenario-style churn: each round an availability mask departs a
    random subset and Pareto weights bias the participation sample; the
    sampled users are admitted under slot pressure. Through arbitrary
    depart/rejoin interleavings the user<->slot maps stay a bijection,
    departed users are never seated, and the FIFO clocks advance strictly
    monotonically (every newly seated slot's admit tick exceeds every tick
    issued before it)."""
    U = C + extra
    rng = np.random.default_rng(seed)
    pool = SlotPool(U, C)
    weights = rng.pareto(1.5, U) + 1.0
    last_tick = int(pool.state_dict()["clock"]) - 1
    for t in range(rounds):
        avail = rng.random(U) >= 0.4
        m = int(rng.integers(1, C + 1))
        sel = sample_participants(rng, U, m, weights=weights,
                                  available=avail)
        assert avail[sel].all()                   # departed never sampled
        res = pool.admit(sel)
        pool.check()
        ticks = np.sort(pool.admit_seq[res.slots[res.newly]])
        assert (ticks > last_tick).all(), "admit clock went backwards"
        if ticks.size:
            last_tick = int(ticks[-1])
        resident = np.flatnonzero(pool.user_slot >= 0)
        assert np.isin(sel, resident).all()       # the whole sample seated
        assert resident.size <= C


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=10))
def test_scores_and_staleness_carry_across_churn(seq):
    """Arbitrary depart/rejoin (eviction pressure) sequences on the sparse
    OSAFL server: a user's score / stale-score carry / participation flag
    ride back in on readmission exactly as last written, and users sitting
    out keep their table rows untouched — only the slot-resident
    contribution row is lost on eviction (the documented semantics)."""
    srv = _sparse_server("osafl", U=6, C=2, seed=1)
    rng = np.random.default_rng(7)
    N = int(srv.w.shape[0])
    expected = {u: (1.0, 1.0, False) for u in range(6)}
    for u in seq:
        res = srv.admit([u])
        s = int(res.slots[0])
        if res.newly[0]:
            # carried per-user state was gathered into the slot...
            assert float(np.asarray(srv.inner.last_scores)[s]) == \
                expected[u][0]
            assert float(np.asarray(srv.inner._lam_prev)[s]) == \
                expected[u][1]
            assert bool(np.asarray(srv.inner.participated)[s]) == \
                expected[u][2]
            # ...and the contribution row was reset to the refresh value
            np.testing.assert_array_equal(
                np.asarray(srv.inner.d_buffer[s]),
                np.asarray(srv.inner.init_row()))
        cohort = srv.cohort
        live = cohort >= 0
        d = jnp.asarray(rng.normal(size=(2, N)).astype(np.float32))
        srv.round_stacked(d, jnp.asarray(live))
        scores = np.asarray(srv.tables["scores"])
        lam = np.asarray(srv.tables["lam_prev"])
        part = np.asarray(srv.tables["participated"])
        for uu in cohort[live].tolist():
            expected[uu] = (float(scores[uu]), float(lam[uu]),
                            bool(part[uu]))
        # everyone else's table rows are exactly their carried values
        for uu in set(range(6)) - set(cohort[live].tolist()):
            assert (float(scores[uu]), float(lam[uu]),
                    bool(part[uu])) == expected[uu], f"user {uu} drifted"
