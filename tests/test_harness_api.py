"""The unified harness API: ``repro.harness.run`` + the declarative
config-compatibility matrix (``repro.harness.compat``).

Covers: every matrix rule fires through ``run()``/``validate()`` with the
one uniform error format (parametrized over the full ``RULES`` table — a
new rule without a sweep entry fails the coverage test); ``resolve()``
produces a describable plan for the valid engine corners; the deprecated
``run_*`` entry points still exist (as documented shims re-exported from
``benchmarks.common``) and dispatch to the same engines."""
import dataclasses
import re

import numpy as np
import pytest

from repro.harness import (ExperimentConfig, ExperimentConfigError,
                           resolve, run)
from repro.harness.compat import RULES

_FMT = re.compile(r"^invalid experiment configuration \[[a-z-]+\]: .+")


def _xc(**kw):
    return ExperimentConfig(**dict(
        dict(model="mlp", dataset=2, num_clients=8, rounds=2,
             capacity=(12, 24), arrivals=4, batch=8, seed=5), **kw))


# one sweep entry per matrix rule: (rule key, alg, config overrides)
INVALID = [
    ("engine", "osafl", dict(engine="turbo")),
    ("algorithm", "sgd", dict()),
    ("request-backend", "osafl", dict(request_backend="np")),
    ("round-backend", "osafl", dict(round_backend="turbo")),
    ("resource-backend", "osafl", dict(resource_backend="f16")),
    ("pod-engine", "osafl", dict(engine="pod", pod_engine="nope")),
    ("cohort-size", "osafl", dict(cohort_size=9)),
    ("participation", "osafl", dict(participation=1.5)),
    ("participation-pool", "osafl", dict(participation=0.5)),
    ("num-clusters", "osafl", dict(num_clusters=-1)),
    ("oracle-requests", "osafl",
     dict(engine="loop", request_backend="stacked")),
    ("oracle-cohort", "osafl", dict(engine="loop", cohort_size=4)),
    ("fused-engine", "osafl",
     dict(engine="pod", round_backend="fused", request_backend="stacked")),
    ("rounds-per-dispatch", "osafl",
     dict(round_backend="fused", request_backend="stacked",
          rounds_per_dispatch=0)),
    ("fused-alg", "fedavg",
     dict(round_backend="fused", request_backend="stacked")),
    ("fused-requests", "osafl", dict(round_backend="fused")),
    ("fused-cohort", "osafl",
     dict(round_backend="fused", request_backend="stacked", cohort_size=4)),
    ("fused-hierarchy", "osafl",
     dict(round_backend="fused", request_backend="stacked", num_clusters=2)),
    ("hier-engine", "osafl", dict(engine="loop", num_clusters=1)),
    ("hier-population", "osafl", dict(num_clusters=3)),
    ("hier-cohort", "osafl", dict(num_clusters=2, cohort_size=5)),
    ("scenario-engine", "osafl", dict(engine="loop", scenario="churn()")),
    ("scenario-fused", "osafl",
     dict(round_backend="fused", request_backend="stacked",
          scenario="churn()")),
    ("cluster-churn", "osafl",
     dict(num_clusters=2, scenario="cluster_churn()")),
]


@pytest.mark.parametrize("key,alg,overrides",
                         INVALID, ids=[k for k, _, _ in INVALID])
def test_invalid_combo_raises_uniform_error(key, alg, overrides):
    with pytest.raises(ExperimentConfigError) as ei:
        _xc(**overrides).validate(alg)
    assert ei.value.key == key
    assert _FMT.match(str(ei.value)), str(ei.value)
    assert isinstance(ei.value, ValueError)      # old except clauses survive
    # run() raises identically (validation happens before any engine work)
    with pytest.raises(ExperimentConfigError) as ei2:
        run(alg, _xc(**overrides))
    assert ei2.value.key == key


def test_sweep_covers_every_rule():
    assert {k for k, _, _ in INVALID} == {r.key for r in RULES}


def test_resolve_auto_engine():
    assert resolve("osafl", _xc()).engine == "stacked"
    assert resolve("centralized", _xc()).engine == "centralized"
    assert resolve("osafl", _xc(), mesh=object()).engine == "pod"
    assert resolve("osafl", _xc(engine="loop")).engine == "loop"
    # pod_engine is only part of the plan on the pod path
    assert resolve("osafl", _xc()).pod_engine is None
    assert resolve("osafl", _xc(), mesh=object(),
                   pod_engine="stale").pod_engine == "stale"


def test_describe_names_the_combination():
    line = resolve("osafl", _xc(request_backend="stacked", cohort_size=4,
                                participation=0.5,
                                num_clusters=2)).describe()
    for bit in ("engine=stacked", "alg=osafl", "request=stacked",
                "cohort=4/8", "participation=0.5", "clusters=2"):
        assert bit in line, line


def test_scenario_parse_errors_stay_plain_valueerrors():
    with pytest.raises(ValueError, match="unknown scenario"):
        _xc(scenario="not_a_scenario()").validate("osafl")


def test_shims_are_documented_deprecations():
    from benchmarks import common
    for name in ("run_experiment", "run_vectorized_experiment",
                 "run_pod_online_experiment", "run_centralized_sgd"):
        assert "Deprecated" in getattr(common, name).__doc__
        # the shim and the harness export are the same callable
        import repro.harness as harness
        assert getattr(common, name) is getattr(harness, name)


def test_run_dispatches_each_engine():
    xc = _xc()
    stacked = run("osafl", xc, eval_samples=64)
    loop = run("osafl", dataclasses.replace(xc, engine="loop"),
               eval_samples=64)
    genie = run("centralized", xc, eval_samples=64)
    for hist in (stacked, loop, genie):
        assert len(hist) == xc.rounds
        assert all(np.isfinite(h["test_loss"]) for h in hist)
    # pinned engine == auto-resolved engine, bit for bit
    auto = run("osafl", dataclasses.replace(xc, engine="stacked"),
               eval_samples=64)
    assert [h["test_loss"] for h in auto] == \
        [h["test_loss"] for h in stacked]


def test_centralized_rejects_checkpoint_args(tmp_path):
    with pytest.raises(ValueError, match="does not checkpoint"):
        run("centralized", _xc(), save_every_k=1, checkpoint_dir=tmp_path)
