import os

# Tests run on the single real CPU device. Only dryrun subprocess tests use
# --xla_force_host_platform_device_count, in their own interpreter.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
