"""The fused single-dispatch round (``core/round_fused``) vs the
multi-dispatch engine.

The load-bearing check is *replay bit-parity*: the fused engine's per-round
device draws (``round_keys``/``draw_counts``/``draw_shadowing_db``/
``draw_slots``) are public, so the EXISTING multi-dispatch components —
stacked request stream, FIFO stage/commit, scoped-x64 resource solve,
vmapped local SGD, scored server round — can be driven with exactly the
draws the fused program consumes. With ``resource_backend="x64"`` the two
paths must then be bit-identical: same losses, same participants, same
final weights, same buffer and stream state. Everything else (f32 backend
tolerance, segmentation/resume invariance, the one-executable HLO claim)
layers on top of that anchor.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from benchmarks.common import (ExperimentConfig, build_fused_engine,
                               checkpoint_path, run_experiment,
                               run_pod_online_experiment,
                               run_vectorized_experiment)
from repro.core import round_fused as rf
from repro.core.client import make_vmapped_local_train
from repro.core.resource import pathloss_linear
from repro.core.resource_stacked import (ChannelBatch, ResourceSolveError,
                                         optimize_clients_batched)
from repro.data.video_caching import make_population
from repro.data.video_caching_stacked import StackedRequestStream
from repro.models.small import small_loss

R = 4
XC = ExperimentConfig(model="mlp", dataset=2, num_clients=8, rounds=R,
                      capacity=(12, 24), arrivals=4, batch=8, seed=5,
                      request_backend="stacked", round_backend="fused",
                      resource_backend="x64", rounds_per_dispatch=R)


@pytest.fixture(scope="module")
def x64_run():
    """One R-round fused segment on the x64 parity backend: (engine, final
    carry, host outs). Module-scoped — the compiled segment is reused by the
    replay, f32 and HLO tests."""
    eng, s = build_fused_engine("osafl", XC)
    carry = eng.init_carry(s.server, s.sbuf, s.rstream, 0)
    carry, outs = eng.run_segment(carry, R)
    return eng, carry, jax.tree.map(np.asarray, outs)


def test_replay_bit_parity_x64(x64_run):
    """Drive the multi-dispatch components with the fused engine's device
    draws: every per-round output and every piece of final state must be
    bit-equal to the fused x64 segment."""
    _, carry, outs = x64_run
    eng2, s2 = build_fused_engine("osafl", XC)
    local_step = make_vmapped_local_train(
        s2.grad_fn, s2.fl.local_lr, s2.fl.kappa_max, prox_mu=0.0)
    xi = pathloss_linear(s2.sysb.distance)
    losses, accs, parts = [], [], []
    for t in range(R):
        k_arr, k_chan, k_slots = rf.round_keys(eng2.base_key, t)
        counts = np.asarray(rf.draw_counts(k_arr, eng2.p_ac, XC.arrivals))
        s2.sbuf.stage(*s2.rstream.draw(counts, XC.dataset, XC.arrivals))
        s2.sbuf.commit()
        # the dB->linear conversion must happen on device in f64 (host numpy
        # ** can differ in the last ulp) — same contract as the fused body
        with enable_x64():
            gamma = np.asarray(10.0 ** (
                rf.draw_shadowing_db(k_chan, s2.U).astype(jnp.float64)
                / 10.0))
        dec = optimize_clients_batched(
            s2.net, s2.sysb, ChannelBatch(xi=xi, gamma=gamma), s2.n_params,
            backend="x64")
        kappas, active = dec.kappa, dec.kappa >= 1
        st = s2.sbuf.state
        slots = np.asarray(rf.draw_slots(k_slots, st.size, st.head, st.cap,
                                         (s2.fl.kappa_max, XC.batch)))
        d, _ = local_step(s2.server.params, s2.sbuf.gather(slots),
                          jnp.asarray(kappas))
        s2.server.round_stacked(s2.codec.flatten_stacked(d), active)
        loss, m = small_loss(s2.server.params, s2.test_batch, s2.model)
        losses.append(float(loss))
        accs.append(float(m["accuracy"]))
        parts.append(int(active.sum()))
    assert outs["test_loss"].tolist() == np.array(
        losses, np.float32).tolist()
    assert outs["test_acc"].tolist() == np.array(accs, np.float32).tolist()
    assert outs["participants"].tolist() == parts
    assert np.array_equal(np.asarray(carry.w), np.asarray(s2.server.w))
    assert np.array_equal(np.asarray(carry.d_buffer),
                          np.asarray(s2.server.d_buffer))
    assert np.array_equal(np.asarray(carry.buf.y),
                          np.asarray(s2.sbuf.state.y))
    assert np.array_equal(np.asarray(carry.buf.x),
                          np.asarray(s2.sbuf.state.x))
    assert np.array_equal(np.asarray(carry.stream.key),
                          np.asarray(s2.rstream.state.key))
    assert np.array_equal(outs["lam_use"][-1],
                          np.asarray(s2.server.last_scores, np.float32))


def test_f32_backend_matches_x64(x64_run):
    """The f32 log-domain resource solve must agree with the x64 oracle on
    the default (non-knife-edge) population: identical kappa decisions ->
    identical participant sets and training trajectory to f32 eval noise
    (documented bound: |test_loss| diff <= 1e-5 relative; exact equality is
    typical because both programs draw identical f32 bits)."""
    _, carry, outs = x64_run
    eng, s = build_fused_engine(
        "osafl", dataclasses.replace(XC, resource_backend="f32"))
    c32 = eng.init_carry(s.server, s.sbuf, s.rstream, 0)
    c32, o32 = eng.run_segment(c32, R)
    o32 = jax.tree.map(np.asarray, o32)
    assert o32["participants"].tolist() == outs["participants"].tolist()
    np.testing.assert_allclose(o32["test_loss"], outs["test_loss"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(c32.w), np.asarray(carry.w),
                               rtol=1e-4, atol=1e-5)
    assert not o32["bad_solve"].any()


def test_segment_invariance():
    """rounds [0, R) as one segment vs two segments of R/2: the absolute-
    round keying makes the split invisible — bit-identical outputs."""
    eng, s = build_fused_engine(
        "osafl", dataclasses.replace(XC, resource_backend="f32"))
    carry = eng.init_carry(s.server, s.sbuf, s.rstream, 0)
    carry, o_full = eng.run_segment(carry, R)
    eng2, s2 = build_fused_engine(
        "osafl", dataclasses.replace(XC, resource_backend="f32"))
    c2 = eng2.init_carry(s2.server, s2.sbuf, s2.rstream, 0)
    c2, o_a = eng2.run_segment(c2, R // 2)
    c2, o_b = eng2.run_segment(c2, R // 2)
    o_split = jax.tree.map(
        lambda a, b: np.concatenate([np.asarray(a), np.asarray(b)]),
        o_a, o_b)
    for k in ("test_loss", "test_acc", "participants"):
        assert np.array_equal(np.asarray(o_full[k]), o_split[k]), k
    assert np.array_equal(np.asarray(carry.w), np.asarray(c2.w))
    assert np.array_equal(np.asarray(carry.t), np.asarray(c2.t))


def test_harness_fused_checkpoint_resume(tmp_path):
    """The fused harness truncates segments at checkpoint boundaries and a
    resume from a mid-run RunState snapshot continues bit-exactly."""
    fxc = dataclasses.replace(XC, resource_backend="f32")
    da, db = tmp_path / "a", tmp_path / "b"
    ha = run_vectorized_experiment("osafl", fxc, eval_samples=64,
                                   save_every_k=R, checkpoint_dir=da)
    run_vectorized_experiment("osafl", fxc, eval_samples=64,
                              save_every_k=2, checkpoint_dir=db)
    hb = run_vectorized_experiment("osafl", fxc, eval_samples=64,
                                   save_every_k=2, checkpoint_dir=db,
                                   resume_from=checkpoint_path(db, 2))
    assert [h["test_loss"] for h in ha] == [h["test_loss"] for h in hb]
    assert [h["participants"] for h in ha] == \
        [h["participants"] for h in hb]
    # and the fused harness agrees with the direct-engine segment
    eng, s = build_fused_engine("osafl", fxc, eval_samples=64)
    carry = eng.init_carry(s.server, s.sbuf, s.rstream, 0)
    _, outs = eng.run_segment(carry, R)
    assert [h["test_loss"] for h in ha] == \
        np.asarray(outs["test_loss"]).astype(float).tolist()


def test_single_dispatch_hlo(x64_run):
    """The one-dispatch claim, checked on the optimized HLO: one module, one
    entry computation, and a while loop whose trip count is the segment
    length (the rounds scan stayed a scan)."""
    from repro.launch.hlo_analysis import dispatch_report
    eng, _, _ = x64_run
    rep = dispatch_report(eng.compiled_text(R), rounds_per_dispatch=R)
    assert rep["hlo_modules"] == 1
    assert rep["entry_computations"] == 1
    assert rep["scan_carries_rounds"], rep["while_trip_counts"]
    assert rep["single_dispatch"]


def test_check_outputs_raises_on_bad_solve():
    with pytest.raises(ResourceSolveError, match=r"round\(s\) \[1, 3\]"):
        rf.FusedEngine.check_outputs(
            {"bad_solve": np.array([False, True, False, True])})
    rf.FusedEngine.check_outputs({"bad_solve": np.zeros(4, bool)})


def test_fused_validation_errors():
    with pytest.raises(ValueError, match="OSAFL scored round only"):
        build_fused_engine("fedavg", dataclasses.replace(
            XC, rounds_per_dispatch=1))
    with pytest.raises(ValueError, match="stacked"):
        build_fused_engine("osafl", dataclasses.replace(
            XC, request_backend="python"))
    with pytest.raises(ValueError, match="resource backend"):
        build_fused_engine("osafl", dataclasses.replace(
            XC, resource_backend="f16"))
    with pytest.raises(ValueError, match="rounds_per_dispatch"):
        build_fused_engine("osafl", dataclasses.replace(
            XC, rounds_per_dispatch=0))
    with pytest.raises(ValueError, match="round_backend"):
        run_vectorized_experiment("osafl", dataclasses.replace(
            XC, round_backend="turbo"))
    oracle_fused = dataclasses.replace(XC, request_backend="python")
    with pytest.raises(ValueError, match="dispatch"):
        run_experiment("osafl", oracle_fused)
    with pytest.raises(ValueError, match="dispatch"):
        run_pod_online_experiment("osafl", oracle_fused)


def test_init_carry_refuses_cold_stream():
    """The in-scan request draw runs at static warmup=0, so a cohort whose
    sliding windows are still cold must be rejected up front."""
    eng, s = build_fused_engine("osafl", XC)
    cat, streams = make_population(XC.seed, XC.num_clients)
    cold = StackedRequestStream.from_streams(cat, streams, seed=XC.seed + 1)
    with pytest.raises(ValueError, match="warm"):
        eng.init_carry(s.server, s.sbuf, cold, 0)
