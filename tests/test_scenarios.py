"""Scenario layer (src/repro/scenarios/): spec parsing and composition
semantics, hook purity, the null-scenario bit-exactness guarantee on every
engine, and the pairwise composition matrix on both the dense-stacked and
sparse-cohort paths (the ISSUE acceptance surface).

The null-parity tests are the load-bearing ones: a scenario hook that
touches the host RNG, resizes a draw, or perturbs an input when it should
not fire shows up here as a bitwise trajectory divergence.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np
import pytest

from benchmarks.common import (ExperimentConfig, run_centralized_sgd,
                               run_experiment, run_pod_online_experiment,
                               run_vectorized_experiment)
from repro.core.resource_stacked import stack_clients
from repro.core.resource import make_clients
from repro.scenarios import REGISTRY, Scenario, parse_scenario

METRICS = ("round", "test_loss", "test_acc", "participants")


def _xc(**kw) -> ExperimentConfig:
    base = dict(model="mlp", dataset=2, num_clients=6, rounds=3,
                capacity=(12, 24), arrivals=4, batch=8, seed=7)
    base.update(kw)
    return ExperimentConfig(**base)


def _key(history):
    return [tuple(h[k] for k in METRICS) for h in history]


# ---------------------------------------------------------------------------
# parsing and composition semantics
# ---------------------------------------------------------------------------

def test_parse_scenario_basics():
    assert parse_scenario("", seed=0) is None
    assert parse_scenario(None, seed=0) is None
    null = parse_scenario("null", seed=0)
    assert isinstance(null, Scenario) and null.is_null
    scn = parse_scenario("churn(p_away=0.4)+flash_crowd(scale=2)", seed=1)
    assert [p.name for p in scn.perturbations] == ["churn", "flash_crowd"]
    assert not scn.is_null
    assert scn.arrival_width(8) == 16


def test_parse_scenario_rejects_malformed_specs():
    for bad in ("nope()", "churn(p_away=2.0)", "null+churn()",
                "churn(bogus_kw=1)", "churn(p_away=)", "churn)("):
        with pytest.raises(ValueError):
            parse_scenario(bad, seed=0)


def test_registry_covers_the_named_perturbations():
    assert {"churn", "flash_crowd", "quiet", "radius_step",
            "device_classes", "pareto_select"} <= set(REGISTRY)


def test_bind_is_idempotent_and_guards_rebind():
    scn = parse_scenario("churn()", seed=0)
    scn.bind(8)
    scn.bind(8)                                   # idempotent
    with pytest.raises(ValueError):
        scn.bind(16)


def test_hooks_are_pure_in_seed_and_round():
    """The same (spec, seed) replayed gives identical draws round by round
    — the property resume and the golden pins rest on."""
    a = parse_scenario("churn(p_away=0.5)+pareto_select()", seed=3)
    b = parse_scenario("churn(p_away=0.5)+pareto_select()", seed=3)
    a.bind(12), b.bind(12)
    for t in (0, 1, 5, 99):
        np.testing.assert_array_equal(a.round_available(t, 12),
                                      b.round_available(t, 12))
        np.testing.assert_array_equal(a.round_selection_weights(t, 12),
                                      b.round_selection_weights(t, 12))
    c = parse_scenario("churn(p_away=0.5)+pareto_select()", seed=4)
    c.bind(12)
    assert any(not np.array_equal(a.round_available(t, 12),
                                  c.round_available(t, 12))
               for t in range(12))                # a different world


def test_composition_masks_and_weights_combine():
    """Availability masks AND together; selection weights multiply;
    arrival transforms chain in spec order."""
    scn = parse_scenario("churn(p_away=1.0,period=2,away=1)"
                         "+churn(p_away=1.0,period=3,away=1)", seed=5)
    scn.bind(8)
    one = parse_scenario("churn(p_away=1.0,period=2,away=1)", seed=5)
    one.bind(8)
    for t in range(6):
        both = scn.round_available(t, 8)
        first = one.round_available(t, 8)
        assert (both <= first).all()              # AND can only remove
    w2 = parse_scenario("pareto_select()+pareto_select(alpha=3.0)", seed=5)
    w2.bind(8)
    w = w2.round_selection_weights(0, 8)
    assert w.shape == (8,) and (w > 0).all()
    chain = parse_scenario("flash_crowd(period=1,duty=1,scale=2)"
                           "+quiet(scale=0.5)", seed=0)
    chain.bind(4)
    e_u, p_ac = chain.round_arrivals(0, 6, np.full(4, 0.8))
    assert int(e_u) == 12                         # flash_crowd doubled E_u
    np.testing.assert_allclose(p_ac, 0.4)         # quiet halved p_ac
    assert chain.arrival_width(6) == 12


def test_null_scenario_hooks_return_inputs_untouched():
    scn = parse_scenario("null", seed=0)
    scn.bind(4)
    p = np.array([0.5, 0.5, 0.5, 0.5])
    e_u, p_ac = scn.round_arrivals(0, 8, p)
    assert e_u == 8 and p_ac is p                 # same objects, no copy
    assert scn.round_available(0, 4) is None
    assert scn.round_selection_weights(0, 4) is None
    sysb = stack_clients(make_clients(np.random.default_rng(0), 4))
    assert scn.round_system(0, sysb) is sysb
    assert scn.arrival_width(8) == 8


def test_perturbation_parameter_validation():
    for bad in ("churn(p_away=-0.1)", "churn(period=1)",
                "flash_crowd(scale=0)", "flash_crowd(duty=9,period=4)",
                "quiet(scale=1.5)", "radius_step(at=-1)",
                "radius_step(factor=0.0)", "device_classes(f=0.0)",
                "device_classes(weak_frac=2)", "pareto_select(alpha=0)"):
        with pytest.raises(ValueError):
            parse_scenario(bad, seed=0)


# ---------------------------------------------------------------------------
# the null-scenario anchor: bit-exact on every engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,overrides", [
    ("vectorized", {}),
    ("stacked_requests", dict(request_backend="stacked")),
    ("fused", dict(request_backend="stacked", round_backend="fused")),
    ("cohort", dict(cohort_size=4, participation=0.75)),
])
def test_null_scenario_bit_exact(name, overrides):
    base = run_vectorized_experiment("osafl", _xc(**overrides),
                                     eval_samples=32)
    null = run_vectorized_experiment(
        "osafl", _xc(scenario="null", **overrides), eval_samples=32)
    assert _key(base) == _key(null), f"{name}: null scenario diverged"


def test_null_scenario_bit_exact_pod():
    base = run_pod_online_experiment("osafl", _xc(), eval_samples=32)
    null = run_pod_online_experiment("osafl", _xc(scenario="null"),
                                     eval_samples=32)
    assert _key(base) == _key(null)


def test_non_null_scenario_rejected_off_the_stacked_paths():
    with pytest.raises(ValueError, match="scenario"):
        run_experiment("osafl", _xc(scenario="churn()"), eval_samples=16)
    with pytest.raises(ValueError, match="scenario"):
        run_centralized_sgd(_xc(scenario="churn()"), eval_samples=16)
    with pytest.raises(ValueError, match="scenario"):
        run_vectorized_experiment(
            "osafl", _xc(scenario="churn()", request_backend="stacked",
                         round_backend="fused"), eval_samples=16)
    # ""/"null" pass through everywhere
    assert run_experiment("osafl", _xc(scenario="null", rounds=1),
                          eval_samples=16)


# ---------------------------------------------------------------------------
# pairwise composition on the dense-stacked and sparse-cohort paths
# ---------------------------------------------------------------------------

# one representative spec per named perturbation, tuned to actually fire
# within the 2-round matrix runs
SPECS = {
    "churn": "churn(p_away=0.5,period=2,away=1)",
    "flash_crowd": "flash_crowd(period=2,duty=1,scale=2)",
    "quiet": "quiet(scale=0.5)",
    "radius_step": "radius_step(at=1,factor=1.667)",
    "device_classes": "device_classes(weak_frac=0.5)",
    "pareto_select": "pareto_select()",
}

PAIRS = sorted(itertools.combinations(sorted(SPECS), 2))


@pytest.mark.parametrize("a,b", PAIRS)
def test_pairwise_compositions_run_on_both_paths(a, b):
    spec = f"{SPECS[a]}+{SPECS[b]}"
    for overrides in ({}, dict(cohort_size=4, participation=0.75)):
        hist = run_vectorized_experiment(
            "osafl", _xc(rounds=2, scenario=spec, **overrides),
            eval_samples=16)
        assert [h["round"] for h in hist] == [0, 1], (spec, overrides)
        assert all(np.isfinite(h["test_loss"]) for h in hist), \
            (spec, overrides)
        assert all(0 <= h["participants"] <= 6 for h in hist)


def test_scenario_perturbs_the_trajectory():
    """A firing scenario must actually change the run (guards against
    hooks that parse but never apply)."""
    base = run_vectorized_experiment("osafl", _xc(), eval_samples=32)
    churned = run_vectorized_experiment(
        "osafl", _xc(scenario="churn(p_away=1.0,period=2,away=1)"),
        eval_samples=32)
    assert _key(base) != _key(churned)
    parts = [h["participants"] for h in churned]
    assert min(parts) < min(h["participants"] for h in base) or \
        parts != [h["participants"] for h in base]
