"""Distribution parity of the batched Gumbel-trick request model.

The stacked sampler (``data/video_caching_stacked.py``) must be
*stream-equivalent in distribution* to the per-user oracle
(``data/video_caching.py``): every decision branch of Algorithm 5 has an
exact analytic pmf computable from the catalog + user parameters, and the
stacked draws are chi-squared-tested against it per branch at a fixed
Markov state (first request / exploit / explore), plus the exploit-vs-
explore branch frequency at the eps boundary values. Chain-level behaviour
is compared against the scalar oracle on per-chain statistics (iid across
chains — labels *within* one sticky chain are dependent, so pooled-label
chi-squared tests would be anti-conservative).

Also here: structural parity (sliding windows, Dataset-1 feature rows,
padded layout), snapshot round-trips of the stacked stream through the
RunState codec (hypothesis), and the ``request_backend="stacked"`` harness
smoke + guard rails.

All tests are fixed-seed and therefore deterministic; the chi-squared
acceptance thresholds (p > 1e-3) were checked against a seed sweep
(p-values consistent with Uniform[0,1], no systematic bias).
"""
import dataclasses
import tempfile

import jax
import numpy as np
import pytest

from _hyp import given, settings, st

from repro import checkpoint
from repro.checkpoint import CheckpointError
from repro.data.video_caching import (Catalog, D1_DIM, F_FILES,
                                      FILES_PER_GENRE, G_GENRES,
                                      RequestStream, SEQ_LEN, UserModel,
                                      dataset1_sample, make_population,
                                      zipf_mandelbrot_pmf)
from repro.data.video_caching_stacked import StackedRequestStream

try:                                           # scipy: exact chi2 p-values
    from scipy import stats as _scipy_stats
except ImportError:                            # pragma: no cover
    _scipy_stats = None


# ---------------------------------------------------------------------------
# chi-squared helpers (scipy when available, Wilson-Hilferty fallback)
# ---------------------------------------------------------------------------

def _chi2_ok(f_obs, f_exp, alpha=1e-3) -> bool:
    """Pearson chi-squared goodness-of-fit at significance ``alpha``."""
    f_obs, f_exp = np.asarray(f_obs, float), np.asarray(f_exp, float)
    f_exp = f_exp * (f_obs.sum() / f_exp.sum())
    stat = float(np.sum((f_obs - f_exp) ** 2 / f_exp))
    k = len(f_obs) - 1
    if _scipy_stats is not None:
        return _scipy_stats.chi2.sf(stat, k) > alpha
    # Wilson-Hilferty: chi2_{1-alpha}(k) ~= k (1 - 2/(9k) + z sqrt(2/(9k)))^3
    z = 3.0902                                  # Phi^-1(1 - 1e-3)
    crit = k * (1 - 2 / (9 * k) + z * np.sqrt(2 / (9 * k))) ** 3
    return stat <= crit


def _assert_pmf_match(pmf, labels, n):
    """Chi-squared of observed label counts vs an analytic pmf, with
    low-expectation cells lumped (standard validity rule E >= 5)."""
    obs = np.bincount(labels, minlength=F_FILES).astype(float)
    exp = pmf * n
    assert obs[exp == 0].sum() == 0, "draw outside the branch support"
    big = exp >= 5
    f_obs = np.concatenate([obs[big], [obs[~big].sum()]])
    f_exp = np.concatenate([exp[big], [exp[~big].sum()]])
    keep = f_exp > 0
    assert _chi2_ok(f_obs[keep], f_exp[keep])


# ---------------------------------------------------------------------------
# fixed-state cohorts: U independent copies of one user at one Markov state
# ---------------------------------------------------------------------------

_RNG = np.random.default_rng(0)
CAT = Catalog.create(_RNG)
USER = UserModel.create(_RNG, topk=3)          # K=3: exploit draw is random


def _clone_cohort(U, genre, file, eps=None, topk=None, warm_hist=True):
    """U scalar streams with identical user parameters pinned at one Markov
    state (the per-branch pmfs condition on exactly this)."""
    streams = []
    for u in range(U):
        um = UserModel(genre_pref=USER.genre_pref.copy(),
                       eps=USER.eps if eps is None else eps,
                       p_ac=USER.p_ac,
                       topk=USER.topk if topk is None else topk)
        um._genre, um._file = genre, file
        s = RequestStream(CAT, um, np.random.default_rng(u))
        if warm_hist:
            s._history = [0] * SEQ_LEN          # ds2 emits from step one
        streams.append(s)
    return streams


def _one_draw(streams, seed):
    """One request per user via the stacked sampler; returns (U,) labels."""
    stk = StackedRequestStream.from_streams(CAT, streams, seed=seed)
    _, ys, _ = stk.draw_dataset2(np.ones(len(streams), int), 1)
    return np.asarray(ys)[:, 0]


N_COHORT = 6000


def test_first_request_pmf():
    """First request: genre ~ Cat(pref), then Zipf-Mandelbrot through the
    genre's popularity order (Algorithm 5 lines 1-2)."""
    z = zipf_mandelbrot_pmf(FILES_PER_GENRE)
    pmf = np.zeros(F_FILES)
    for g in range(G_GENRES):
        for r in range(FILES_PER_GENRE):
            pmf[g * FILES_PER_GENRE + CAT.popularity[g][r]] += \
                USER.genre_pref[g] * z[r]
    labels = _one_draw(_clone_cohort(N_COHORT, -1, -1), seed=7)
    _assert_pmf_match(pmf, labels, N_COHORT)


def _exploit_pmf(user, f0):
    """The oracle's exploit branch pmf: re-normalized softmax over the
    top-K most-similar same-genre files, current file excluded."""
    g0 = f0 // FILES_PER_GENRE
    lo = g0 * FILES_PER_GENRE
    members = np.arange(lo, lo + FILES_PER_GENRE)
    members = members[members != f0]
    sims = CAT.cos_sim[f0, members]
    probs = np.exp(sims - sims.max())
    probs /= probs.sum()
    order = np.argsort(-probs)[:user.topk]
    pmf = np.zeros(F_FILES)
    pmf[members[order]] = probs[order] / probs[order].sum()
    return pmf


def test_exploit_pmf_topk():
    """Exploit branch (eps=1 pins it): support is exactly the top-K
    most-similar same-genre files and the draw follows the re-normalized
    softmax."""
    g0, f0 = 2, 47
    labels = _one_draw(_clone_cohort(N_COHORT, g0, f0, eps=1.0), seed=18)
    _assert_pmf_match(_exploit_pmf(USER, f0), labels, N_COHORT)


def test_exploit_topk1_is_argmax():
    """K=1 degenerates to the deterministic most-similar file — both the
    oracle and the Gumbel draw (argmax over a single candidate)."""
    g0, f0 = 1, 33
    streams = _clone_cohort(256, g0, f0, eps=1.0, topk=1)
    labels = _one_draw(streams, seed=4)
    expect = streams[0].user.next_request(np.random.default_rng(0), CAT)
    assert np.all(labels == expect)


def test_explore_pmf():
    """Explore branch (eps=0 pins it): genre ~ Cat(pref | not current),
    then Zipf-Mandelbrot — the current genre is never drawn."""
    g0, f0 = 2, 47
    z = zipf_mandelbrot_pmf(FILES_PER_GENRE)
    others = [g for g in range(G_GENRES) if g != g0]
    pref = USER.genre_pref[others]
    pref = pref / pref.sum()
    pmf = np.zeros(F_FILES)
    for gg, pg in zip(others, pref):
        for r in range(FILES_PER_GENRE):
            pmf[gg * FILES_PER_GENRE + CAT.popularity[gg][r]] += pg * z[r]
    labels = _one_draw(_clone_cohort(N_COHORT, g0, f0, eps=0.0), seed=9)
    lo = g0 * FILES_PER_GENRE
    assert np.all((labels < lo) | (labels >= lo + FILES_PER_GENRE))
    _assert_pmf_match(pmf, labels, N_COHORT)


@pytest.mark.parametrize("eps", [0.4, 0.9])
def test_branch_frequency_at_eps_bounds(eps):
    """P(exploit) == eps at the boundary values of the paper's eps_u range.
    Exploit always stays in the current genre and explore always leaves it,
    so the branch is read off the genre transition."""
    g0, f0 = 2, 47
    labels = _one_draw(_clone_cohort(N_COHORT, g0, f0, eps=eps),
                       seed=10)
    stay = int((labels // FILES_PER_GENRE == g0).sum())
    assert _chi2_ok([stay, N_COHORT - stay],
                    [eps * N_COHORT, (1 - eps) * N_COHORT])


def test_chain_level_statistics_match_oracle():
    """Whole-chain comparison vs the scalar oracle on per-chain statistics
    (iid across chains): same-genre transition counts and distinct-file
    counts agree (Mann-Whitney), and the independent first labels agree
    (chi-squared two-sample)."""
    if _scipy_stats is None:                    # pragma: no cover
        pytest.skip("chain-level rank tests need scipy")
    C, n = 400, 12

    def fresh(u):
        return RequestStream(CAT, UserModel(
            genre_pref=USER.genre_pref.copy(), eps=USER.eps, p_ac=USER.p_ac,
            topk=USER.topk), np.random.default_rng(5000 + u))

    scalar = np.stack([fresh(u).draw_dataset2(n)[1] for u in range(C)])
    stk = StackedRequestStream.from_streams(
        CAT, [fresh(u) for u in range(C)], seed=42)
    _, ys, _ = stk.draw_dataset2(np.full(C, n), n)
    stacked = np.asarray(ys)

    def same_genre(y):
        g = y // FILES_PER_GENRE
        return (g[:, 1:] == g[:, :-1]).sum(1)

    def distinct(y):
        return np.array([len(set(row)) for row in y])

    assert _scipy_stats.mannwhitneyu(
        same_genre(scalar), same_genre(stacked)).pvalue > 1e-3
    assert _scipy_stats.mannwhitneyu(
        distinct(scalar), distinct(stacked)).pvalue > 1e-3
    a = np.bincount(scalar[:, 0], minlength=F_FILES)
    b = np.bincount(stacked[:, 0], minlength=F_FILES)
    big = (a + b) >= 8
    tbl = np.stack([np.concatenate([a[big], [a[~big].sum()]]),
                    np.concatenate([b[big], [b[~big].sum()]])]).astype(float)
    tbl = tbl[:, tbl.sum(0) > 0]
    assert _scipy_stats.chi2_contingency(tbl).pvalue > 1e-3


# ---------------------------------------------------------------------------
# structural parity: layouts, sliding windows, Dataset-1 features
# ---------------------------------------------------------------------------

def test_padded_layout_and_ranges():
    cat, streams = make_population(1, 8)
    stk = StackedRequestStream.from_streams(cat, streams, seed=2)
    counts = np.array([3, 0, 2, 5, 5, 1, 4, 5])
    xs, ys, c = stk.draw_dataset2(counts, 5)
    assert xs.shape == (8, 5, SEQ_LEN) and ys.shape == (8, 5)
    assert np.array_equal(c, counts)
    ys = np.asarray(ys)
    assert np.all((ys >= 0) & (ys < F_FILES))
    xs1, ys1, _ = stk.draw_dataset1(counts, 5)
    assert xs1.shape == (8, 5, D1_DIM)
    for u, n in enumerate(counts):              # rows past counts are padding
        assert np.all(np.asarray(ys1)[u, n:] == 0)
        assert np.all(np.asarray(xs1)[u, n:] == 0)
    with pytest.raises(ValueError, match="pad width"):
        stk.draw_dataset2(np.full(8, 6), 5)
    with pytest.raises(ValueError, match="width"):
        stk.draw_dataset2(counts, 0)
    with pytest.raises(ValueError, match="counts shape"):
        stk.draw_dataset2(np.ones(5, int), 5)


def test_dataset2_windows_slide():
    """Within one user's stream, consecutive Dataset-2 samples satisfy the
    oracle's construction: window_{i+1} = window_i[1:] + [label_i]."""
    cat, streams = make_population(2, 6)
    stk = StackedRequestStream.from_streams(cat, streams, seed=3)
    stk.draw_dataset2(np.full(6, 4), 4)          # consume the warm-up
    xs, ys, _ = stk.draw_dataset2(np.full(6, 6), 6)
    x, y = np.asarray(xs), np.asarray(ys)
    for u in range(6):
        for i in range(5):
            assert list(x[u, i + 1]) == list(x[u, i][1:]) + [y[u, i]]


def test_dataset1_features_match_oracle_construction():
    """Every emitted Dataset-1 feature row is exactly ``dataset1_sample`` of
    the previous request (the sliding-window pairing), bit-for-bit up to
    f32 rounding."""
    cat, streams = make_population(3, 5)
    stk = StackedRequestStream.from_streams(cat, streams, seed=5)
    xs, ys, _ = stk.draw_dataset1(np.full(5, 6), 6)
    x, y = np.asarray(xs), np.asarray(ys)
    for u in range(5):
        for i in range(5):
            ref = dataset1_sample(cat, streams[u].user, int(y[u, i]))
            np.testing.assert_allclose(x[u, i + 1], ref,
                                       rtol=1e-6, atol=1e-6)


def test_zero_counts_freeze_markov_state():
    """Users with no arrivals this round must not advance their Markov
    chain (the oracle draws nothing for them)."""
    cat, streams = make_population(4, 6)
    stk = StackedRequestStream.from_streams(cat, streams, seed=6)
    stk.draw_dataset2(np.full(6, 3), 3)
    before = {k: np.asarray(v) for k, v in stk.state_dict().items()}
    stk.draw_dataset2(np.zeros(6, int), 3)
    after = stk.state_dict()
    for k in before:
        if k == "key":                          # the cohort key advances
            continue
        np.testing.assert_array_equal(before[k], np.asarray(after[k]))


def test_zipf_pmf_is_cached_and_readonly():
    """Satellite bugfix: the pmf used to be rebuilt on every explore/first
    draw; now it is one shared read-only array per (n, gamma, q)."""
    a = zipf_mandelbrot_pmf(20)
    assert a is zipf_mandelbrot_pmf(20, gamma=1.2, q=2.0)
    assert not a.flags.writeable
    assert a is not zipf_mandelbrot_pmf(20, gamma=1.3)


# ---------------------------------------------------------------------------
# checkpoint round-trips (RunState codec)
# ---------------------------------------------------------------------------

_CKPT_CAT, _CKPT_STREAMS = make_population(9, 4)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from([1, 2]), st.lists(st.integers(0, 4), min_size=1,
                                         max_size=4), st.integers(0, 4))
def test_stream_snapshot_roundtrip(dataset, bursts, tail):
    """snapshot -> save_run_state -> load -> restore onto a *differently
    seeded* fresh stream: the restored stream continues in bit-exact
    lockstep with the original (draws and state)."""
    s1 = StackedRequestStream.from_streams(_CKPT_CAT, _CKPT_STREAMS, seed=3)
    U = s1.num_users
    for n in bursts:
        counts = np.array([(n + u) % 5 for u in range(U)])
        s1.draw(counts, dataset, 4)
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save_run_state(d + "/s", {"stream": s1.state_dict()})
        loaded = checkpoint.load_run_state(d + "/s")
    s2 = StackedRequestStream.from_streams(_CKPT_CAT, _CKPT_STREAMS, seed=77)
    s2.load_state_dict(loaded["stream"])
    for k, v in s1.state_dict().items():
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(s2.state_dict()[k]), k)
    counts = np.array([(tail + u) % 5 for u in range(U)])
    x1, y1, _ = s1.draw(counts, dataset, 4)
    x2, y2, _ = s2.draw(counts, dataset, 4)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    for k, v in s1.state_dict().items():
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(s2.state_dict()[k]), k)


# ---------------------------------------------------------------------------
# harness integration: smoke + guard rails
# ---------------------------------------------------------------------------

def test_stacked_backend_harness_smoke_u64():
    """Tier-1 smoke (ISSUE acceptance): the stacked request backend runs the
    full vectorized online harness end-to-end for 3 rounds at U=64."""
    from benchmarks.common import ExperimentConfig, run_vectorized_experiment
    xc = ExperimentConfig(model="mlp", dataset=2, num_clients=64, rounds=3,
                          seed=3, request_backend="stacked")
    hist = run_vectorized_experiment("osafl", xc, eval_samples=64)
    assert len(hist) == 3
    for h in hist:
        assert np.isfinite(h["test_loss"])
        assert 0 <= h["participants"] <= 64
        assert h["request_gen_s"] > 0
    assert hist[-1]["participants"] > 0


def test_stacked_backend_harness_smoke_dataset1():
    from benchmarks.common import ExperimentConfig, run_vectorized_experiment
    xc = ExperimentConfig(model="fcn", dataset=1, num_clients=8, rounds=2,
                          capacity=(12, 24), arrivals=4, batch=8, seed=5,
                          request_backend="stacked")
    hist = run_vectorized_experiment("osafl", xc, eval_samples=32)
    assert len(hist) == 2 and np.isfinite(hist[-1]["test_loss"])


def test_request_backend_guard_rails(tmp_path):
    """The loop harness is the python-stream oracle (stacked refused), an
    unknown backend is refused, and a snapshot cannot resume into a
    different request backend (it is part of the run shape)."""
    from benchmarks.common import (ExperimentConfig, checkpoint_path,
                                   run_centralized_sgd, run_experiment,
                                   run_vectorized_experiment)
    xc = ExperimentConfig(model="mlp", dataset=2, num_clients=4, rounds=1,
                          capacity=(12, 24), arrivals=4, batch=8, seed=5,
                          request_backend="stacked")
    with pytest.raises(ValueError, match="request_backend"):
        run_experiment("osafl", xc, eval_samples=16)
    with pytest.raises(ValueError, match="request_backend"):
        run_centralized_sgd(xc, eval_samples=16)
    with pytest.raises(ValueError, match="request_backend"):
        run_vectorized_experiment(
            "osafl", dataclasses.replace(xc, request_backend="np"),
            eval_samples=16)
    run_vectorized_experiment("osafl", xc, eval_samples=16,
                              save_every_k=1, checkpoint_dir=tmp_path)
    with pytest.raises(CheckpointError, match="request_backend"):
        run_vectorized_experiment(
            "osafl",
            dataclasses.replace(xc, rounds=2, request_backend="python"),
            eval_samples=16, resume_from=checkpoint_path(tmp_path, 1))
