"""Optional-hypothesis shim: keeps the property tests collectable (and still
meaningful) when the `hypothesis` dev dependency is absent.

If hypothesis is installed, this module re-exports the real `given`,
`settings`, and `strategies`. Otherwise it provides a miniature fallback that
draws a fixed number of deterministic pseudo-random examples from the small
strategy subset the suite uses (integers, floats, lists, sampled_from), so
tier-1 never hard-fails on a missing dev dependency but the invariants are
still exercised. Install the real thing via requirements-dev.txt (or the
`dev` extra) for full shrinking and boundary coverage.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover

    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng, i):
            """i-th example for this test run (i=0,1 hit boundaries)."""
            return self._draw(rng, i)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            ends = (min_value, max_value)
            return _Strategy(lambda rng, i: int(
                ends[i] if i < 2 else rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            ends = (min_value, max_value)
            return _Strategy(lambda rng, i: float(
                ends[i] if i < 2 else rng.uniform(min_value, max_value)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng, i):
                n = min_size if i == 0 else int(
                    rng.integers(min_size, max_size + 1))
                return [elements.example(rng, 2) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(
                lambda rng, i: seq[i % len(seq) if i < 2
                                   else int(rng.integers(len(seq)))])

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            # no functools.wraps: pytest would follow __wrapped__ and treat
            # the strategy-filled parameters as fixtures
            def run():
                rng = np.random.default_rng(0)
                for i in range(_FALLBACK_EXAMPLES):
                    fn(*[s.example(rng, i) for s in strategies])
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run
        return deco

    def settings(**_kwargs):
        return lambda fn: fn
